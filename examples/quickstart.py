"""Quickstart: the full paper pipeline in ~60 lines.

Builds a WatDiv-like RDF graph, deploys pattern-induced subgraphs onto 4
edge servers, schedules a 20-user SPARQL workload with the B&B MINLP solver,
and compares against the paper's four baselines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.sparql.query import parse_sparql


def main() -> None:
    # 1. data: synthetic WatDiv-flavoured RDF graph
    g = generate_watdiv_like(scale=2.0, seed=0)
    print(f"RDF graph: {g.store}")

    # 2. system: 4 edge servers (0.2 GHz, ~75 Mbps links), 20 end users,
    #    cloud at 5 Mbps — the paper's §5.1 defaults
    params = SystemParams.synthetic(n_users=20, n_edges=4, seed=1)
    system = EdgeCloudSystem(g.store, g.dictionary, params,
                             storage_budgets=400_000)

    # 3. offline: per-user query history -> pattern-induced subgraphs
    history = [workload_sparql(g, 5, seed=100 + n) for n in range(20)]
    system.prepare(history)
    for es in system.edges:
        print(f"  ES{es.server_id}: {len(es.index)} resident patterns, "
              f"{es.used_bytes():,} bytes of G[P]")
    print(f"construction: {system.construction_seconds:.3f}s")

    # 4. online: one scheduling round per policy
    texts = workload_sparql(g, 20, seed=77)
    queries = [(n, parse_sparql(t, g.dictionary))
               for n, t in enumerate(texts)]
    print(f"\n{'policy':<12} {'objective(s)':>12} {'edge%':>7} "
          f"{'sched(ms)':>10}")
    for policy in ["cloud_only", "random", "edge_first", "greedy", "bnb"]:
        rep = system.run_round(queries, policy=policy)
        edge_frac = 1.0 - rep.assignment_ratio.get(-1, 0.0)
        print(f"{policy:<12} {rep.objective:>12.3f} {edge_frac:>6.0%} "
              f"{rep.schedule_seconds * 1e3:>10.2f}")

    # 5. dynamic placement: an asynchronous delta-rebalance overlapping the
    # next round (compute runs on a background thread; the commit waits at
    # the round's epoch barrier and ships only TripleDelta diffs)
    handle = system.rebalance_async()
    system.run_round(queries, policy="greedy")
    report = handle.join()
    changes = report.changes
    print(f"\nrebalance (added, evicted) per ES: {changes}")
    print(f"epoch {report.epoch}: shipped {report.shipped_bytes}B as deltas"
          f" (full re-ship: {report.full_bytes}B),"
          f" {report.matcher_calls} matcher calls"
          f" ({report.induced_hits} memo hits)")


if __name__ == "__main__":
    main()
