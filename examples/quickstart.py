"""Quickstart: full SPARQL over the cloud-edge system via `SparqlEndpoint`.

Builds a WatDiv-like RDF graph, stands up the paper's edge-cloud system (4
edge servers, 20 end users, B&B MINLP scheduling), and then talks to it
through the one-object public API: ``SparqlEndpoint`` — SELECT/ASK with
FILTER, OPTIONAL, UNION, DISTINCT, ORDER BY, LIMIT/OFFSET, all compiled
onto the shard-parallel BGP engine. Algebra queries are scheduled onto
edges per BGP leaf: a query runs at an edge iff every *required* leaf's
pattern is resident there.

(The pre-algebra entry points — ``parse_sparql`` -> ``QueryGraph`` ->
``QueryEngine.execute`` / ``EdgeCloudSystem.run_round`` — still work as
thin shims for the Def.-2 BGP subset; new code should use the endpoint.)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import json
import threading
import urllib.request
from urllib.parse import quote

from repro import SparqlEndpoint, SparqlHttpServer
from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql


def main() -> None:
    # 1. data: synthetic WatDiv-flavoured RDF graph
    g = generate_watdiv_like(scale=2.0, seed=0)
    print(f"RDF graph: {g.store}")

    # 2. a standalone endpoint over the raw store: parse -> algebra ->
    #    batched engine, no system wiring required
    ep = SparqlEndpoint(g.store, g.dictionary)
    tbl = ep.query(
        'SELECT DISTINCT ?c WHERE { ?x <country> ?c . ?x <likes> ?p . '
        'FILTER (?c != "Country0") } ORDER BY ?c LIMIT 5')
    print("countries:", [c for (c,) in tbl.rows()])
    print("any subgenres?", ep.ask("ASK { ?g <subgenreOf> ?h }"))

    # 2b. device-resident joins (PR 7): the jax backend runs eligible
    #     bound-predicate star/path BGPs fully on the accelerator —
    #     fused scan+probe Pallas kernels, ONE device->host transfer per
    #     batch (interpret mode here on CPU; compiled and fast on TPU)
    from repro.rdf.sharding import ShardedTripleStore
    from repro.sparql.engine import JaxBackend, QueryEngine
    from repro.sparql.query import parse_sparql
    sharded = ShardedTripleStore.from_store(g.store, 4)
    eng = QueryEngine(backend=JaxBackend())
    star = ['SELECT ?x ?p WHERE { ?x <likes> ?p . ?p <hasGenre> ?gn }',
            'SELECT ?x ?c WHERE { ?x <country> ?c . ?x <likes> ?p }']
    eng.execute_batch(sharded, [parse_sparql(t, g.dictionary)
                                for t in star])
    s = eng.stats
    print(f"device pipeline [{s.backend_mode}]: "
          f"{s.device_queries} on-device, {s.device_fallbacks} host "
          f"fallbacks, {s.host_transfers} bulk transfer(s) "
          f"({s.host_transfer_bytes:,}B), {s.scalar_syncs} scalar syncs")

    # 3. the edge-cloud system: 4 edge servers (0.2 GHz, ~75 Mbps links),
    #    20 end users, cloud at 5 Mbps — the paper's §5.1 defaults
    params = SystemParams.synthetic(n_users=20, n_edges=4, seed=1)
    system = EdgeCloudSystem(g.store, g.dictionary, params,
                             storage_budgets=400_000)
    history = [workload_sparql(g, 5, seed=100 + n) for n in range(20)]
    system.prepare(history)       # per-user history -> G[P] on the edges
    for es in system.edges:
        print(f"  ES{es.server_id}: {len(es.index)} resident patterns, "
              f"{es.used_bytes():,} bytes of G[P]")
    print(f"construction: {system.construction_seconds:.3f}s")

    # 4. one endpoint over the whole system (shared engine = one cache
    #    domain); algebra texts join plain BGPs in the same rounds
    ep = SparqlEndpoint.from_system(system)
    texts = workload_sparql(g, 16, seed=77) + [
        'SELECT ?x ?g WHERE { ?x <likes> ?p . '
        'OPTIONAL { ?p <hasGenre> ?g } }',
        'SELECT ?x ?y WHERE { { ?x <follows> ?y } UNION '
        '{ ?x <likes> ?y } } LIMIT 50',
        'SELECT DISTINCT ?c WHERE { ?u <country> ?c } ORDER BY ?c',
        'ASK { ?x <subgenreOf> ?y }',
    ]
    pairs = [(n % 20, t) for n, t in enumerate(texts)]
    print(f"\n{'policy':<12} {'objective(s)':>12} {'edge%':>7} "
          f"{'sched(ms)':>10}")
    for policy in ["cloud_only", "random", "edge_first", "greedy", "bnb"]:
        rep = ep.run_round(pairs, policy=policy, observe=(policy == "bnb"))
        edge_frac = 1.0 - rep.assignment_ratio.get(-1, 0.0)
        print(f"{policy:<12} {rep.objective:>12.3f} {edge_frac:>6.0%} "
              f"{rep.schedule_seconds * 1e3:>10.2f}")

    # 5. the plan, as the admission layer sees it (cache provenance per
    #    BGP leaf after the rounds above warmed the engine)
    print("\n" + ep.explain(texts[-4]))

    # 6. dynamic placement: an asynchronous delta-rebalance overlapping the
    #    next round picks up the observed OPTIONAL/UNION leaf patterns
    handle = system.rebalance_async()
    ep.run_round(pairs, policy="greedy")
    report = handle.join()
    print(f"\nrebalance (added, evicted) per ES: {report.changes}")
    print(f"epoch {report.epoch}: shipped {report.shipped_bytes}B as deltas"
          f" (full re-ship: {report.full_bytes}B),"
          f" {report.matcher_calls} matcher calls"
          f" ({report.induced_hits} memo hits)")
    s = ep.stats
    print(f"engine: {s.queries} BGP executions, {s.bgp_leaves} algebra "
          f"leaves, {s.filters_applied} filters, {s.optional_joins} "
          f"left-joins, {s.union_branches} union branches, "
          f"{s.cache_hits} result-cache hits")

    # 6b. collaborative partial evaluation (PR 8): when NO single edge
    #     holds every leaf of a query, the scheduler has a third option
    #     beyond {edge, cloud} — split the query across the edges that
    #     hold its leaves, ship compact dictionary-free binding tables
    #     over the fast backhaul, and assemble at the cloud. Chosen only
    #     when the generalized Eq. 5 prices it under full cloud
    #     evaluation (congested cloud pool, fast backhaul below);
    #     results are bit-identical to cloud-only either way.
    import numpy as np
    from repro.core.pattern import pattern_of
    pp = SystemParams(
        F=np.full(2, 1.0e9), r_edge=np.full((4, 2), 75e6),
        r_cloud=np.full(4, 5e6), assoc=np.ones((4, 2), dtype=bool),
        r_backhaul=np.full(2, 1e9),      # fast edge->assembler backhaul
        F_cloud=0.05e9)                  # congested cloud compute pool
    collab = EdgeCloudSystem(g.store, g.dictionary, pp,
                             storage_budgets=10_000_000)
    collab.edges[0].deploy(g.store, [pattern_of(parse_sparql(
        'SELECT ?x ?p WHERE { ?x <likes> ?p }', g.dictionary))])
    collab.edges[1].deploy(g.store, [pattern_of(parse_sparql(
        'SELECT ?p ?gn WHERE { ?p <hasGenre> ?gn }', g.dictionary))])
    cep = SparqlEndpoint.from_system(collab)
    ptext = ('SELECT ?x ?gn WHERE { { ?x <likes> ?p } '
             '{ ?p <hasGenre> ?gn } }')
    print("\n" + cep.explain(ptext))
    prep = collab.run_round_batched([(0, cep.parse(ptext))],
                                    policy="bnb", collect_results=True)
    o = prep.outcomes[0]
    print(f"partial round: {prep.partial_queries} query split across "
          f"edges {list(o.partial_servers)}, shipped "
          f"{prep.partial_bytes_shipped:,}B of binding tables, "
          f"{prep.results[0].num_matches} rows assembled at the cloud")

    # 6c. live ingest (PR 9): SPARQL UPDATE through the same endpoint.
    #     INSERT DATA mints new dictionary terms (bumping the version the
    #     plan memo keys on), routes rows to the right shards id-stably,
    #     invalidates only the touched patterns' induced-subgraph memos,
    #     and propagates version-consistent deltas to every populated
    #     edge replica — queries never observe a half-applied placement.
    ack = ep.update('INSERT DATA { <liveUser> <likes> <Product0> . '
                    '<liveUser> <country> <Country1> }')
    print(f"\ningest: +{ack['inserted']} triples, "
          f"{ack['new_terms']} new terms, {ack['edges_updated']} edge "
          f"replicas updated ({ack['shipped_bytes']}B shipped), "
          f"placement epoch {ack['placement_epoch']}")
    print("liveUser rows:", ep.query(
        'SELECT ?p ?o WHERE { <liveUser> ?p ?o }').num_matches)
    ep.update('DELETE WHERE { <liveUser> ?p ?o }')   # and back out
    # continuous-ingest regimes pair writes with the multi-epoch
    # pipelined rebalance: epoch N+1's induced-id prefetch overlaps
    # epoch N's commit, and writes are admitted between epochs
    pipe = system.rebalance_pipeline(epochs=2)
    print(f"pipelined rebalance: epochs {[r.epoch for r in pipe]}")

    # 7. serving: the SPARQL-Protocol HTTP front end. Concurrent clients
    #    coalesce inside a 2ms admission window into ONE engine batch
    #    (W3C JSON results; 503+Retry-After on a full queue, 504 on
    #    missed deadlines — see examples/serve_offload.py for more)
    served = texts[-3:]                      # UNION / DISTINCT / ASK
    replies = [None] * 12
    with SparqlHttpServer(ep, window_s=0.002, max_batch=64) as srv:
        def client(j: int) -> None:
            url = srv.url + "/sparql?query=" + quote(served[j % 3])
            with urllib.request.urlopen(url) as r:
                replies[j] = json.loads(r.read())
        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(len(replies))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # writes ride the same route: POST application/sparql-update
        # serializes against the micro-batch window it shares (reads in
        # the window see the pre-write store, the write commits after)
        upd = urllib.request.Request(
            srv.url + "/sparql",
            data=b"INSERT DATA { <httpUser> <likes> <Product0> }",
            headers={"Content-Type": "application/sparql-update"},
            method="POST")
        with urllib.request.urlopen(upd) as r:
            wack = json.loads(r.read())
        adm = srv.stats_dict()["admission"]
    print(f"\nHTTP: {len(replies)} concurrent clients -> {adm['batches']} "
          f"engine batches (mean batch {adm['mean_batch_size']:.1f}); "
          f"ASK over HTTP: {replies[2]['boolean']}; update over HTTP: "
          f"+{wack['inserted']} triple, {wack['new_terms']} new term(s)")

    # 8. generating workloads: sample star/path/flower/snowflake BGPs by
    #    walking the live store — every template records its EXACT result
    #    cardinality at sample time — then replay a seeded, Zipf-skewed
    #    open-loop schedule through the admission queue and verify every
    #    served answer against the recorded ground truth
    from repro import (AdmissionQueue, PatternSampler, ShapeConfig,
                       TrafficConfig, build_schedule, replay)
    smp = PatternSampler(g.store, g.dictionary, seed=7,
                         exclude_predicates=["country"])  # churn reserve
    templates = smp.sample_mix(
        [ShapeConfig(s, size=3, const_frac=0.3,
                     decorations=(None, "filter", "limit"))
         for s in ("star", "path", "flower", "snowflake")], 3)
    sched = build_schedule(templates, TrafficConfig(
        duration_s=0.3, qps=200, zipf_s=1.2, cold_fraction=0.15,
        write_fraction=0.2, write_style="churn", seed=7),
        churn_predicate="country")   # writes never touch sampled preds
    ep2 = SparqlEndpoint(g.store, g.dictionary)
    with AdmissionQueue(ep2, window_s=0.004, max_batch=32,
                        coalesce_writes=True) as aq:
        rep = replay(aq, sched)
    star_p99 = rep.per_shape["star"].percentiles()["p99"] * 1e3
    print(f"\nworkload: {len(templates)} sampled templates -> "
          f"{rep.completed} served ({rep.writes.count} writes, "
          f"{rep.admission['writes_coalesced']} commits coalesced away); "
          f"{rep.verified}/{sched.n_queries} answers matched their "
          f"sample-time cardinality exactly; star p99 {star_p99:.1f}ms")
    assert rep.verification_ok


if __name__ == "__main__":
    main()
