"""Serve batched inference requests through the paper's offload scheduler.

The MINLP scheduler (pattern-executability -> assignment + resource
allocation) is workload-agnostic: here it routes *model inference* requests
across two "edge" replica pools — one hosting the recsys scorer, one hosting
a small LM decode service — with a cloud fallback, exactly as it routes
SPARQL queries in examples/quickstart.py.

Run:  PYTHONPATH=src python examples/serve_offload.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_spec
from repro.launch.train import make_batch_iter, reduce_config
from repro.models.common import AxisRules
from repro.models.recsys import init_recsys_params, recsys_score
from repro.models.transformer import (init_kv_cache, init_lm_params,
                                      lm_decode_step)
from repro.runtime.serving import OffloadServingPool, Replica

RULES = AxisRules(batch=(), fsdp=None, tp=None)
CLASS_RECSYS, CLASS_LM = 0, 1


def main() -> None:
    # — replica 0: wide&deep CTR scorer ——————————————————————————
    rspec = get_spec("wide-deep")
    rcfg = reduce_config(rspec)
    rparams = init_recsys_params(rcfg, jax.random.PRNGKey(0))
    score = jax.jit(lambda b: recsys_score(rcfg, rparams, b, RULES))

    def recsys_runner(payloads):
        batch = {k: jnp.stack([p[k][0] for p in payloads])
                 for k in payloads[0]}
        return np.asarray(score(batch)).tolist()

    # — replica 1: LM single-token decode ————————————————————————
    lspec = get_spec("qwen3-0.6b")
    lcfg = reduce_config(lspec)
    lparams = init_lm_params(lcfg, jax.random.PRNGKey(1))
    dec = jax.jit(lambda c, t, i: lm_decode_step(lcfg, lparams, c, t, i,
                                                 RULES))

    def lm_runner(payloads):
        toks = jnp.asarray([[p["token"]] for p in payloads], jnp.int32)
        cache = init_kv_cache(lcfg, len(payloads), 8)
        logits, _ = dec(cache, toks, jnp.int32(0))
        return np.asarray(jnp.argmax(logits[:, 0], -1)).tolist()

    def cloud_runner(payloads):   # cloud serves every class
        out = []
        for p in payloads:
            out.append(recsys_runner([p])[0] if "ids" in p
                       else lm_runner([p])[0])
        return out

    pool = OffloadServingPool(
        replicas=[
            Replica(0, classes={CLASS_RECSYS}, cycles_per_s=2e8,
                    link_bps=75e6, runner=recsys_runner),
            Replica(1, classes={CLASS_LM}, cycles_per_s=4e8,
                    link_bps=75e6, runner=lm_runner),
        ],
        cloud_runner=cloud_runner, cloud_link_bps=5e6)

    # — build a mixed admission batch ————————————————————————————
    rng = np.random.default_rng(0)
    rbatch = next(make_batch_iter(rspec, rcfg, 1, seed=3))
    requests = []
    for i in range(16):
        if i % 2 == 0:
            requests.append({"class_id": CLASS_RECSYS,
                             "cycles": float(rng.uniform(1e6, 5e7)),
                             "result_bits": float(rng.uniform(1e4, 1e6)),
                             "payload": {k: v for k, v in rbatch.items()}})
        else:
            requests.append({"class_id": CLASS_LM,
                             "cycles": float(rng.uniform(1e7, 2e8)),
                             "result_bits": float(rng.uniform(1e3, 1e5)),
                             "payload": {"token": int(rng.integers(
                                 0, lcfg.vocab))}})

    for policy in ["cloud_only", "greedy", "bnb"]:
        out = pool.admit(requests, policy=policy)
        counts = {int(k): int((out.assignments == k).sum())
                  for k in sorted(set(out.assignments.tolist()))}
        print(f"{policy:<11} objective={out.objective:9.3f}s "
              f"assignments={counts} sched={out.schedule_seconds*1e3:.1f}ms")
        assert all(r is not None for r in out.responses)
    print("OK — all responses served; B&B placed each class on its replica")


if __name__ == "__main__":
    main()
