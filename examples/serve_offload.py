"""Serve concurrent SPARQL clients over HTTP with micro-batch admission.

Stands up the serving front end from :mod:`repro.runtime.http` — a
SPARQL-Protocol-style endpoint (``GET/POST /sparql``, W3C JSON results)
whose admission queue coalesces concurrently arriving requests into ONE
engine batch per micro-batch window. Here the queue runs in ``mode="pool"``:
each coalesced batch is admitted through the paper's offload scheduler
(:class:`~repro.runtime.serving.OffloadServingPool`, B&B MINLP placement),
so every HTTP burst is scheduled across two edge replicas and the cloud
before executing — the cloud-edge offloading story, end to end over
sockets.

The script fires a fleet of concurrent urllib clients (GET and POST,
SELECT and ASK), then reads ``GET /stats`` back to show what the window
bought: how many engine batches served the burst, the coalescing factor,
and the cache provenance (endpoint memo hits, engine scan dedup).

Run:  PYTHONPATH=src python examples/serve_offload.py
"""

import json
import threading
import time
import urllib.request
from urllib.parse import quote

from repro import SparqlEndpoint
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.runtime.http import SparqlHttpServer
from repro.runtime.serving import (OffloadServingPool, Replica,
                                   make_sparql_runner)
from repro.sparql.engine import QueryEngine


def main() -> None:
    # 1. data + an endpoint wired to the offload pool: two SPARQL-serving
    #    edge replicas (0.2 GHz-ish, 75 Mbps links) and a cloud fallback —
    #    one shared engine, so the whole pool is a single cache domain
    g = generate_watdiv_like(scale=1.0, seed=0)
    engine = QueryEngine()
    runner = make_sparql_runner(g.store, engine)
    pool = OffloadServingPool(
        replicas=[Replica(0, {0}, 2e8, 75e6, runner),
                  Replica(1, {0}, 4e8, 75e6, runner)],
        cloud_runner=runner, cloud_link_bps=5e6)
    ep = SparqlEndpoint(g.store, g.dictionary, engine=engine, pool=pool)
    print(f"RDF graph: {g.store}")

    # 2. the HTTP front end: a 2 ms admission window, up to 64 queries per
    #    engine batch, every batch placed by the B&B offload scheduler
    texts = workload_sparql(g, 8, seed=1) + [
        'SELECT ?x ?g WHERE { ?x <likes> ?p . '
        'OPTIONAL { ?p <hasGenre> ?g } } LIMIT 20',
        'ASK { ?x <subgenreOf> ?y }',
    ]
    #    greedy placement per batch: B&B is exponential in batch size, so
    #    a 64-wide coalesced batch wants the O(n log n) scheduler
    with SparqlHttpServer(ep, window_s=0.002, max_batch=64, mode="pool",
                          mode_kw={"policy": "greedy"}) as srv:
        print(f"serving on {srv.url}  (window=2ms, max_batch=64, "
              f"mode=pool, policy=greedy)\n")

        # 3. a concurrent client fleet: everyone fires at once, so the
        #    window coalesces the burst into a handful of engine batches
        n_clients = 32
        lat = [0.0] * n_clients
        body = [None] * n_clients

        def client(j: int) -> None:
            text = texts[j % len(texts)]
            t0 = time.perf_counter()
            if j % 3 == 0:                       # POST application/sparql-query
                req = urllib.request.Request(
                    srv.url + "/sparql", data=text.encode(),
                    headers={"Content-Type": "application/sparql-query"})
            else:                                # GET ?query=
                req = srv.url + "/sparql?query=" + quote(text)
            with urllib.request.urlopen(req) as r:
                body[j] = json.loads(r.read())
            lat[j] = time.perf_counter() - t0

        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(n_clients)]
        start = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - start

        # 4. what the clients saw: W3C SPARQL JSON results
        sel = body[1]["results"]["bindings"]
        print(f"{n_clients} concurrent clients served in {wall*1e3:.1f}ms "
              f"(mean {sum(lat)/len(lat)*1e3:.1f}ms, "
              f"max {max(lat)*1e3:.1f}ms)")
        print(f"sample SELECT row: {sel[0] if sel else '(empty)'}")
        ask = next(b for b in body if "boolean" in b)
        print(f"sample ASK result: {ask}")

        # 5. what the window bought, straight from GET /stats
        with urllib.request.urlopen(srv.url + "/stats") as r:
            stats = json.loads(r.read())
        adm = stats["admission"]
        print(f"\ncoalescing: {adm['submitted']} requests -> "
              f"{adm['batches']} engine batches "
              f"(mean batch {adm['mean_batch_size']:.1f}, "
              f"max coalesced {adm['max_coalesced']})")
        print(f"provenance: endpoint memo hits={stats['endpoint_memo']['hits']}"
              f", engine cache hits={stats['engine']['cache_hits']}, "
              f"scans deduped={stats['engine']['scans_deduped']}")
        assert adm["batches"] < adm["submitted"], "burst should coalesce"
    print("\nOK — coalesced admission served the burst through the "
          "offload pool")


if __name__ == "__main__":
    main()
