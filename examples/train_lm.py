"""End-to-end LM training driver with checkpoint/restart + fault tolerance.

Trains a reduced qwen3-family decoder for a few hundred steps on synthetic
token streams, checkpointing every 50 steps, then simulates a crash and
resumes from the latest checkpoint. (Use --preset full on real hardware —
this container is 1 CPU core.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""

import argparse
import shutil
import tempfile

import jax
import numpy as np

from repro.configs.registry import get_spec
from repro.launch.train import make_batch_iter, reduce_config
from repro.models.common import AxisRules
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim.adamw import AdamWConfig
from repro.runtime.checkpoint import latest_step
from repro.runtime.train_loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()

    spec = get_spec(args.arch)
    cfg = reduce_config(spec)
    rules = AxisRules(batch=(), fsdp=None, tp=None)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced) — {n_params:,} params")

    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_ck_")
    loss_fn = lambda p, b: lm_loss(cfg, p, b, rules)         # noqa: E731
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=10, total_steps=args.steps)

    half = args.steps // 2
    print(f"— phase 1: train to step {half}, checkpoint every 25 —")
    r1 = train(loss_fn, params, make_batch_iter(spec, cfg, 8), opt,
               TrainLoopConfig(total_steps=half, log_every=20,
                               ckpt_every=25, ckpt_dir=ckpt_dir))

    print(f"— simulated crash; resuming from step "
          f"{latest_step(ckpt_dir)} —")
    r2 = train(loss_fn, params, make_batch_iter(spec, cfg, 8), opt,
               TrainLoopConfig(total_steps=args.steps, log_every=20,
                               ckpt_every=25, ckpt_dir=ckpt_dir))
    assert r2.resumed_from == latest_step(ckpt_dir) or r2.resumed_from
    first = r1.history[0]["loss"]
    last = r2.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"(resumed at step {r2.resumed_from})")
    assert last < first, "training must reduce loss"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
