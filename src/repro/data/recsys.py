"""Synthetic recsys batches with a planted logistic structure.

Labels come from a sparse ground-truth weight vector over (field, id) pairs
so training measurably reduces BCE — not pure noise.
"""

from __future__ import annotations

import numpy as np


def recsys_batch(batch: int, n_sparse: int = 40, vocab: int = 1_000_000,
                 nnz: int = 4, n_dense: int = 13, seed: int = 0,
                 hot_fraction: float = 0.05) -> dict:
    """Power-law ids + dense features + planted-model labels."""
    rng = np.random.default_rng(seed)
    # power-law id popularity within each field
    u = rng.random((batch, n_sparse, nnz))
    ids = np.minimum((vocab * u ** 3).astype(np.int64), vocab - 1)
    mask = (rng.random((batch, n_sparse, nnz)) < 0.85).astype(np.float32)
    mask[..., 0] = 1.0  # at least one id per bag
    dense = rng.normal(0, 1, (batch, n_dense)).astype(np.float32)
    # planted model: "hot" ids (small id values) push labels positive
    hot = (ids < vocab * hot_fraction).astype(np.float32) * mask
    logit = hot.sum(axis=(1, 2)) * 0.8 - 2.0 + dense[:, 0] * 0.5
    labels = (rng.random(batch) < 1.0 / (1.0 + np.exp(-logit))
              ).astype(np.float32)
    return {"ids": ids.astype(np.int32), "id_mask": mask, "dense": dense,
            "labels": labels}
