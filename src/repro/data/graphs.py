"""Graph datasets + neighbor sampling for GNN training.

- synthetic graph generators (power-law degree, Cora-like, molecule batches)
- CSR adjacency + a real **uniform fanout neighbor sampler** (GraphSAGE
  style, required by the ``minibatch_lg`` shape): seeds -> k-hop sampled
  subgraph with per-hop fanouts, returned as padded arrays ready for jit.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray      # [N+1]
    indices: np.ndarray     # [E] neighbor ids (outgoing)
    n_nodes: int

    @classmethod
    def from_edges(cls, edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edge_index[:, 0], edge_index[:, 1]
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        indptr = np.searchsorted(src_s, np.arange(n_nodes + 1))
        return cls(indptr=indptr, indices=dst_s, n_nodes=n_nodes)


def random_graph(n_nodes: int, n_edges: int, seed: int = 0,
                 power: float = 0.8) -> np.ndarray:
    """Power-law-ish random digraph as an edge index [E, 2]."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_nodes + 1) ** power
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w)
    dst = rng.choice(n_nodes, size=n_edges, p=w)
    keep = src != dst
    return np.stack([src[keep], dst[keep]], axis=1).astype(np.int32)


def cora_like(n_nodes: int = 2708, n_edges: int = 10556, d_feat: int = 1433,
              n_classes: int = 7, seed: int = 0) -> dict:
    """Cora-shaped synthetic citation graph with sparse binary features."""
    rng = np.random.default_rng(seed)
    edge_index = random_graph(n_nodes, n_edges, seed=seed)
    feat = (rng.random((n_nodes, d_feat)) < 0.012).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    mask = np.zeros(n_nodes, np.float32)
    mask[rng.choice(n_nodes, size=max(8, n_nodes // 20), replace=False)] = 1.0
    return {"feat": feat, "edge_index": edge_index, "labels": labels,
            "label_mask": mask}


def molecule_batch(batch: int = 128, n_nodes: int = 30, n_edges: int = 64,
                   n_species: int = 16, seed: int = 0) -> dict:
    """Batched small molecules: radius-graph-ish edges + synthetic energy."""
    rng = np.random.default_rng(seed)
    N = batch * n_nodes
    species = rng.integers(0, n_species, N).astype(np.int32)
    coords = rng.normal(0, 1.5, (N, 3)).astype(np.float32)
    edges = []
    for g in range(batch):
        base = g * n_nodes
        s = rng.integers(0, n_nodes, n_edges) + base
        d = rng.integers(0, n_nodes, n_edges) + base
        edges.append(np.stack([s, d], axis=1))
    edge_index = np.concatenate(edges).astype(np.int32)
    keep = edge_index[:, 0] != edge_index[:, 1]
    edge_index = edge_index[keep]
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    energy = rng.normal(0, 1, batch).astype(np.float32)
    return {"species": species, "coords": coords, "edge_index": edge_index,
            "graph_ids": graph_ids, "energy": energy}


# ---------------------------------------------------------------------------
# neighbor sampler (GraphSAGE fanout sampling)
# ---------------------------------------------------------------------------

def sample_neighbors(g: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                     rng: np.random.Generator) -> dict:
    """K-hop uniform neighbor sampling.

    Returns a node-induced sampled subgraph with *local* ids:
    {nodes (global ids, seeds first), edge_index (local), seed_count}.
    """
    nodes = list(seeds.tolist())
    local = {int(v): i for i, v in enumerate(nodes)}
    edges_src: list[int] = []
    edges_dst: list[int] = []
    frontier = list(seeds.tolist())
    for fanout in fanouts:
        nxt: list[int] = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fanout, deg)
            picks = rng.choice(deg, size=take, replace=False)
            for nb in g.indices[lo + picks]:
                nb = int(nb)
                if nb not in local:
                    local[nb] = len(nodes)
                    nodes.append(nb)
                    nxt.append(nb)
                # message flows neighbor -> seed side (dst = v)
                edges_src.append(local[nb])
                edges_dst.append(local[v])
        frontier = nxt
    edge_index = (np.stack([np.asarray(edges_src), np.asarray(edges_dst)],
                           axis=1).astype(np.int32)
                  if edges_src else np.zeros((0, 2), np.int32))
    return {"nodes": np.asarray(nodes, dtype=np.int64),
            "edge_index": edge_index,
            "seed_count": len(seeds)}


def pad_subgraph(sub: dict, n_nodes_pad: int, n_edges_pad: int) -> dict:
    """Pad a sampled subgraph to static shapes (jit-friendly).

    Padding edges are self-loops on a dummy last node, so segment ops stay
    correct; ``node_mask``/``edge_mask`` mark real entries.
    """
    nodes = sub["nodes"]
    ei = sub["edge_index"]
    n, e = len(nodes), len(ei)
    if n > n_nodes_pad or e > n_edges_pad:
        raise ValueError(f"subgraph ({n} nodes, {e} edges) exceeds padding "
                         f"({n_nodes_pad}, {n_edges_pad})")
    nodes_p = np.zeros(n_nodes_pad, dtype=np.int64)
    nodes_p[:n] = nodes
    ei_p = np.full((n_edges_pad, 2), n_nodes_pad - 1, dtype=np.int32)
    ei_p[:e] = ei
    node_mask = np.zeros(n_nodes_pad, np.float32)
    node_mask[:n] = 1.0
    edge_mask = np.zeros(n_edges_pad, np.float32)
    edge_mask[:e] = 1.0
    return {"nodes": nodes_p, "edge_index": ei_p, "node_mask": node_mask,
            "edge_mask": edge_mask, "seed_count": sub["seed_count"]}
