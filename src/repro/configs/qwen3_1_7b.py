"""qwen3-1.7b [hf:Qwen/Qwen3-8B family]. qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
Pure full attention -> long_500k skipped.
"""

from ..models.transformer import LMConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_head=128, d_ff=6144, vocab=151936,
        qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
        act="silu",
    )
    return ArchSpec(
        arch_id="qwen3-1.7b", family="lm", config=cfg,
        skip_shapes={"long_500k": "pure full-attention arch; 512k decode "
                                  "requires sub-quadratic attention state"},
        source="hf:Qwen/Qwen3-8B",
        microbatches=2)
