"""EGNN [arXiv:2102.09844]: 4L hidden=64, E(n)-equivariant."""

from ..models.gnn import GNNConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = GNNConfig(name="egnn", model="egnn", n_layers=4, d_hidden=64,
                    n_species=16)
    return ArchSpec(arch_id="egnn", family="gnn", config=cfg,
                    source="arXiv:2102.09844")
