"""Architecture registry: 10 assigned archs x their shape grids.

Each arch module exposes ``spec() -> ArchSpec``; the registry builds
*cells* — (arch x shape) units with a step function, abstract inputs
(ShapeDtypeStruct, no allocation) and in/out shardings — consumed by the
dry-run driver, the roofline extractor and the smoke tests alike.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import AxisRules
from ..models.gnn import GNNConfig, gnn_init, gnn_loss
from ..models.recsys import (RecsysConfig, init_recsys_params, recsys_loss,
                             recsys_param_shardings, recsys_score,
                             retrieval_topk)
from ..models.transformer import (LMConfig, cache_shardings, init_kv_cache,
                                  init_lm_params, lm_decode_step, lm_forward,
                                  lm_loss, param_shardings)
from ..optim.adamw import AdamWConfig, adamw_init
from ..runtime.train_loop import make_train_step

ARCH_IDS = [
    "phi3.5-moe-42b-a6.6b", "granite-moe-1b-a400m", "qwen3-0.6b",
    "qwen3-1.7b", "gemma2-2b",
    "pna", "egnn", "gcn-cora", "nequip",
    "wide-deep",
]

_MODULE_OF = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-1b-a400m": "granite_moe",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma2-2b": "gemma2_2b",
    "pna": "pna",
    "egnn": "egnn",
    "gcn-cora": "gcn_cora",
    "nequip": "nequip",
    "wide-deep": "wide_deep",
}

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, seq_shard=True),
}

GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433),
    "minibatch_lg": dict(kind="sampled", n_nodes=184320, n_edges=169984,
                         d_feat=602, batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": dict(kind="full", n_nodes=2449029, n_edges=61859140,
                         d_feat=100),
    "molecule": dict(kind="molecule", n_graphs=128, nodes_per=30,
                     edges_per=64),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="score", batch=512),
    "serve_bulk": dict(kind="score", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


@dataclass
class ArchSpec:
    arch_id: str
    family: str                      # lm | gnn | recsys
    config: object
    skip_shapes: dict[str, str] = field(default_factory=dict)
    source: str = ""
    microbatches: int = 1            # grad-accumulation factor for train cells

    @property
    def shapes(self) -> dict:
        table = {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                 "recsys": RECSYS_SHAPES}[self.family]
        return {k: v for k, v in table.items() if k not in self.skip_shapes}


def get_spec(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch_id]}")
    return mod.spec()


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for a in ARCH_IDS:
        s = get_spec(a)
        cells.extend((a, shape) for shape in s.shapes)
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCH_IDS:
        s = get_spec(a)
        out.extend((a, shape, why) for shape, why in s.skip_shapes.items())
    return out


# ---------------------------------------------------------------------------
# cell construction (dry-run + smoke share this)
# ---------------------------------------------------------------------------

@dataclass
class Cell:
    fn: Callable                # jit-able step function
    abstract_args: tuple        # ShapeDtypeStructs (params, opt, batch, ...)
    in_shardings: tuple
    out_shardings: object       # None -> let GSPMD choose
    # scan-body probe for roofline correction: (fn, abstract args, n_repeat)
    probe: tuple | None = None
    description: str = ""
    # grad-accumulation scan bodies are ALSO counted once by cost_analysis;
    # roofline totals scale by this factor (== microbatches)
    cost_multiplier: int = 1


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _pad_to(n: int, m: int = 512) -> int:
    """Data-pipeline padding: sharded leading dims need divisibility by the
    batch-axis product (32 on the multi-pod mesh); 512 also keeps TPU lane
    alignment."""
    return ((n + m - 1) // m) * m


def _batch_dim_spec(mesh, rules, dim: int):
    """Shard a leading dim over the batch axes when divisible, else
    replicate (e.g. batch=1 retrieval / long-context decode)."""
    total = 1
    for ax in rules.batch:
        total *= mesh.shape[ax]
    return rules.batch if dim % total == 0 else None


def _opt_cfg() -> AdamWConfig:
    return AdamWConfig(peak_lr=3e-4, warmup_steps=100, total_steps=10_000)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(spec: ArchSpec, shape_name: str, mesh) -> Cell:
    rules = AxisRules.for_mesh(mesh)
    if spec.family == "lm":
        return _lm_cell(spec, shape_name, mesh, rules)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape_name, mesh, rules)
    return _recsys_cell(spec, shape_name, mesh, rules)


# -- LM ----------------------------------------------------------------------

def _lm_cell(spec: ArchSpec, shape_name: str, mesh, rules) -> Cell:
    cfg: LMConfig = spec.config
    sh = LM_SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    key = jax.random.PRNGKey(0)
    params = _abstract(lambda k: init_lm_params(cfg, k), key)
    p_spec = param_shardings(cfg, rules)
    p_named = _named(mesh, p_spec)
    batch_spec = NamedSharding(mesh, P(_batch_dim_spec(mesh, rules, B), None))

    if sh["kind"] == "train":
        opt = _abstract(adamw_init, params)
        o_named = {"m": p_named, "v": p_named,
                   "step": NamedSharding(mesh, P())}
        loss_fn = partial_loss(cfg, rules)
        mb = spec.microbatches
        step = make_train_step(loss_fn, _opt_cfg(), microbatches=mb)
        if mb > 1:
            tokens = jax.ShapeDtypeStruct((mb, B // mb, S), jnp.int32)
            batch_spec = NamedSharding(
                mesh, P(None, _batch_dim_spec(mesh, rules, B // mb), None))
            bprobe = B // mb
        else:
            tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
            bprobe = B
        probe = _lm_probe(cfg, rules, bprobe, S, mesh, train=True)
        return Cell(fn=step, abstract_args=(params, opt, tokens),
                    in_shardings=(p_named, o_named, batch_spec),
                    out_shardings=None, probe=probe,
                    description=f"train_step B={B} S={S} mb={mb}",
                    cost_multiplier=mb)

    if sh["kind"] == "prefill":
        def fwd(params, tokens):
            logits, _ = lm_forward(cfg, params, tokens, rules)
            return logits
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        probe = _lm_probe(cfg, rules, B, S, mesh, train=False)
        return Cell(fn=fwd, abstract_args=(params, tokens),
                    in_shardings=(p_named, batch_spec), out_shardings=None,
                    probe=probe, description=f"prefill B={B} S={S}")

    # decode
    seq_shard = sh.get("seq_shard", False)
    cache = _abstract(lambda: init_kv_cache(cfg, B, S))
    c_named = _named(mesh, cache_shardings(cfg, rules, seq_shard=seq_shard))

    def decode(params, cache, tokens, pos):
        return lm_decode_step(cfg, params, cache, tokens, pos, rules)

    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return Cell(fn=decode, abstract_args=(params, cache, tokens, pos),
                in_shardings=(p_named, c_named, batch_spec,
                              NamedSharding(mesh, P())),
                out_shardings=None,
                description=f"serve_step B={B} cache={S}")


def partial_loss(cfg, rules):
    def loss_fn(params, tokens):
        return lm_loss(cfg, params, tokens, rules)
    return loss_fn


def _lm_probe(cfg: LMConfig, rules, B, S, mesh, train: bool):
    """Single-layer probe: measures scan-body cost once for the roofline
    correction total = module + (L-1) * probe."""
    from ..models.transformer import _layer
    lcfg = cfg
    key = jax.random.PRNGKey(0)
    full = _abstract(lambda k: init_lm_params(lcfg, k), key)
    layer0 = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                          full["layers"])
    x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    window = jax.ShapeDtypeStruct((), jnp.int32)
    x_spec = NamedSharding(mesh, P(rules.batch, None, None))
    lp_spec = _named(mesh, param_shardings(lcfg, rules)["layers"])
    lp_spec = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*s.spec[1:])), lp_spec)

    if train:
        def probe_fn(lp, x, window):
            def f(lp, x):
                positions = jnp.broadcast_to(jnp.arange(S), (B, S))
                out, aux = _layer(lcfg, lp, x, window, positions, rules)
                return jnp.mean(out.astype(jnp.float32))
            val, grads = jax.value_and_grad(f, argnums=(0, 1))(lp, x)
            return val, grads
    else:
        def probe_fn(lp, x, window):
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            out, aux = _layer(lcfg, lp, x, window, positions, rules)
            return out
    return (probe_fn, (layer0, x, window),
            (lp_spec, x_spec, NamedSharding(mesh, P())),
            cfg.n_layers - 1)


# -- GNN ----------------------------------------------------------------------

def _gnn_batch_struct(cfg: GNNConfig, sh: dict):
    if sh["kind"] == "molecule":
        N = sh["n_graphs"] * sh["nodes_per"]
        E = sh["n_graphs"] * sh["edges_per"]
        G = sh["n_graphs"]
    else:
        N, E, G = sh["n_nodes"], sh["n_edges"], 1
    N, E = _pad_to(N), _pad_to(E)   # pipeline pads to shardable sizes
    ei = jax.ShapeDtypeStruct((E, 2), jnp.int32)
    if cfg.model in ("gcn", "pna"):
        d_feat = sh.get("d_feat", cfg.d_feat)
        return {
            "feat": jax.ShapeDtypeStruct((N, d_feat), jnp.float32),
            "edge_index": ei,
            "labels": jax.ShapeDtypeStruct((N,), jnp.int32),
            "label_mask": jax.ShapeDtypeStruct((N,), jnp.float32),
        }
    return {
        "species": jax.ShapeDtypeStruct((N,), jnp.int32),
        "coords": jax.ShapeDtypeStruct((N, 3), jnp.float32),
        "edge_index": ei,
        "graph_ids": jax.ShapeDtypeStruct((N,), jnp.int32),
        "energy": jax.ShapeDtypeStruct((G,), jnp.float32),
    }


def _gnn_cell(spec: ArchSpec, shape_name: str, mesh, rules) -> Cell:
    cfg: GNNConfig = spec.config
    sh = dict(GNN_SHAPES[shape_name])
    if cfg.model in ("gcn", "pna") and sh["kind"] == "molecule":
        sh["d_feat"] = cfg.n_species      # one-hot species as features
    # gcn/pna configs pin d_feat per dataset shape
    key = jax.random.PRNGKey(0)
    dcfg = cfg
    if cfg.model in ("gcn", "pna"):
        dcfg = GNNConfig(**{**cfg.__dict__,
                            "d_feat": sh.get("d_feat", cfg.d_feat)})
    params = _abstract(lambda k: gnn_init(dcfg, k), key)
    opt = _abstract(adamw_init, params)
    batch = _gnn_batch_struct(dcfg, sh)

    def loss_fn(params, batch):
        return gnn_loss(dcfg, params, batch, rules)

    step = make_train_step(loss_fn, _opt_cfg())
    # vertex-partitioned DistGNN schedule: edges AND node arrays shard over
    # the batch axes; mp_aggregate psum_scatters edge partials back to the
    # node shards (params replicated — they are tiny)
    repl = NamedSharding(mesh, P())
    batch_sh = {}
    for k, v in batch.items():
        if k == "energy":
            batch_sh[k] = repl
        else:
            ax = _batch_dim_spec(mesh, rules, v.shape[0])
            batch_sh[k] = NamedSharding(
                mesh, P(ax, *([None] * (v.ndim - 1))))
    p_sh = jax.tree.map(lambda _: repl, params)
    o_sh = {"m": p_sh, "v": p_sh, "step": repl}
    return Cell(fn=step, abstract_args=(params, opt, batch),
                in_shardings=(p_sh, o_sh, batch_sh), out_shardings=None,
                description=f"gnn train {shape_name}")


# -- recsys ---------------------------------------------------------------------

def _recsys_cell(spec: ArchSpec, shape_name: str, mesh, rules) -> Cell:
    cfg: RecsysConfig = spec.config
    sh = RECSYS_SHAPES[shape_name]
    B = sh["batch"]
    key = jax.random.PRNGKey(0)
    params = _abstract(lambda k: init_recsys_params(cfg, k), key)
    p_named = _named(mesh, recsys_param_shardings(cfg, rules))
    bspec = {
        "ids": jax.ShapeDtypeStruct((B, cfg.n_sparse, cfg.nnz_per_field),
                                    jnp.int32),
        "id_mask": jax.ShapeDtypeStruct((B, cfg.n_sparse, cfg.nnz_per_field),
                                        jnp.float32),
        "dense": jax.ShapeDtypeStruct((B, cfg.n_dense), jnp.float32),
    }
    bax = _batch_dim_spec(mesh, rules, B)
    b_named = {
        "ids": NamedSharding(mesh, P(bax, None, None)),
        "id_mask": NamedSharding(mesh, P(bax, None, None)),
        "dense": NamedSharding(mesh, P(bax, None)),
    }
    if sh["kind"] == "train":
        bspec["labels"] = jax.ShapeDtypeStruct((B,), jnp.float32)
        b_named["labels"] = NamedSharding(mesh, P(rules.batch))
        opt = _abstract(adamw_init, params)
        o_named = {"m": p_named, "v": p_named,
                   "step": NamedSharding(mesh, P())}

        def loss_fn(params, batch):
            return recsys_loss(cfg, params, batch, rules)
        step = make_train_step(loss_fn, _opt_cfg())
        return Cell(fn=step, abstract_args=(params, opt, bspec),
                    in_shardings=(p_named, o_named, b_named),
                    out_shardings=None,
                    description=f"recsys train B={B}")
    if sh["kind"] == "score":
        def fn(params, batch):
            return recsys_score(cfg, params, batch, rules)
        return Cell(fn=fn, abstract_args=(params, bspec),
                    in_shardings=(p_named, b_named), out_shardings=None,
                    description=f"recsys score B={B}")

    def fn(params, batch):
        return retrieval_topk(cfg, params, batch, rules, k=100)
    return Cell(fn=fn, abstract_args=(params, bspec),
                in_shardings=(p_named, b_named), out_shardings=None,
                description=f"retrieval B={B} C={cfg.n_candidates}")
