"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
Pure full attention -> long_500k skipped (noted in DESIGN.md / EXPERIMENTS).
"""

from ..models.transformer import LMConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=6400, vocab=32064,
        n_experts=16, top_k=2, qk_norm=False, tie_embeddings=False,
        rope_theta=10_000.0, act="silu", q_chunk=256,
    )
    return ArchSpec(
        arch_id="phi3.5-moe-42b-a6.6b", family="lm", config=cfg,
        skip_shapes={"long_500k": "pure full-attention arch; 512k decode "
                                  "requires sub-quadratic attention state"},
        source="hf:microsoft/Phi-3.5-MoE-instruct",
        microbatches=4)
