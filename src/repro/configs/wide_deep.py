"""Wide&Deep [arXiv:1606.07792]: 40 sparse fields, embed 32, MLP 1024-512-256."""

from ..models.recsys import RecsysConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = RecsysConfig(name="wide-deep", n_sparse=40, embed_dim=32,
                       vocab_per_field=1_000_000, n_dense=13,
                       mlp_dims=(1024, 512, 256), nnz_per_field=4,
                       n_candidates=1_000_000, retrieval_dim=256)
    return ArchSpec(arch_id="wide-deep", family="recsys", config=cfg,
                    source="arXiv:1606.07792")
