"""GCN [arXiv:1609.02907]: 2L hidden=16, mean aggregation, sym norm."""

from ..models.gnn import GNNConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = GNNConfig(name="gcn-cora", model="gcn", n_layers=2, d_hidden=16,
                    n_classes=7, d_feat=1433)
    return ArchSpec(arch_id="gcn-cora", family="gnn", config=cfg,
                    source="arXiv:1609.02907")
