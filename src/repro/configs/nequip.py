"""NequIP [arXiv:2101.03164]: 5L hidden=32, l_max=2, n_rbf=8, cutoff=5.

O(3)-equivariant interatomic potential; irreps in the Cartesian tensor
basis (see DESIGN.md hardware-adaptation notes).
"""

from ..models.gnn import GNNConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = GNNConfig(name="nequip", model="nequip", n_layers=5, d_hidden=32,
                    l_max=2, n_rbf=8, cutoff=5.0, n_species=16)
    return ArchSpec(arch_id="nequip", family="gnn", config=cfg,
                    source="arXiv:2101.03164")
