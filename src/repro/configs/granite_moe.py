"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
Pure full attention -> long_500k skipped.
"""

from ..models.transformer import LMConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_head=64, d_ff=512, vocab=49155,
        n_experts=32, top_k=8, tie_embeddings=True, act="silu",
    )
    return ArchSpec(
        arch_id="granite-moe-1b-a400m", family="lm", config=cfg,
        skip_shapes={"long_500k": "pure full-attention arch; 512k decode "
                                  "requires sub-quadratic attention state"},
        source="hf:ibm-granite/granite-3.0-1b-a400m-base")
