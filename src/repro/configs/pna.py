"""PNA [arXiv:2004.05718]: 4L hidden=75, mean/max/min/std x id/amp/atten."""

from ..models.gnn import GNNConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = GNNConfig(name="pna", model="pna", n_layers=4, d_hidden=75,
                    n_classes=16)
    return ArchSpec(arch_id="pna", family="gnn", config=cfg,
                    source="arXiv:2004.05718")
