"""gemma2-2b [arXiv:2408.00118]. Local+global alternating, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
sliding window 4096 on even layers, attn softcap 50, final softcap 30,
GeGLU, sandwich norms, sqrt(d) embedding scaling.

Hybrid local/global -> long_500k RUNS here (O(S) cache attention per step;
local layers bound the window).
"""

from ..models.transformer import LMConfig
from .registry import ArchSpec


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8,
        n_kv_heads=4, d_head=256, d_ff=9216, vocab=256000,
        attn_pattern="local_global", window=4096,
        attn_softcap=50.0, final_softcap=30.0, sandwich_norm=True,
        scale_embed=True, act="gelu", tie_embeddings=True,
    )
    return ArchSpec(arch_id="gemma2-2b", family="lm", config=cfg,
                    source="arXiv:2408.00118",
                    microbatches=4)
