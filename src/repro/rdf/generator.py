"""Synthetic RDF data + workload generation, WatDiv-style.

WatDiv [Aluç et al., ISWC'14] generates an e-commerce-flavoured schema with
entity classes connected by predicates of widely varying fan-out, then derives
query workloads from structural templates (star / linear / snowflake /
complex).  We reproduce that recipe at configurable scale so every benchmark
in §5 of the paper has a deterministic, self-contained data source.

All randomness flows through a seeded ``np.random.Generator``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .dictionary import Dictionary
from .graph import TripleStore

# (class_from, predicate, class_to, out_degree_low, out_degree_high, coverage)
# coverage = fraction of `class_from` instances that carry this predicate.
_SCHEMA = [
    ("User",     "follows",     "User",     1, 8,  0.6),
    ("User",     "likes",       "Product",  1, 10, 0.8),
    ("User",     "makesPurchase", "Purchase", 1, 4, 0.5),
    ("Purchase", "purchaseFor", "Product",  1, 1,  1.0),
    ("Purchase", "purchaseDate", "Date",    1, 1,  1.0),
    ("Product",  "hasGenre",    "Genre",    1, 3,  0.9),
    ("Product",  "producedBy",  "Producer", 1, 1,  0.7),
    ("Product",  "hasReview",   "Review",   0, 12, 0.7),
    ("Review",   "reviewer",    "User",     1, 1,  1.0),
    ("Review",   "rating",      "Rating",   1, 1,  1.0),
    ("Product",  "retailedBy",  "Retailer", 1, 4,  0.8),
    ("Retailer", "country",     "Country",  1, 1,  1.0),
    ("User",     "country",     "Country",  1, 1,  0.9),
    ("Producer", "country",     "Country",  1, 1,  0.9),
    ("Genre",    "subgenreOf",  "Genre",    0, 2,  0.4),
]

# relative class sizes at scale=1.0 (instances per class)
_CLASS_SIZE = {
    "User": 500, "Product": 400, "Purchase": 300, "Review": 600,
    "Producer": 40, "Retailer": 30, "Genre": 25, "Date": 80,
    "Rating": 5, "Country": 20,
}


@dataclass
class GeneratedGraph:
    store: TripleStore
    dictionary: Dictionary
    class_of: dict[str, np.ndarray]   # class name -> entity id array


def generate_watdiv_like(scale: float = 1.0, seed: int = 0) -> GeneratedGraph:
    """Generate a WatDiv-flavoured RDF graph. ``scale=1`` ≈ 6-8k triples.

    Triples grow ~linearly with ``scale`` (WatDiv 100M <-> scale ≈ 1.5e4).
    """
    rng = np.random.default_rng(seed)
    d = Dictionary()
    class_of: dict[str, np.ndarray] = {}
    for cname, base in _CLASS_SIZE.items():
        n = max(2, int(base * scale))
        ids = np.asarray([d.add_entity(f"{cname}{i}") for i in range(n)])
        class_of[cname] = ids

    s_all, p_all, o_all = [], [], []
    for cfrom, pred, cto, lo, hi, cov in _SCHEMA:
        pid = d.add_predicate(pred)
        src = class_of[cfrom]
        dst = class_of[cto]
        mask = rng.random(len(src)) < cov
        srcs = src[mask]
        # power-law-ish popularity on destinations: a few hot entities get
        # most references (WatDiv models this with Zipfian object selection)
        weights = 1.0 / np.arange(1, len(dst) + 1) ** 0.8
        weights /= weights.sum()
        degs = rng.integers(lo, hi + 1, size=len(srcs))
        total = int(degs.sum())
        if total == 0:
            continue
        objs = rng.choice(dst, size=total, p=weights, replace=True)
        s_all.append(np.repeat(srcs, degs))
        p_all.append(np.full(total, pid, dtype=np.int64))
        o_all.append(objs)

    store = TripleStore(np.concatenate(s_all), np.concatenate(p_all),
                        np.concatenate(o_all), d.num_entities,
                        d.num_predicates)
    return GeneratedGraph(store=store, dictionary=d, class_of=class_of)


# ---------------------------------------------------------------------------
# Workload generation: structural templates -> concrete BGP queries
# ---------------------------------------------------------------------------

# Templates are edge lists over symbolic vertices. Vertices named "?x*" are
# variables; "C*" slots are filled with constants sampled from actual graph
# matches, guaranteeing non-empty results (how WatDiv instantiates templates).
# (src, predicate, dst)
_TEMPLATES: dict[str, list[tuple[str, str, str]]] = {
    # star: one center, several outgoing edges
    "star2": [("?x", "likes", "?p1"), ("?x", "follows", "?u1")],
    "star3": [("?x", "likes", "?p1"), ("?x", "follows", "?u1"),
              ("?x", "country", "?c")],
    # linear chains
    "chain2": [("?x", "likes", "?y"), ("?y", "hasGenre", "?g")],
    "chain3": [("?x", "makesPurchase", "?pu"), ("?pu", "purchaseFor", "?pr"),
               ("?pr", "producedBy", "?prod")],
    # snowflake: chain + star at the end
    "snowflake": [("?x", "likes", "?p"), ("?p", "hasReview", "?r"),
                  ("?r", "reviewer", "?u"), ("?p", "retailedBy", "?rt")],
    # complex: cycle-ish with a constant anchor slot
    "complex": [("?x", "likes", "?p"), ("?x", "country", "C0"),
                ("?p", "hasGenre", "?g"), ("?p", "retailedBy", "?rt"),
                ("?rt", "country", "C0")],
    # constant-anchored star (selective)
    "anchored_star": [("?x", "likes", "C0"), ("?x", "follows", "?u"),
                      ("?x", "country", "?c")],
    "anchored_chain": [("C0", "hasReview", "?r"), ("?r", "reviewer", "?u"),
                       ("?u", "country", "?c")],
}


def template_names() -> list[str]:
    return list(_TEMPLATES)


def workload_sparql(g: GeneratedGraph, n_queries: int, seed: int = 0,
                    templates: list[str] | None = None) -> list[str]:
    """Instantiate ``n_queries`` SPARQL BGP query strings from templates."""
    rng = np.random.default_rng(seed)
    names = templates or list(_TEMPLATES)
    d = g.dictionary
    queries: list[str] = []
    attempts = 0
    while len(queries) < n_queries and attempts < n_queries * 20:
        attempts += 1
        name = names[int(rng.integers(len(names)))]
        edges = _TEMPLATES[name]
        # sample constants: pick a random triple of the template's first
        # constant-adjacent predicate and reuse its entity
        const_map: dict[str, str] = {}
        ok = True
        for (sv, pred, ov) in edges:
            for slot, is_subj in ((sv, True), (ov, False)):
                if slot.startswith("C") and slot not in const_map:
                    pid = d.predicate_id(pred)
                    tids = g.store.pred_tids(pid)
                    if len(tids) == 0:
                        ok = False
                        break
                    tid = int(tids[int(rng.integers(len(tids)))])
                    eid = int(g.store.s[tid] if is_subj else g.store.o[tid])
                    const_map[slot] = d.entity(eid)
            if not ok:
                break
        if not ok:
            continue

        def term(t: str) -> str:
            if t.startswith("?"):
                return t
            return f"<{const_map[t]}>"

        variables = sorted({t for e in edges for t in (e[0], e[2])
                            if t.startswith("?")})
        body = " . ".join(
            f"{term(sv)} <{pred}> {term(ov)}" for (sv, pred, ov) in edges)
        queries.append(f"SELECT {' '.join(variables)} WHERE {{ {body} }}")
    return queries
