"""Dictionary encoding for RDF terms.

RDF engines (gStore, RDF-3X, Virtuoso, ...) map URIs/literals to dense integer
ids once at load time; all query processing then happens on integers. The
cloud and every edge server share one global dictionary, so a subgraph shipped
to an edge needs no re-encoding (paper §2.2: edges store subgraphs of the same
graph G).
"""

from __future__ import annotations

import numpy as np


class Dictionary:
    """Bidirectional term <-> id mapping (entities and predicates separate).

    Entity ids and predicate ids live in independent id spaces, mirroring the
    paper's graph model G = {V, E, L, f}: V indexes entities, L indexes
    properties.
    """

    def __init__(self) -> None:
        self._ent2id: dict[str, int] = {}
        self._id2ent: list[str] = []
        self._pred2id: dict[str, int] = {}
        self._id2pred: list[str] = []
        self._version = 0

    # -- encoding ----------------------------------------------------------
    def add_entity(self, term: str) -> int:
        eid = self._ent2id.get(term)
        if eid is None:
            eid = len(self._id2ent)
            self._ent2id[term] = eid
            self._id2ent.append(term)
            self._version += 1
        return eid

    def add_predicate(self, term: str) -> int:
        pid = self._pred2id.get(term)
        if pid is None:
            pid = len(self._id2pred)
            self._pred2id[term] = pid
            self._id2pred.append(term)
            self._version += 1
        return pid

    @property
    def version(self) -> int:
        """Monotone token bumped whenever a NEW term is added.

        Compiled query plans bake dictionary ids in (triple constants,
        FILTER-operand ``ent_id`` / ``pred_id``), so anything memoizing a
        plan must key on this alongside the query text — a term unknown at
        compile time may exist after live ingest grows the dictionary
        (:class:`repro.sparql.endpoint.SparqlEndpoint` does exactly this).
        """
        return self._version

    # -- lookup ------------------------------------------------------------
    def entity_id(self, term: str) -> int:
        return self._ent2id[term]

    def predicate_id(self, term: str) -> int:
        return self._pred2id[term]

    def has_entity(self, term: str) -> bool:
        return term in self._ent2id

    def has_predicate(self, term: str) -> bool:
        return term in self._pred2id

    def entity(self, eid: int) -> str:
        return self._id2ent[eid]

    def predicate(self, pid: int) -> str:
        return self._id2pred[pid]

    @property
    def num_entities(self) -> int:
        return len(self._id2ent)

    @property
    def num_predicates(self) -> int:
        return len(self._id2pred)

    # -- (de)serialization ---------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        return {
            "entities": np.asarray(self._id2ent, dtype=object),
            "predicates": np.asarray(self._id2pred, dtype=object),
        }

    @classmethod
    def from_arrays(cls, arrays: dict[str, np.ndarray]) -> "Dictionary":
        d = cls()
        for t in arrays["entities"]:
            d.add_entity(str(t))
        for t in arrays["predicates"]:
            d.add_predicate(str(t))
        return d
