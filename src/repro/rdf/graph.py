"""Dictionary-encoded RDF storage: the :class:`RDFStore` protocol and the
single-buffer :class:`TripleStore` implementation.

Every consumer of RDF data in this repo — the BGP matcher, the batched query
engine and its backends, pattern-induced subgraph construction, placement
accounting — programs against :class:`RDFStore`, the accessor surface listed
on the protocol below. Two implementations exist:

- :class:`TripleStore` (here): one monolithic buffer. Storage layout is three
  parallel int arrays (s, p, o) plus derived indexes:

  * CSR grouping of triple ids by predicate (``pred_tids`` — candidate scans
    for bound-predicate triple patterns, the common case);
  * per-predicate triples sorted by subject and by object (``pred_index``),
    enabling ``searchsorted`` merge joins during BGP matching.

- :class:`repro.rdf.sharding.ShardedTripleStore`: S hash-partitioned-by-
  predicate ``TripleStore`` shards behind the same protocol. Triple ids stay
  *global* (shard-concatenation order), so joins and subgraph extraction are
  unchanged, while candidate scans prune to the single shard owning a bound
  predicate (and fan out across shards only for wildcard predicates).

Everything is a dense NumPy array so the matcher is pure data-parallel array
code (the TPU adaptation of gStore's pointer-based matching; see DESIGN.md §3).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

# Monotone store-version tokens. Stores mutate ONLY through ``apply_delta``
# (the placement data-plane, repro.rdf.deltas), which takes a fresh token —
# so a version uniquely identifies store *contents* and stays a sound
# cache-invalidation key: any result memoized against version v can never be
# served for a store holding different triples.
_STORE_VERSIONS = itertools.count()


def triples_size_bytes(n_triples: int) -> int:
    """Modeled storage cost of ``n_triples`` triples.

    Matches an on-disk layout of 3x int64 per triple plus ~25% index overhead
    (gStore's VS-tree etc. are heavier; this is conservative). Shared by
    ``RDFStore.size_bytes`` implementations and the placement knapsack so
    byte accounting agrees regardless of store kind.
    """
    return int(n_triples * 3 * 8 * 1.25)


@dataclass
class PredIndex:
    """Per-predicate sorted views used by the join matcher."""

    tids: np.ndarray        # triple ids with this predicate
    s_order: np.ndarray     # tids permuted so that s is ascending
    s_sorted: np.ndarray    # subjects in ascending order (len == len(tids))
    o_order: np.ndarray     # tids permuted so that o is ascending
    o_sorted: np.ndarray    # objects in ascending order


@runtime_checkable
class RDFStore(Protocol):
    """Accessor surface the matcher / engine / placement stack consumes.

    Triple ids are *global*: ``s[t], p[t], o[t]`` is triple ``t`` for any id
    returned by ``pred_tids`` / ``pred_index`` / a candidate scan, whatever
    the physical layout behind it. ``version`` is a hashable token unique to
    the store's contents (stores are immutable after construction), used as
    a cache-invalidation key by :class:`repro.sparql.engine.QueryEngine` —
    for a sharded store it is a composite over the shard versions.
    """

    s: np.ndarray
    p: np.ndarray
    o: np.ndarray
    num_entities: int
    num_predicates: int
    pred_count: np.ndarray
    pred_distinct_s: np.ndarray
    pred_distinct_o: np.ndarray

    @property
    def num_triples(self) -> int: ...

    @property
    def version(self): ...

    def pred_tids(self, pid: int) -> np.ndarray: ...

    def pred_index(self, pid: int) -> PredIndex: ...

    def triples(self) -> np.ndarray: ...

    def size_bytes(self) -> int: ...

    def subgraph(self, edge_ids: np.ndarray) -> "RDFStore": ...

    def apply_delta(self, delta): ...


class TripleStore:
    """An RDF graph G = (V, E, L, f) as parallel arrays + indexes."""

    def __init__(self, s: np.ndarray, p: np.ndarray, o: np.ndarray,
                 num_entities: int, num_predicates: int) -> None:
        s = np.ascontiguousarray(s, dtype=np.int64)
        p = np.ascontiguousarray(p, dtype=np.int64)
        o = np.ascontiguousarray(o, dtype=np.int64)
        if not (s.shape == p.shape == o.shape) or s.ndim != 1:
            raise ValueError("s, p, o must be 1-D arrays of equal length")
        # Deduplicate (RDF graphs are edge *multisets* in the paper's Def. 1,
        # but duplicate identical triples carry no information for BGP
        # matching; gStore also dedupes at load).
        trip = np.stack([s, p, o], axis=1)
        trip = np.unique(trip, axis=0) if len(trip) else trip.reshape(0, 3)
        self.s, self.p, self.o = trip[:, 0], trip[:, 1], trip[:, 2]
        self.num_entities = int(num_entities)
        self.num_predicates = int(num_predicates)
        self.version = next(_STORE_VERSIONS)
        self._pred_index: dict[int, PredIndex] = {}
        self._build_indexes()

    # -- construction --------------------------------------------------------
    def _build_indexes(self) -> None:
        T = len(self.s)
        order = np.argsort(self.p, kind="stable")
        sorted_p = self.p[order]
        # CSR boundaries over predicates
        self._pred_starts = np.searchsorted(
            sorted_p, np.arange(self.num_predicates + 1))
        self._pred_tids = order
        # per-predicate stats (for the cardinality estimator) — vectorized
        self.pred_count = np.diff(self._pred_starts)
        self.pred_distinct_s = np.zeros(self.num_predicates, dtype=np.int64)
        self.pred_distinct_o = np.zeros(self.num_predicates, dtype=np.int64)
        if T:
            ps = np.unique(np.stack([self.p, self.s], axis=1), axis=0)
            np.add.at(self.pred_distinct_s, ps[:, 0], 1)
            po = np.unique(np.stack([self.p, self.o], axis=1), axis=0)
            np.add.at(self.pred_distinct_o, po[:, 0], 1)
        self._T = T

    def pred_tids(self, pid: int) -> np.ndarray:
        lo, hi = self._pred_starts[pid], self._pred_starts[pid + 1]
        return self._pred_tids[lo:hi]

    def pred_index(self, pid: int) -> PredIndex:
        """Lazily-built sorted views for predicate ``pid``."""
        idx = self._pred_index.get(pid)
        if idx is None:
            tids = self.pred_tids(pid)
            so = np.argsort(self.s[tids], kind="stable")
            oo = np.argsort(self.o[tids], kind="stable")
            idx = PredIndex(
                tids=tids,
                s_order=tids[so], s_sorted=self.s[tids][so],
                o_order=tids[oo], o_sorted=self.o[tids][oo],
            )
            self._pred_index[pid] = idx
        return idx

    def owning_part(self, pid: int) -> tuple["TripleStore", int]:
        """(flat store, global-id offset) holding predicate ``pid``.

        The monolithic store owns everything at offset 0; the sharded
        store returns the predicate's owning shard. This is how
        device-resident consumers (:mod:`repro.sparql.device_join`) address
        a predicate's shard-LOCAL ``pred_index`` views plus the lift needed
        to go back to global triple ids.
        """
        return self, 0

    # -- basic accessors -----------------------------------------------------
    @property
    def num_triples(self) -> int:
        return self._T

    def triples(self) -> np.ndarray:
        """[T, 3] int64 array of (s, p, o)."""
        return np.stack([self.s, self.p, self.o], axis=1)

    def size_bytes(self) -> int:
        """Storage cost of this (sub)graph — used by the placement knapsack."""
        return triples_size_bytes(self._T)

    # -- incremental maintenance ----------------------------------------------
    def apply_delta(self, delta):
        """Apply a :class:`repro.rdf.deltas.TripleDelta` in place.

        Content semantics are idempotent per side: adding a present row or
        evicting an absent one is a no-op (the store is a deduplicated
        set). Indexes are rebuilt, ``pred_index`` views dropped, and a
        fresh version token is taken, so every version-keyed consumer
        (engine result/scan/plan caches, staged device arrays) sees this
        as a new store. Returns the new version.
        """
        from .deltas import DeltaVersionError, setdiff_rows
        if delta.base_version != self.version:
            raise DeltaVersionError(
                f"delta targets version {delta.base_version!r}, store is at "
                f"{self.version!r}")
        rows = self.triples()
        if len(delta.evict):
            rows = setdiff_rows(rows, delta.evict)
        if len(delta.add):
            rows = np.concatenate([rows, delta.add])
        rows = (np.unique(rows, axis=0) if len(rows)
                else rows.reshape(0, 3))
        self.s, self.p, self.o = rows[:, 0], rows[:, 1], rows[:, 2]
        self.version = next(_STORE_VERSIONS)
        self._pred_index.clear()
        self._build_indexes()
        return self.version

    # -- subgraph extraction ---------------------------------------------------
    def subgraph(self, edge_ids: np.ndarray) -> "TripleStore":
        """Subgraph induced by a set of triple (edge) ids.

        Entity/predicate ids are preserved (global dictionary; paper §2.2).
        """
        edge_ids = np.unique(np.asarray(edge_ids, dtype=np.int64))
        return TripleStore(self.s[edge_ids], self.p[edge_ids], self.o[edge_ids],
                           self.num_entities, self.num_predicates)

    # -- (de)serialization ------------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        return {
            "s": self.s, "p": self.p, "o": self.o,
            "meta": np.asarray([self.num_entities, self.num_predicates]),
        }

    @classmethod
    def from_arrays(cls, a: dict[str, np.ndarray]) -> "TripleStore":
        ne, npred = (int(x) for x in a["meta"])
        return cls(a["s"], a["p"], a["o"], ne, npred)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"TripleStore(triples={self._T}, entities={self.num_entities},"
                f" predicates={self.num_predicates})")
