"""Versioned triple deltas — the placement data-plane's wire format.

The paper's data-localization half (§3.2) keeps pattern-induced subgraphs
G[P] resident at edge servers. The seed reproduction refreshed them by
rebuilding and re-shipping the *entire* induced subgraph whenever residency
changed; edge KG systems (Xu et al., *Knowledge Graph Management on the
Edge*) show that what makes dynamic placement viable under constrained
links is incremental, diff-based maintenance of the edge-resident fragment.
This module is that diff protocol:

**Delta protocol.** A :class:`TripleDelta` carries the *content* difference
between an edge store's current triples and its target residency:

- ``add``   — ``[A, 3]`` int64 ``(s, p, o)`` rows to insert (shipped in
  full from the cloud: 24 modeled bytes per triple);
- ``evict`` — ``[E, 3]`` rows to remove (the edge already holds the
  content, so the wire carries only a per-triple key: 8 modeled bytes);
- ``base_version`` — the store version the delta applies to. Application
  is guarded: applying a delta to any other version raises
  :class:`DeltaVersionError`, so a half-computed rebalance can never land
  on a store that moved underneath it.

Deltas are expressed in triple *content*, not local triple ids — stores
deduplicate and re-sort on every mutation, so content is the only id-stable
coordinate system across versions (cloud-global edge ids are stable too,
and :class:`repro.edge.server.EdgeServer` tracks residency in them; the
delta itself stays self-contained). Application is idempotent per side:
adding an already-present row or evicting an absent one is a no-op, which
is what makes ``apply(delta)`` / ``apply(delta.inverse(v))`` an exact
round-trip (asserted in ``tests/test_rebalance.py``).

Application is ``store.apply_delta(delta)``, in place on either store
kind: :class:`repro.rdf.graph.TripleStore` rebuilds its
arrays/indexes and takes a fresh version token;
:class:`repro.rdf.sharding.ShardedTripleStore` routes the delta's rows to
their owning shards by predicate hash and mutates **only the touched
shards** — untouched shards keep their version tokens, so version-keyed
consumers (the engine's per-shard scan cache, the JAX backend's staged
device arrays) invalidate exactly where data changed.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# modeled wire cost (matches repro.rdf.graph.triples_size_bytes's 3x int64
# row layout): an added triple ships its full row, an evicted one only a key
ADD_WIRE_BYTES = 3 * 8
EVICT_WIRE_BYTES = 8


class DeltaVersionError(RuntimeError):
    """Delta applied to a store whose version moved since computation."""


def as_rows(x: np.ndarray) -> np.ndarray:
    """Normalize to a contiguous ``[N, 3]`` int64 row array."""
    x = np.asarray(x, dtype=np.int64)
    if x.size == 0:
        return np.zeros((0, 3), dtype=np.int64)
    if x.ndim != 2 or x.shape[1] != 3:
        raise ValueError("triple rows must have shape [N, 3]")
    return np.ascontiguousarray(x)


def member_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Boolean mask over ``a``'s rows: which appear in ``b``.

    Bytewise row membership (one void view + sorted ``searchsorted``) —
    the primitive both delete acks and coalesced-commit bookkeeping use.
    """
    a, b = as_rows(a), as_rows(b)
    if len(a) == 0 or len(b) == 0:
        return np.zeros(len(a), dtype=bool)
    void = np.dtype((np.void, a.dtype.itemsize * 3))
    av = np.ascontiguousarray(a).view(void).ravel()
    bv = np.sort(np.ascontiguousarray(b).view(void).ravel())
    pos = np.searchsorted(bv, av)
    pos[pos == len(bv)] = len(bv) - 1
    return bv[pos] == av


def union_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Deduplicated row-set union of two ``[N, 3]`` arrays."""
    a, b = as_rows(a), as_rows(b)
    if len(a) == 0:
        return np.unique(b, axis=0) if len(b) else b
    if len(b) == 0:
        return np.unique(a, axis=0)
    return np.unique(np.concatenate([a, b]), axis=0)


def setdiff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of ``a`` not present in ``b`` (both deduplicated ``[N, 3]``).

    Pure lexicographic set algebra: rows of ``b`` are concatenated first, so
    a unique row whose first occurrence lands in the ``a`` region is in
    ``a`` only.
    """
    a, b = as_rows(a), as_rows(b)
    if len(a) == 0 or len(b) == 0:
        return a
    both = np.concatenate([b, a])
    uniq, first = np.unique(both, axis=0, return_index=True)
    return uniq[first >= len(b)]


@dataclass(frozen=True)
class TripleDelta:
    """Content diff from one store version to a target residency."""

    base_version: object                 # store version this applies to
    add: np.ndarray = field(default_factory=lambda: np.zeros((0, 3),
                                                             dtype=np.int64))
    evict: np.ndarray = field(default_factory=lambda: np.zeros((0, 3),
                                                               dtype=np.int64))

    def __post_init__(self) -> None:
        object.__setattr__(self, "add", as_rows(self.add))
        object.__setattr__(self, "evict", as_rows(self.evict))

    @property
    def n_add(self) -> int:
        return len(self.add)

    @property
    def n_evict(self) -> int:
        return len(self.evict)

    @property
    def is_noop(self) -> bool:
        return not (len(self.add) or len(self.evict))

    @property
    def shipped_bytes(self) -> int:
        """Modeled cloud->edge wire bytes: full rows for adds, keys for
        evicts (the edge already holds evicted content)."""
        return (len(self.add) * ADD_WIRE_BYTES
                + len(self.evict) * EVICT_WIRE_BYTES)

    def inverse(self, base_version) -> "TripleDelta":
        """The delta undoing this one, applicable to ``base_version`` (the
        version the forward application produced)."""
        return TripleDelta(base_version=base_version,
                           add=self.evict, evict=self.add)


def delta_between(store, target_rows: np.ndarray) -> TripleDelta:
    """Delta turning ``store``'s current content into ``target_rows``.

    ``store`` is any :class:`repro.rdf.graph.RDFStore`; ``target_rows`` is
    an ``[N, 3]`` row array (deduplicated internally). The result satisfies
    ``add ∩ current = ∅`` and ``evict ⊆ current``, which is what makes the
    inverse round-trip exact.
    """
    target = as_rows(target_rows)
    target = (np.unique(target, axis=0) if len(target)
              else target.reshape(0, 3))
    current = store.triples()
    return TripleDelta(base_version=store.version,
                       add=setdiff_rows(target, current),
                       evict=setdiff_rows(current, target))


def rows_at(cloud_store, edge_ids: np.ndarray) -> np.ndarray:
    """Cloud triple rows at the given (cloud-global) edge ids."""
    eids = np.unique(np.asarray(edge_ids, dtype=np.int64))
    return np.stack([cloud_store.s[eids], cloud_store.p[eids],
                     cloud_store.o[eids]], axis=1) if len(eids) else \
        np.zeros((0, 3), dtype=np.int64)
