"""Hash-partitioned sharded RDF storage behind the :class:`RDFStore` protocol.

Distributed SPARQL engines scale by partitioning the graph and evaluating as
much of each query as possible locally per partition (Peng et al., VLDB'16;
Naacke et al.'s Spark study) — and a production store quickly outgrows a
single device buffer. :class:`ShardedTripleStore` brings that layout behind
the accessor surface every consumer in this repo already programs against:

- **Partitioning.** Triples are hash-partitioned by predicate into S
  :class:`TripleStore` shards (``shard_of_pred``). All triples of one
  predicate land in one shard, so a bound-predicate candidate scan — the
  common case in real workloads — touches exactly one shard (partition
  pruning); only wildcard-predicate scans fan out across shards.

- **Global triple ids.** Shard k owns the contiguous global id range
  ``[shard_offsets[k], shard_offsets[k+1])``; global = local + offset.
  ``s``/``p``/``o`` are exposed as concatenated global arrays, so the join
  matcher, repeated-variable filters, and ``subgraph`` extraction work
  unchanged on global ids.

- **Composite version.** ``version`` is a tuple over a fresh token plus the
  shard versions, so engine caches keyed on ``store.version`` can never
  confuse a sharded store with any other store (or shard).

The shard-aware *scan* fast paths live in :mod:`repro.sparql.engine`: the
NumPy backend scans shards independently and keeps the per-shard partitions
(``parts()``) separate as :class:`repro.sparql.matcher.CandidateParts`; the
JAX backend stages per-shard device arrays and fuses each shard's
deduplicated batch scans into one ``triple_scan_many`` launch per *touched*
shard. Downstream, the matcher's join pipeline exploits the same layout:
bound-predicate equi-joins probe the owning shard's ``pred_index`` sorted
views shard-locally (the partition-disjointness condition holds trivially —
one predicate lives in exactly one shard), and partial binding tables merge
only at variable-predicate / cross-shard joins.
"""

from __future__ import annotations

import numpy as np

from .graph import PredIndex, TripleStore, _STORE_VERSIONS

# Knuth's multiplicative hash constant — spreads consecutive predicate ids
# (schema order groups correlated predicates) across shards.
_HASH_MULT = 2654435761


def shard_of_pred(pid: int | np.ndarray, num_shards: int):
    """Owning shard of predicate ``pid`` under multiplicative hashing."""
    return (np.asarray(pid, dtype=np.uint64) * _HASH_MULT) % np.uint64(
        num_shards)


class ShardedTripleStore:
    """S predicate-hash-partitioned :class:`TripleStore` shards, one
    :class:`RDFStore`.

    Construction mirrors ``TripleStore(s, p, o, num_entities,
    num_predicates)`` plus ``num_shards``. Duplicate triples share a
    predicate, hence a shard, so shard-local dedup equals global dedup.
    """

    def __init__(self, s: np.ndarray, p: np.ndarray, o: np.ndarray,
                 num_entities: int, num_predicates: int,
                 num_shards: int = 4) -> None:
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        s = np.ascontiguousarray(s, dtype=np.int64)
        p = np.ascontiguousarray(p, dtype=np.int64)
        o = np.ascontiguousarray(o, dtype=np.int64)
        if not (s.shape == p.shape == o.shape) or s.ndim != 1:
            raise ValueError("s, p, o must be 1-D arrays of equal length")
        self.num_entities = int(num_entities)
        self.num_predicates = int(num_predicates)
        self.num_shards = int(num_shards)

        owner = shard_of_pred(p, self.num_shards).astype(np.int64)
        self.shards: list[TripleStore] = [
            TripleStore(s[owner == k], p[owner == k], o[owner == k],
                        self.num_entities, self.num_predicates)
            for k in range(self.num_shards)]
        self._pred_index: dict[int, PredIndex] = {}
        self._rebuild_global_layout()

    def _rebuild_global_layout(self) -> None:
        """(Re)derive the global-id view from the shard list: offsets,
        concatenated arrays, aggregated stats, and a fresh composite
        version. Called at construction and after ``apply_delta`` mutates
        shards in place (global triple ids are ephemeral per version — every
        id-consuming cache is version-keyed)."""
        # global id layout: shard k owns [offsets[k], offsets[k+1])
        sizes = np.asarray([sh.num_triples for sh in self.shards],
                           dtype=np.int64)
        self.shard_offsets = np.concatenate(
            [np.zeros(1, dtype=np.int64), np.cumsum(sizes)])
        self.s = np.concatenate([sh.s for sh in self.shards])
        self.p = np.concatenate([sh.p for sh in self.shards])
        self.o = np.concatenate([sh.o for sh in self.shards])
        self._T = int(sizes.sum())

        # per-predicate stats: each predicate lives in exactly one shard, so
        # elementwise sums aggregate exactly
        self.pred_count = np.sum(
            [sh.pred_count for sh in self.shards], axis=0)
        self.pred_distinct_s = np.sum(
            [sh.pred_distinct_s for sh in self.shards], axis=0)
        self.pred_distinct_o = np.sum(
            [sh.pred_distinct_o for sh in self.shards], axis=0)

        self.version = (next(_STORE_VERSIONS),
                        *(sh.version for sh in self.shards))
        self._pred_index.clear()

    # -- incremental maintenance ----------------------------------------------
    def apply_delta(self, delta):
        """Apply a :class:`repro.rdf.deltas.TripleDelta` per shard, in place.

        Rows are routed to their owning shards by predicate hash; **only
        touched shards** are mutated and take fresh version tokens —
        untouched shards keep theirs, so per-shard version-keyed consumers
        (the engine's bound-predicate scan cache, the JAX backend's staged
        device arrays) stay valid exactly where data did not change. The
        composite version and global-id layout are rebuilt (shard sizes may
        shift every offset after the first touched shard). Returns the new
        composite version.
        """
        from .deltas import DeltaVersionError, TripleDelta
        if delta.base_version != self.version:
            raise DeltaVersionError(
                f"delta targets version {delta.base_version!r}, store is at "
                f"{self.version!r}")
        add_owner = shard_of_pred(delta.add[:, 1],
                                  self.num_shards).astype(np.int64)
        ev_owner = shard_of_pred(delta.evict[:, 1],
                                 self.num_shards).astype(np.int64)
        touched = np.union1d(np.unique(add_owner), np.unique(ev_owner))
        for k in touched:
            sh = self.shards[int(k)]
            sh.apply_delta(TripleDelta(base_version=sh.version,
                                       add=delta.add[add_owner == k],
                                       evict=delta.evict[ev_owner == k]))
        self._rebuild_global_layout()
        return self.version

    # -- sharding-specific accessors -----------------------------------------
    def shard_of_pred(self, pid: int) -> int:
        return int(shard_of_pred(pid, self.num_shards))

    def owning_part(self, pid: int) -> tuple[TripleStore, int]:
        """(owning shard, global-id offset) for predicate ``pid`` — the
        shard-local counterpart of :meth:`pred_index` (same views, ids NOT
        lifted), used by device-resident consumers that stage shard-local
        sorted views and re-lift on the host after the batch fetch."""
        k = self.shard_of_pred(pid)
        return self.shards[k], int(self.shard_offsets[k])

    def parts(self) -> list[tuple[TripleStore, int]]:
        """Non-empty ``(shard, global_id_offset)`` pairs — the candidate
        partitions a wildcard-predicate scan (and the shard-local join
        pipeline downstream of it) fans out over."""
        return [(sh, int(off))
                for sh, off in zip(self.shards, self.shard_offsets)
                if sh.num_triples]

    # -- RDFStore protocol ---------------------------------------------------
    @property
    def num_triples(self) -> int:
        return self._T

    def pred_tids(self, pid: int) -> np.ndarray:
        k = self.shard_of_pred(pid)
        return self.shards[k].pred_tids(pid) + self.shard_offsets[k]

    def pred_index(self, pid: int) -> PredIndex:
        """Owning shard's sorted views, lifted to global triple ids."""
        idx = self._pred_index.get(pid)
        if idx is None:
            k = self.shard_of_pred(pid)
            off = self.shard_offsets[k]
            local = self.shards[k].pred_index(pid)
            idx = PredIndex(
                tids=local.tids + off,
                s_order=local.s_order + off, s_sorted=local.s_sorted,
                o_order=local.o_order + off, o_sorted=local.o_sorted,
            )
            self._pred_index[pid] = idx
        return idx

    def triples(self) -> np.ndarray:
        """[T, 3] int64 array of (s, p, o) in global-id order."""
        return np.stack([self.s, self.p, self.o], axis=1)

    def size_bytes(self) -> int:
        return sum(sh.size_bytes() for sh in self.shards)

    def subgraph(self, edge_ids: np.ndarray) -> "ShardedTripleStore":
        """Induced subgraph by global edge ids; stays sharded with the same
        shard count (shards can end up empty — pruning still applies)."""
        edge_ids = np.unique(np.asarray(edge_ids, dtype=np.int64))
        return ShardedTripleStore(
            self.s[edge_ids], self.p[edge_ids], self.o[edge_ids],
            self.num_entities, self.num_predicates,
            num_shards=self.num_shards)

    # -- (de)serialization ---------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        return {
            "s": self.s, "p": self.p, "o": self.o,
            "meta": np.asarray([self.num_entities, self.num_predicates,
                                self.num_shards]),
        }

    @classmethod
    def from_arrays(cls, a: dict[str, np.ndarray]) -> "ShardedTripleStore":
        ne, npred, ns = (int(x) for x in a["meta"])
        return cls(a["s"], a["p"], a["o"], ne, npred, num_shards=ns)

    @classmethod
    def from_store(cls, store, num_shards: int) -> "ShardedTripleStore":
        """Re-partition any :class:`RDFStore` into ``num_shards`` shards."""
        return cls(store.s, store.p, store.o, store.num_entities,
                   store.num_predicates, num_shards=num_shards)

    def __repr__(self) -> str:  # pragma: no cover
        per = [sh.num_triples for sh in self.shards]
        return (f"ShardedTripleStore(triples={self._T}, shards={per}, "
                f"entities={self.num_entities}, "
                f"predicates={self.num_predicates})")
