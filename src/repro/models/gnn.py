"""GNN family: GCN, PNA, EGNN, NequIP — segment-op message passing.

JAX has no sparse SpMM beyond BCOO; the message-passing primitive here is
gather(src) -> transform -> ``jax.ops.segment_sum``/``segment_max`` (dst),
exactly as the kernel-taxonomy prescribes. The same edge-index layout feeds
the ``segment_mp`` Pallas kernel on TPU (see repro/kernels).

Equivariant models use the **Cartesian tensor basis** for irreps up to l=2
(l=0 scalar, l=1 vector, l=2 symmetric-traceless 3x3). For l<=2 this is an
equivalent change of basis from real spherical harmonics; tensor-product
paths (CG contractions) become dot/cross/symmetric-outer/mat-vec products —
MXU/VPU friendly and exactly E(3)-equivariant (tested by rotating inputs).
See DESIGN.md §Hardware-adaptation.

All models expose ``init_params``, ``forward`` and a scalar ``loss`` so the
runtime's generic train loop / dry-run drivers treat every family uniformly.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.mesh import compat_pvary, compat_shard_map
from .common import AxisRules, constrain, dense_init, key_tree


# ---------------------------------------------------------------------------
# graph batch + segment helpers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str                  # gcn | pna | egnn | nequip
    n_layers: int
    d_hidden: int
    n_classes: int = 16
    d_feat: int = 128
    n_species: int = 16         # equivariant models: atom-type vocabulary
    l_max: int = 2              # nequip
    n_rbf: int = 8              # nequip
    cutoff: float = 5.0         # nequip
    aggregators: tuple[str, ...] = ("mean", "max", "min", "std")  # pna
    scalers: tuple[str, ...] = ("identity", "amplification", "attenuation")


def seg_sum(x, idx, n):
    return jax.ops.segment_sum(x, idx, num_segments=n)


def mp_aggregate(msg, dst, n, rules, op: str = "sum"):
    """Distributed message aggregation (the GNN hot path).

    Vertex-partitioned DistGNN schedule: EDGE arrays are sharded over the
    DP axes, NODE tensors are sharded on the node dim. Each shard
    segment-reduces its local edges into a full [n, D] partial; a
    ``psum_scatter`` over the DP axes combines partials *and* leaves the
    result node-sharded (half the bytes of psum, no replicated outputs).
    GSPMD cannot shard the scatter on its own (it replicates multi-GB
    operands; §Perf iteration G1) — shard_map pins the layout.

    ``op="max"``: pmax has no AD rule, so a custom VJP routes the cotangent
    to the argmax positions (ties receive it jointly — subgradient).
    """
    mesh = rules.mesh
    if mesh is None or not rules.batch:
        if op == "sum":
            return seg_sum(msg, dst, n)
        raw = jax.ops.segment_max(msg, dst, num_segments=n)
        has = seg_sum(jnp.ones((msg.shape[0], 1), msg.dtype), dst, n) > 0
        return jnp.where(has, raw, 0.0)

    from jax.sharding import PartitionSpec as P
    batch = rules.batch
    nsh = 1
    for ax in batch:
        nsh *= mesh.shape[ax]
    assert n % nsh == 0, f"node dim {n} not divisible by {nsh}"

    if op == "sum":
        def body(msg_b, dst_b):
            part = jax.ops.segment_sum(msg_b, dst_b, num_segments=n)
            return jax.lax.psum_scatter(part, batch, scatter_dimension=0,
                                        tiled=True)
        return compat_shard_map(body, mesh=mesh,
                             in_specs=(P(batch, None), P(batch)),
                             out_specs=P(batch, None))(msg, dst)

    def run_max(m, d):
        def body(mb, db):
            part = jax.ops.segment_max(mb, db, num_segments=n)
            full = jax.lax.pmax(part, batch)
            has = jax.lax.psum(jax.ops.segment_sum(
                jnp.ones((mb.shape[0], 1), mb.dtype), db,
                num_segments=n), batch) > 0
            full = jnp.where(has, full, 0.0)
            # keep only this shard's node slice (node-sharded output)
            idx = jnp.int32(0)
            for ax in batch:
                idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
            return jax.lax.dynamic_slice_in_dim(full, idx * (n // nsh),
                                                n // nsh, axis=0)
        return compat_shard_map(body, mesh=mesh,
                             in_specs=(P(batch, None), P(batch)),
                             out_specs=P(batch, None))(m, d)

    @jax.custom_vjp
    def f(m, d):
        return run_max(m, d)

    def fwd(m, d):
        y = run_max(m, d)
        return y, (m, d, y)

    def bwd(res, g):
        m, d, y = res
        dmsg = jnp.where(m == y[d], g[d], 0.0)
        return dmsg, None

    f.defvjp(fwd, bwd)
    return f(msg, dst)


def seg_mean(x, idx, n, eps=1e-9):
    s = seg_sum(x, idx, n)
    cnt = seg_sum(jnp.ones((x.shape[0], 1), x.dtype), idx, n)
    return s / (cnt + eps)


def seg_max(x, idx, n):
    """segment_max with empty segments mapped to 0 (not -inf)."""
    raw = jax.ops.segment_max(x, idx, num_segments=n,
                              indices_are_sorted=False)
    has = jax.ops.segment_sum(jnp.ones((x.shape[0], 1), x.dtype), idx,
                              num_segments=n) > 0
    return jnp.where(has, raw, 0.0)


def seg_min(x, idx, n):
    return -seg_max(-x, idx, n)


def degrees(dst: jnp.ndarray, n: int) -> jnp.ndarray:
    return seg_sum(jnp.ones((dst.shape[0],), jnp.float32), dst, n)


def _mlp(params: list, x: jnp.ndarray, act=jax.nn.silu) -> jnp.ndarray:
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i < len(params) - 1:
            x = act(x)
    return x


def _mlp_init(key, dims: list[int], dtype=jnp.float32) -> list:
    ks = key_tree(key, len(dims) - 1)
    return [(dense_init(k, (dims[i], dims[i + 1]), dtype=dtype),
             jnp.zeros((dims[i + 1],), dtype))
            for i, k in enumerate(ks)]


# ---------------------------------------------------------------------------
# GCN (Kipf & Welling) — sym-normalized SpMM via segments
# ---------------------------------------------------------------------------

def gcn_init(cfg: GNNConfig, key: jax.Array) -> dict:
    ks = key_tree(key, cfg.n_layers)
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {"w": [dense_init(ks[i], (dims[i], dims[i + 1]), dtype=jnp.float32)
                  for i in range(cfg.n_layers)]}


def gcn_forward(cfg: GNNConfig, params: dict, feat: jnp.ndarray,
                edge_index: jnp.ndarray, rules: AxisRules) -> jnp.ndarray:
    """feat [N, F]; edge_index [E, 2] (src, dst). Self-loops added here."""
    n = feat.shape[0]
    src, dst = edge_index[:, 0], edge_index[:, 1]
    ones = jnp.ones((src.shape[0], 1), jnp.float32)
    deg = mp_aggregate(ones, dst, n, rules)[:, 0] + 1.0   # +1 self loop
    inv_sqrt = jax.lax.rsqrt(deg)
    x = feat

    def layer(x, w, last):
        x = x @ w
        msg = x[src] * (inv_sqrt[src] * inv_sqrt[dst])[:, None]
        agg = mp_aggregate(msg, dst, n, rules) \
            + x * (inv_sqrt * inv_sqrt)[:, None]
        return agg if last else jax.nn.relu(agg)

    for i, w in enumerate(params["w"]):
        x = jax.checkpoint(layer, static_argnums=(2,))(
            x, w, i == len(params["w"]) - 1)
    return x


# ---------------------------------------------------------------------------
# PNA (Corso et al.) — multi-aggregator + degree scalers
# ---------------------------------------------------------------------------

def pna_init(cfg: GNNConfig, key: jax.Array) -> dict:
    ks = key_tree(key, 2 + 3 * cfg.n_layers)
    h = cfg.d_hidden
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "msg": _mlp_init(ks[2 + 3 * i], [2 * h, h, h]),
            "post": _mlp_init(ks[3 + 3 * i], [n_agg * h + h, h, h]),
        })
    return {
        "encode": _mlp_init(ks[0], [cfg.d_feat, h]),
        "layers": layers,
        "decode": _mlp_init(ks[1], [h, h, cfg.n_classes]),
    }


def pna_forward(cfg: GNNConfig, params: dict, feat: jnp.ndarray,
                edge_index: jnp.ndarray, rules: AxisRules) -> jnp.ndarray:
    n = feat.shape[0]
    src, dst = edge_index[:, 0], edge_index[:, 1]
    ones = jnp.ones((src.shape[0], 1), jnp.float32)
    cnt = mp_aggregate(ones, dst, n, rules)
    deg = cnt[:, 0]
    safe_cnt = jnp.maximum(cnt, 1.0)
    # PNA degree scalers, delta = mean log(deg+1) over the batch graph
    logd = jnp.log(deg + 1.0)
    delta = jnp.mean(logd) + 1e-9
    scaler_map = {
        "identity": jnp.ones_like(deg),
        "amplification": logd / delta,
        # deg-0 rows aggregate to zero anyway; clamp keeps the scaler finite
        "attenuation": delta / jnp.maximum(logd, np.log(2.0)),
    }
    x = _mlp(params["encode"], feat)

    def layer(x, lp):
        m = _mlp(lp["msg"], jnp.concatenate([x[dst], x[src]], axis=-1))
        mean = mp_aggregate(m, dst, n, rules) / safe_cnt
        aggs = []
        for a in cfg.aggregators:
            if a == "mean":
                aggs.append(mean)
            elif a == "max":
                aggs.append(mp_aggregate(m, dst, n, rules, op="max"))
            elif a == "min":
                aggs.append(-mp_aggregate(-m, dst, n, rules, op="max"))
            elif a == "std":
                sq = mp_aggregate(m * m, dst, n, rules) / safe_cnt
                aggs.append(jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0)
                                     + 1e-9))
        scaled = []
        for s in cfg.scalers:
            for a in aggs:
                scaled.append(a * scaler_map[s][:, None])
        h = jnp.concatenate(scaled + [x], axis=-1)
        return x + _mlp(lp["post"], h)

    for lp in params["layers"]:
        x = jax.checkpoint(layer)(x, lp)
    return _mlp(params["decode"], x)


# ---------------------------------------------------------------------------
# EGNN (Satorras et al.) — E(n)-equivariant, scalar-distance messages
# ---------------------------------------------------------------------------

def egnn_init(cfg: GNNConfig, key: jax.Array) -> dict:
    ks = key_tree(key, 2 + 3 * cfg.n_layers)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        base = 2 + 3 * i
        layers.append({
            "phi_e": _mlp_init(ks[base], [2 * h + 1, h, h]),
            "phi_x": _mlp_init(ks[base + 1], [h, h, 1]),
            "phi_h": _mlp_init(ks[base + 2], [2 * h, h, h]),
        })
    return {
        "embed": dense_init(ks[0], (cfg.n_species, h), dtype=jnp.float32),
        "layers": layers,
        "decode": _mlp_init(ks[1], [h, h, 1]),
    }


def egnn_forward(cfg: GNNConfig, params: dict, species: jnp.ndarray,
                 coords: jnp.ndarray, edge_index: jnp.ndarray,
                 rules: AxisRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """species [N] int, coords [N,3]. Returns (h [N,H], coords' [N,3])."""
    n = coords.shape[0]
    src, dst = edge_index[:, 0], edge_index[:, 1]
    ones = jnp.ones((src.shape[0], 1), jnp.float32)
    safe_cnt = jnp.maximum(mp_aggregate(ones, dst, n, rules), 1.0)
    h = params["embed"][species]
    x = coords

    def layer(h, x, lp):
        rel = x[dst] - x[src]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = _mlp(lp["phi_e"], jnp.concatenate([h[dst], h[src], d2], axis=-1))
        # coordinate update, normalized for stability (EGNN §3.1 variant:
        # unit-ish direction + bounded coefficient keeps |x| from blowing up)
        coef = jnp.tanh(_mlp(lp["phi_x"], m))
        upd = mp_aggregate(rel / (jnp.sqrt(d2) + 1.0) * coef, dst, n, rules)
        x = x + upd / safe_cnt
        # feature update
        magg = mp_aggregate(m, dst, n, rules)
        h = h + _mlp(lp["phi_h"], jnp.concatenate([h, magg], axis=-1))
        return h, x

    for lp in params["layers"]:
        h, x = jax.checkpoint(layer)(h, x, lp)
    return h, x


def egnn_energy(cfg: GNNConfig, params: dict, species, coords, edge_index,
                graph_ids, n_graphs: int, rules: AxisRules) -> jnp.ndarray:
    h, _ = egnn_forward(cfg, params, species, coords, edge_index, rules)
    e_atom = _mlp(params["decode"], h)[:, 0]
    return seg_sum(e_atom, graph_ids, n_graphs)


# ---------------------------------------------------------------------------
# NequIP (Batzner et al.) — E(3)-equivariant tensor products, l_max = 2
# Cartesian irrep basis: l0 [., C], l1 [., C, 3], l2 [., C, 3, 3] (sym-tr.)
# ---------------------------------------------------------------------------

def _sym_traceless(M: jnp.ndarray) -> jnp.ndarray:
    Ms = 0.5 * (M + jnp.swapaxes(M, -1, -2))
    tr = jnp.trace(Ms, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=M.dtype)
    return Ms - tr * eye / 3.0


def _bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP radial basis: sin(n pi r / rc) / r with polynomial cutoff."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rc = cutoff
    rs = jnp.clip(r, 1e-5, rc)
    basis = jnp.sqrt(2.0 / rc) * jnp.sin(n * jnp.pi * rs[..., None] / rc) \
        / rs[..., None]
    u = jnp.clip(r / rc, 0.0, 1.0)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5   # p=3 polynomial
    return basis * env[..., None]


def nequip_init(cfg: GNNConfig, key: jax.Array) -> dict:
    C = cfg.d_hidden
    ks = key_tree(key, 3 + 8 * cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        base = 3 + 8 * i
        layers.append({
            # radial MLP -> per-path, per-channel weights (6 paths, see fwd)
            "radial": _mlp_init(ks[base], [cfg.n_rbf, C, 6 * C]),
            # channel mixers per output l
            "mix0": dense_init(ks[base + 1], (2 * C, C), dtype=jnp.float32),
            "mix1": dense_init(ks[base + 2], (3 * C, C), dtype=jnp.float32),
            "mix2": dense_init(ks[base + 3], (2 * C, C), dtype=jnp.float32),
            # gates: scalars produced from l0 to gate l1/l2
            "gate": _mlp_init(ks[base + 4], [C, 2 * C]),
            "self0": dense_init(ks[base + 5], (C, C), dtype=jnp.float32),
            "self1": dense_init(ks[base + 6], (C, C), dtype=jnp.float32),
            "self2": dense_init(ks[base + 7], (C, C), dtype=jnp.float32),
        })
    return {
        "embed": dense_init(ks[0], (cfg.n_species, C), dtype=jnp.float32),
        "layers": layers,
        "decode": _mlp_init(ks[1], [C, C, 1]),
    }


def _nequip_messages(cfg: GNNConfig, radial_mlp, rbf, Y1, Y2, s0, s1, s2,
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Tensor-product messages for one edge set (chunk-shaped or full).

    CG contractions in Cartesian form:
      p0: l0 x Y0 -> l0        p1: l0 x Y1 -> l1     p2: l0 x Y2 -> l2
      p3: l1 . Y1 -> l0        p4: l1 x Y1 -> l1 (cross)
      p5: l2 @ Y1 -> l1        (+ l1 (x) Y1 -> l2 sym-traceless outer)
    Returns flattened (m0 [E,2C], m1 [E,3C*3], m2 [E,2C*9]).
    """
    C = cfg.d_hidden
    E = rbf.shape[0]
    W = _mlp(radial_mlp, rbf).reshape(-1, 6, C)        # [E, 6 paths, C]
    m0_a = W[:, 0] * s0
    m1_a = W[:, 1][..., None] * (s0[..., None] * Y1[:, None, :])
    m2_a = W[:, 2][..., None, None] * (s0[..., None, None]
                                       * Y2[:, None, :, :])
    m0_b = W[:, 3] * jnp.einsum("eci,ei->ec", s1, Y1)
    m1_b = W[:, 4][..., None] * jnp.cross(s1, Y1[:, None, :])
    m1_c = W[:, 5][..., None] * jnp.einsum("ecij,ej->eci", s2, Y1)
    m2_b = _sym_traceless(s1[..., :, None] * Y1[:, None, None, :])
    m2_b = W[:, 3][..., None, None] * m2_b   # reuse radial ch. (path share)
    m0 = jnp.concatenate([m0_a, m0_b], -1)
    m1 = jnp.concatenate([m1_a, m1_b, m1_c], 1).reshape(E, -1)
    m2 = jnp.concatenate([m2_a, m2_b], 1).reshape(E, -1)
    return m0, m1, m2


def _nequip_aggregate_fused(cfg: GNNConfig, lp, h0, h1, h2, src, dst, rbf,
                            Y1, Y2, n: int, rules: AxisRules,
                            n_chunks: int = 8):
    """Fused, edge-chunked message+aggregate under shard_map (§Perf G2).

    The unfused path materializes [E_local, 2C*9] message tensors (~9 GB on
    ogb_products); here each shard scans its local edges in chunks — remat'd
    chunk bodies recompute messages in backward — and psum_scatters each
    chunk's partial straight onto the node shards, so peak edge state is
    one chunk.
    """
    from jax.sharding import PartitionSpec as P
    mesh, batch = rules.mesh, rules.batch
    C = cfg.d_hidden
    nsh = 1
    for ax in batch:
        nsh *= mesh.shape[ax]
    radial_leaves, radial_def = jax.tree.flatten(lp["radial"])

    def body(h0_l, h1_l, h2_l, src_b, dst_b, rbf_b, Y1_b, Y2_b, *rleaves):
        radial = radial_def.unflatten(list(rleaves))
        h0f = jax.lax.all_gather(h0_l, batch, axis=0, tiled=True)
        h1f = jax.lax.all_gather(h1_l, batch, axis=0, tiled=True)
        h2f = jax.lax.all_gather(h2_l, batch, axis=0, tiled=True)
        E_l = src_b.shape[0]
        bc = -(-E_l // n_chunks)            # ceil; tail masked below

        @jax.checkpoint
        def chunk(carry, i):
            a0, a1, a2 = carry
            start = jnp.maximum(jnp.minimum(i * bc, E_l - bc), 0)  # clamp...
            pos = start + jnp.arange(bc)
            live = (pos < E_l) & (pos >= i * bc)       # ... overlap masked
            sl = lambda a: jax.lax.dynamic_slice_in_dim(   # noqa: E731
                a, start, bc, axis=0)
            sc = jnp.where(live, sl(src_b), 0)
            dc = jnp.where(live, sl(dst_b), 0)
            m0, m1, m2 = _nequip_messages(
                cfg, radial, sl(rbf_b), sl(Y1_b), sl(Y2_b),
                h0f[sc], h1f[sc], h2f[sc])
            lv = live[:, None]
            p0 = jax.ops.segment_sum(jnp.where(lv, m0, 0), dc,
                                     num_segments=n)
            p1 = jax.ops.segment_sum(jnp.where(lv, m1, 0), dc,
                                     num_segments=n)
            p2 = jax.ops.segment_sum(jnp.where(lv, m2, 0), dc,
                                     num_segments=n)
            a0 += jax.lax.psum_scatter(p0, batch, scatter_dimension=0,
                                       tiled=True)
            a1 += jax.lax.psum_scatter(p1, batch, scatter_dimension=0,
                                       tiled=True)
            a2 += jax.lax.psum_scatter(p2, batch, scatter_dimension=0,
                                       tiled=True)
            return (a0, a1, a2), None

        zeros = tuple(
            compat_pvary(jnp.zeros((n // nsh, d), jnp.float32), batch)
            for d in (2 * C, 3 * C * 3, 2 * C * 9))
        (a0, a1, a2), _ = jax.lax.scan(chunk, zeros, jnp.arange(n_chunks))
        return a0, a1, a2

    nsp = P(batch, None)
    rspecs = tuple(P(*([None] * leaf.ndim)) for leaf in radial_leaves)
    a0, a1, a2 = compat_shard_map(
        body, mesh=mesh,
        in_specs=(nsp, P(batch, None, None), P(batch, None, None, None),
                  P(batch), P(batch), nsp, nsp,
                  P(batch, None, None)) + rspecs,
        out_specs=(nsp, nsp, nsp))(
        h0, h1, h2, src, dst, rbf, Y1, Y2, *radial_leaves)
    return (a0, a1.reshape(n, 3 * C, 3), a2.reshape(n, 2 * C, 3, 3))


def nequip_forward(cfg: GNNConfig, params: dict, species: jnp.ndarray,
                   coords: jnp.ndarray, edge_index: jnp.ndarray,
                   rules: AxisRules) -> dict:
    """Returns final irrep features {l0:[N,C], l1:[N,C,3], l2:[N,C,3,3]}."""
    n = coords.shape[0]
    C = cfg.d_hidden
    src, dst = edge_index[:, 0], edge_index[:, 1]
    rel = coords[src] - coords[dst]                    # [E, 3]
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    rhat = rel / r[:, None]
    # spherical harmonics, Cartesian basis
    Y1 = rhat                                          # [E, 3]
    Y2 = _sym_traceless(rhat[:, :, None] * rhat[:, None, :])  # [E, 3, 3]
    rbf = _bessel_rbf(r, cfg.n_rbf, cfg.cutoff)        # [E, n_rbf]

    h0 = params["embed"][species]                      # [N, C]
    h1 = jnp.zeros((n, C, 3), jnp.float32)
    h2 = jnp.zeros((n, C, 3, 3), jnp.float32)
    fused = rules.mesh is not None and bool(rules.batch)

    def layer(h0, h1, h2, lp):
        if fused:
            a0, a1, a2 = _nequip_aggregate_fused(
                cfg, lp, h0, h1, h2, src, dst, rbf, Y1, Y2, n, rules)
        else:
            s0, s1, s2 = h0[src], h1[src], h2[src]
            m0, m1, m2 = _nequip_messages(cfg, lp["radial"], rbf, Y1, Y2,
                                          s0, s1, s2)
            a0 = mp_aggregate(m0, dst, n, rules)
            a1 = mp_aggregate(m1, dst, n, rules).reshape(n, 3 * C, 3)
            a2 = mp_aggregate(m2, dst, n, rules).reshape(n, 2 * C, 3, 3)

        # channel mixing + self-interaction
        n0 = a0 @ lp["mix0"] + h0 @ lp["self0"]
        n1 = jnp.einsum("nkx,kc->ncx",
                        a1.reshape(n, 3 * C, 3), lp["mix1"]) \
            + jnp.einsum("ncx,cd->ndx", h1, lp["self1"])
        n2 = jnp.einsum("nkxy,kc->ncxy",
                        a2.reshape(n, 2 * C, 3, 3), lp["mix2"]) \
            + jnp.einsum("ncxy,cd->ndxy", h2, lp["self2"])

        # gated nonlinearity: scalars via silu; l>0 gated by sigmoids of l0
        gates = _mlp(lp["gate"], n0)
        g1, g2 = jnp.split(jax.nn.sigmoid(gates), 2, axis=-1)
        h0 = h0 + jax.nn.silu(n0)
        h1 = h1 + n1 * g1[..., None]
        h2 = h2 + n2 * g2[..., None, None]
        return h0, h1, h2

    for lp in params["layers"]:
        h0, h1, h2 = jax.checkpoint(layer)(h0, h1, h2, lp)
    return {"l0": h0, "l1": h1, "l2": h2}


def nequip_energy(cfg: GNNConfig, params: dict, species, coords, edge_index,
                  graph_ids, n_graphs: int, rules: AxisRules) -> jnp.ndarray:
    feats = nequip_forward(cfg, params, species, coords, edge_index, rules)
    e_atom = _mlp(params["decode"], feats["l0"])[:, 0]
    return seg_sum(e_atom, graph_ids, n_graphs)


# ---------------------------------------------------------------------------
# uniform family API: init / forward / loss
# ---------------------------------------------------------------------------

def gnn_init(cfg: GNNConfig, key: jax.Array) -> dict:
    return {"gcn": gcn_init, "pna": pna_init, "egnn": egnn_init,
            "nequip": nequip_init}[cfg.model](cfg, key)


def gnn_loss(cfg: GNNConfig, params: dict, batch: dict,
             rules: AxisRules) -> tuple[jnp.ndarray, dict]:
    """Family-uniform loss.

    batch keys (invariant models): feat [N,F], edge_index [E,2],
      labels [N] int, label_mask [N] float
    batch keys (equivariant): species [N], coords [N,3], edge_index,
      graph_ids [N], energy [G], (label_mask unused)
    """
    if cfg.model in ("gcn", "pna"):
        fwd = gcn_forward if cfg.model == "gcn" else pna_forward
        logits = fwd(cfg, params, batch["feat"], batch["edge_index"], rules)
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, batch["labels"][:, None],
                                     axis=-1)[:, 0]
        nll = (lse - picked) * batch["label_mask"]
        loss = nll.sum() / jnp.maximum(batch["label_mask"].sum(), 1.0)
        return loss, {"nll": loss}
    energy_fn = egnn_energy if cfg.model == "egnn" else nequip_energy
    n_graphs = batch["energy"].shape[0]
    pred = energy_fn(cfg, params, batch["species"], batch["coords"],
                     batch["edge_index"], batch["graph_ids"], n_graphs, rules)
    loss = jnp.mean((pred - batch["energy"]) ** 2)
    return loss, {"mse": loss}
