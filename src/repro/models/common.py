"""Shared model building blocks (pure JAX, no framework deps).

Parameters are plain pytrees (nested dicts of jnp arrays). Sharding is
expressed with ``jax.sharding.PartitionSpec`` built from :class:`AxisRules`,
applied through ``with_sharding_constraint`` under an ambient mesh — model
code never touches a concrete mesh object, so the same model runs on the
single-pod (data, model) and multi-pod (pod, data, model) production meshes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AxisRules:
    """Logical -> mesh axis mapping.

    batch: axes that shard the batch (data parallel, incl. the pod axis)
    fsdp:  axis that shards parameter rows (fully-sharded data parallel)
    tp:    tensor-parallel axis (heads / ffn / vocab / experts)
    mesh:  optional concrete Mesh — required only by shard_map code paths
           (explicit-SPMD MoE dispatch); GSPMD paths work without it.
    """

    batch: tuple[str, ...] = ("data",)
    fsdp: str | None = "data"
    tp: str | None = "model"
    mesh: object = None

    @classmethod
    def for_mesh_axes(cls, axis_names: tuple[str, ...],
                      mesh=None) -> "AxisRules":
        if "pod" in axis_names:
            return cls(batch=("pod", "data"), fsdp="data", tp="model",
                       mesh=mesh)
        return cls(batch=("data",), fsdp="data", tp="model", mesh=mesh)

    @classmethod
    def for_mesh(cls, mesh) -> "AxisRules":
        return cls.for_mesh_axes(tuple(mesh.axis_names), mesh=mesh)


def constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint under the ambient mesh; no-op outside jit."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. plain CPU tests)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6, offset: float = 0.0) -> jnp.ndarray:
    """RMSNorm in fp32, cast back to input dtype. Gemma uses (1+scale)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (offset + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embeddings. x: [..., S, H, D_head], positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,half]
    cos = jnp.cos(angles)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: tuple[int, ...],
               in_axis: int = -2, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Truncated-normal fan-in init (LeCun-ish), bf16 storage."""
    fan_in = shape[in_axis]
    std = (1.0 / fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.bfloat16) -> jnp.ndarray:
    """1/sqrt(d) embeddings: tied-logit variance O(1); pairs with the
    sqrt(d) embedding rescale Gemma-style models apply in forward."""
    std = shape[-1] ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def key_tree(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))
