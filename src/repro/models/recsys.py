"""Wide & Deep recommender (Cheng et al. '16) with a manual EmbeddingBag.

JAX has no native ``nn.EmbeddingBag``; the lookup here is the FBGEMM-style
*unified table*: all 40 sparse fields share one [F * V, D] table and ids are
offset by field (one big gather + masked bag-reduce instead of 40 small
ones). The gather is the hot path — on TPU the embedding table is row-sharded
over the 'model' axis (the classic table-sharding / all-to-all pattern), and
``repro/kernels/embedding_bag.py`` provides the Pallas kernel.

Four serving shapes are first-class:
  train_batch (65k BCE training), serve_p99 (512), serve_bulk (262k),
  retrieval_cand (1 query x 1,000,000 candidates: user-tower embedding dotted
  against a sharded candidate matrix + global top-k — batched GEMV, no loop).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .common import AxisRules, constrain, dense_init, key_tree


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int = 40           # categorical fields
    vocab_per_field: int = 1_000_000
    embed_dim: int = 32
    n_dense: int = 13
    nnz_per_field: int = 4       # multi-hot entries per field
    mlp_dims: tuple[int, ...] = (1024, 512, 256)
    n_candidates: int = 1_000_000
    retrieval_dim: int = 256

    @property
    def unified_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def param_count(self) -> int:
        emb = self.unified_rows * self.embed_dim
        wide = self.unified_rows + self.n_dense
        d_in = self.n_sparse * self.embed_dim + self.n_dense
        deep = 0
        dims = (d_in,) + self.mlp_dims
        for i in range(len(dims) - 1):
            deep += dims[i] * dims[i + 1] + dims[i + 1]
        retr = self.n_candidates * self.retrieval_dim
        return emb + wide + deep + self.mlp_dims[-1] + 1 + retr


def init_recsys_params(cfg: RecsysConfig, key: jax.Array,
                       dtype=jnp.float32) -> dict:
    ks = key_tree(key, 6 + len(cfg.mlp_dims))
    d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dims = (d_in,) + cfg.mlp_dims
    mlp = []
    for i in range(len(dims) - 1):
        mlp.append({
            "w": dense_init(ks[2 + i], (dims[i], dims[i + 1]), dtype=dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    std = cfg.embed_dim ** -0.5
    return {
        "embed": (jax.random.normal(ks[0], (cfg.unified_rows, cfg.embed_dim),
                                    jnp.float32) * std).astype(dtype),
        "wide": (jax.random.normal(ks[1], (cfg.unified_rows,), jnp.float32)
                 * 0.01).astype(dtype),
        "wide_dense": jnp.zeros((cfg.n_dense,), dtype),
        "mlp": mlp,
        "head": dense_init(ks[-2], (cfg.mlp_dims[-1], 1), dtype=dtype),
        "bias": jnp.zeros((), dtype),
        "candidates": (jax.random.normal(
            ks[-1], (cfg.n_candidates, cfg.retrieval_dim), jnp.float32)
            * cfg.retrieval_dim ** -0.5).astype(dtype),
    }


def recsys_param_shardings(cfg: RecsysConfig, rules: AxisRules) -> dict:
    """Row-shard the big tables over TP; replicate the small MLP."""
    from jax.sharding import PartitionSpec as P
    tp, fs = rules.tp, rules.fsdp
    return {
        "embed": P(tp, None),
        "wide": P(tp),
        "wide_dense": P(None),
        # the deep MLP is ~2M params — replicate (first dim 40*32+13=1293
        # is not tileable anyway)
        "mlp": [{"w": P(None, None), "b": P(None)} for _ in cfg.mlp_dims],
        "head": P(None, None),
        "bias": P(),
        "candidates": P(tp, None),
    }


# ---------------------------------------------------------------------------
# EmbeddingBag: unified-table gather + masked mean over the bag
# ---------------------------------------------------------------------------

def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray,
                  vocab_per_field: int, combiner: str = "mean",
                  ) -> jnp.ndarray:
    """ids [B, F, NNZ] per-field local ids; mask [B, F, NNZ] in {0,1}.

    Returns [B, F, D]. Offsetting folds all fields into one gather.
    """
    B, F, NNZ = ids.shape
    offsets = (jnp.arange(F, dtype=ids.dtype) * vocab_per_field)[None, :, None]
    flat = (ids + offsets).reshape(-1)
    emb = table[flat].reshape(B, F, NNZ, -1)
    emb = emb * mask[..., None].astype(emb.dtype)
    s = emb.sum(axis=2)
    if combiner == "sum":
        return s
    cnt = jnp.maximum(mask.sum(axis=2), 1.0)[..., None].astype(emb.dtype)
    return s / cnt


def wide_deep_logits(cfg: RecsysConfig, params: dict, batch: dict,
                     rules: AxisRules) -> jnp.ndarray:
    """batch: ids [B,F,NNZ] int32, id_mask [B,F,NNZ], dense [B, n_dense]."""
    ids, mask, dense = batch["ids"], batch["id_mask"], batch["dense"]
    B, F, NNZ = ids.shape
    bags = embedding_bag(params["embed"], ids, mask, cfg.vocab_per_field)
    bags = constrain(bags, rules.batch, None, None)

    # wide: per-id scalar weights, bag-summed + dense linear
    offsets = (jnp.arange(F, dtype=ids.dtype)
               * cfg.vocab_per_field)[None, :, None]
    wide_vals = params["wide"][(ids + offsets).reshape(-1)].reshape(B, F, NNZ)
    wide = (wide_vals * mask.astype(wide_vals.dtype)).sum(axis=(1, 2))
    wide = wide + dense.astype(wide_vals.dtype) @ params["wide_dense"]

    # deep: concat(field bags, dense) -> MLP (interaction=concat)
    x = jnp.concatenate(
        [bags.reshape(B, F * cfg.embed_dim), dense.astype(bags.dtype)],
        axis=-1)
    for layer in params["mlp"]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
        x = constrain(x, rules.batch, None)
    deep = (x @ params["head"])[:, 0]
    return wide + deep + params["bias"]


def recsys_loss(cfg: RecsysConfig, params: dict, batch: dict,
                rules: AxisRules) -> tuple[jnp.ndarray, dict]:
    logits = wide_deep_logits(cfg, params, batch, rules).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"bce": loss, "acc": acc}


def recsys_score(cfg: RecsysConfig, params: dict, batch: dict,
                 rules: AxisRules) -> jnp.ndarray:
    """Online/offline scoring path (serve_p99 / serve_bulk)."""
    return jax.nn.sigmoid(wide_deep_logits(cfg, params, batch, rules))


def retrieval_topk(cfg: RecsysConfig, params: dict, batch: dict,
                   rules: AxisRules, k: int = 100,
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Score 1 query against n_candidates via the user tower; global top-k.

    The candidate matrix is row-sharded over TP; the dot product and top-k
    lower to a sharded GEMV + cross-shard top-k reduction (no host loop).
    """
    ids, mask, dense = batch["ids"], batch["id_mask"], batch["dense"]
    B = ids.shape[0]
    bags = embedding_bag(params["embed"], ids, mask, cfg.vocab_per_field)
    x = jnp.concatenate(
        [bags.reshape(B, cfg.n_sparse * cfg.embed_dim),
         dense.astype(bags.dtype)], axis=-1)
    for layer in params["mlp"]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    # user tower output = last MLP layer (retrieval_dim)
    scores = x @ params["candidates"].T          # [B, n_candidates]
    scores = constrain(scores, rules.batch, rules.tp)
    return jax.lax.top_k(scores, k)
