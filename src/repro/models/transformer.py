"""Decoder-only transformer LM family (dense, MoE, local/global hybrid).

Covers the five assigned LM architectures through one config:

- qwen3-0.6b / qwen3-1.7b : dense, GQA, per-head qk RMSNorm, SwiGLU
- gemma2-2b               : GQA, alternating local(window)/global attention,
                            attn + final logit softcaps, GeGLU, sandwich norm
- phi3.5-moe-42b          : 16-expert top-2 MoE FFN
- granite-moe-1b          : 32-expert top-8 MoE FFN (tiny per-expert d_ff)

Implementation notes (distribution-minded; see DESIGN.md §5):

- layers run under ``lax.scan`` with stacked [L, ...] params and ``remat``
  on the body — small HLO, low compile time, activation memory O(√L)-style;
- training attention is **query-chunked** (exact softmax over full rows,
  computed per q-chunk via scan) so prefill at 32k never materializes the
  [S, S] score matrix;
- decode attends against a KV cache with masked positions — O(S) per token,
  which also serves ``long_500k`` (B=1, 512k cache) on a sequence-sharded
  cache;
- MoE dispatch is sort-based with per-expert capacity (MegaBlocks-flavoured,
  no [T, E, C] one-hot tensor): top-k -> argsort by expert -> rank-in-expert
  -> scatter into an [E*C, D] buffer -> batched expert GEMMs -> weighted
  combine. Load-balance aux loss included (Switch-style).

Sharding: FSDP over the d_model ("data" axis) + TP over heads/ffn/vocab/
experts ("model" axis); batch over ("pod", "data"). Expressed as
PartitionSpec constraints only — the same code compiles on any mesh.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.mesh import compat_shard_map
from .common import (ACTIVATIONS, AxisRules, constrain, dense_init,
                     embed_init, key_tree, rms_norm, rope, softcap)

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    # MoE
    n_experts: int = 0                  # 0 == dense FFN
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # attention flavor
    attn_pattern: str = "global"        # "global" | "local_global"
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    sandwich_norm: bool = False         # gemma2 pre+post norms
    scale_embed: bool = False           # gemma2 sqrt(d_model) embed scaling
    rope_theta: float = 10_000.0
    act: str = "silu"
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 256
    q_chunk: int = 512

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window (0 == global causal)."""
        if self.attn_pattern == "local_global":
            # gemma2: even layers local sliding-window, odd layers global
            return np.array([self.window if i % 2 == 0 else 0
                             for i in range(self.n_layers)], dtype=np.int32)
        return np.zeros(self.n_layers, dtype=np.int32)

    def param_count(self) -> int:
        """Exact parameter count (excl. vocab padding)."""
        d, dh = self.d_model, self.d_head
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
            + self.n_heads * dh * d
        if self.moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        norms = d * (4 if self.sandwich_norm else 2)
        if self.qk_norm:
            norms += 2 * dh
        per_layer = attn + ffn + norms
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() \
            - self.n_layers * self.n_experts * 3 * d * self.d_ff
        return dense_like + self.n_layers * self.top_k * 3 * d * self.d_ff


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_lm_params(cfg: LMConfig, key: jax.Array,
                   dtype=jnp.bfloat16) -> dict:
    L, d, dh = cfg.n_layers, cfg.d_model, cfg.d_head
    H, Kh, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ks = key_tree(key, 12)

    def stack(initfn, shape, k):
        keys = jax.random.split(k, L)
        return jnp.stack([initfn(kk, shape, dtype=dtype) for kk in keys])

    p: dict = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, d), dtype=dtype),
        "final_norm": jnp.ones((d,), dtype=jnp.float32),
        "layers": {
            "wq": stack(dense_init, (d, H * dh), ks[1]),
            "wk": stack(dense_init, (d, Kh * dh), ks[2]),
            "wv": stack(dense_init, (d, Kh * dh), ks[3]),
            "wo": stack(dense_init, (H * dh, d), ks[4]),
            "ln_attn": jnp.ones((L, d), dtype=jnp.float32),
            "ln_mlp": jnp.ones((L, d), dtype=jnp.float32),
        },
    }
    lay = p["layers"]
    if cfg.sandwich_norm:
        lay["ln_attn_post"] = jnp.ones((L, d), dtype=jnp.float32)
        lay["ln_mlp_post"] = jnp.ones((L, d), dtype=jnp.float32)
    if cfg.qk_norm:
        lay["q_norm"] = jnp.ones((L, dh), dtype=jnp.float32)
        lay["k_norm"] = jnp.ones((L, dh), dtype=jnp.float32)
    if cfg.moe:
        E = cfg.n_experts
        lay["router"] = stack(dense_init, (d, E), ks[5]).astype(jnp.float32)
        lay["wi_gate"] = stack(dense_init, (E, d, F), ks[6])
        lay["wi_up"] = stack(dense_init, (E, d, F), ks[7])
        lay["wo_ffn"] = stack(dense_init, (E, F, d), ks[8])
    else:
        lay["wi_gate"] = stack(dense_init, (d, F), ks[6])
        lay["wi_up"] = stack(dense_init, (d, F), ks[7])
        lay["wo_ffn"] = stack(dense_init, (F, d), ks[8])
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[9], (d, cfg.padded_vocab), dtype=dtype)
    return p


def param_shardings(cfg: LMConfig, rules: AxisRules) -> dict:
    """PartitionSpec tree matching init_lm_params (FSDP + TP)."""
    from jax.sharding import PartitionSpec as P
    fs, tp = rules.fsdp, rules.tp
    lay = {
        "wq": P(None, fs, tp),
        "wk": P(None, fs, None),       # kv heads < tp degree: replicate
        "wv": P(None, fs, None),
        "wo": P(None, tp, fs),
        "ln_attn": P(None, None),
        "ln_mlp": P(None, None),
    }
    if cfg.sandwich_norm:
        lay["ln_attn_post"] = P(None, None)
        lay["ln_mlp_post"] = P(None, None)
    if cfg.qk_norm:
        lay["q_norm"] = P(None, None)
        lay["k_norm"] = P(None, None)
    if cfg.moe:
        lay["router"] = P(None, fs, None)
        lay["wi_gate"] = P(None, tp, fs, None)   # experts over TP
        lay["wi_up"] = P(None, tp, fs, None)
        lay["wo_ffn"] = P(None, tp, None, fs)
    else:
        lay["wi_gate"] = P(None, fs, tp)
        lay["wi_up"] = P(None, fs, tp)
        lay["wo_ffn"] = P(None, tp, fs)
    p = {"embed": P(tp, fs), "final_norm": P(None), "layers": lay}
    if not cfg.tie_embeddings:
        p["lm_head"] = P(fs, tp)
    return p


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_logits(logits: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 window) -> jnp.ndarray:
    """Causal + optional sliding-window mask. window==0 -> global."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        in_win = k_pos[None, :] > (q_pos[:, None] - window)
        use_win = window > 0
        causal = causal & (in_win | jnp.logical_not(use_win))
    return jnp.where(causal[None, None, None, :, :], logits, NEG_INF)


def chunked_causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             window, attn_softcap: float | None,
                             q_chunk: int, rules: AxisRules) -> jnp.ndarray:
    """Exact causal attention, scanned over query chunks.

    q: [B,S,H,dh], k/v: [B,S,Kh,dh]; window is a traced int32 scalar
    (0 == global) so local/global layers share one compiled body.
    """
    B, S, H, dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    scale = dh ** -0.5
    qr = q.reshape(B, S, Kh, G, dh)
    if S <= q_chunk:
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, attn_softcap)
        pos = jnp.arange(S)
        logits = _mask_logits(logits, pos, pos, window)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return out.reshape(B, S, H, dh)

    n_chunks = S // q_chunk
    assert S % q_chunk == 0, "sequence must be divisible by q_chunk"
    k_pos = jnp.arange(S)

    # flash-style memory behavior: remat the chunk body so backward
    # recomputes the [bq, S] probs per chunk instead of saving all of them
    @jax.checkpoint
    def body(_, idx):
        qc = jax.lax.dynamic_slice_in_dim(qr, idx * q_chunk, q_chunk, axis=1)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, attn_softcap)
        q_pos = idx * q_chunk + jnp.arange(q_chunk)
        logits = _mask_logits(logits, q_pos, k_pos, window)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return None, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # [n_chunks, B, q_chunk, Kh, G, dh] -> [B, S, H, dh]
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, S, Kh, G, dh)
    return outs.reshape(B, S, H, dh)


def cache_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, pos: jnp.ndarray, window,
                    attn_softcap: float | None) -> jnp.ndarray:
    """Decode attention: q [B,1,H,dh] vs cache [B,Smax,Kh,dh]; O(Smax)."""
    B, Q, H, dh = q.shape
    Kh = k_cache.shape[2]
    G = H // Kh
    scale = dh ** -0.5
    qr = q.reshape(B, Q, Kh, G, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k_cache,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, attn_softcap)
    s_pos = jnp.arange(k_cache.shape[1])
    valid = s_pos[None, :] <= pos
    if window is not None:
        in_win = s_pos[None, :] > (pos - window)
        valid = valid & (in_win | jnp.logical_not(window > 0))
    logits = jnp.where(valid[None, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, Q, H, dh)


# ---------------------------------------------------------------------------
# FFN: dense GLU + sort-based MoE
# ---------------------------------------------------------------------------

def dense_ffn(cfg: LMConfig, lp: dict, x: jnp.ndarray,
              rules: AxisRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    act = ACTIVATIONS[cfg.act]
    h = act(x @ lp["wi_gate"]) * (x @ lp["wi_up"])
    h = constrain(h, rules.batch, None, rules.tp)
    out = h @ lp["wo_ffn"]
    return out, jnp.zeros((), jnp.float32)


def _moe_core(cfg: LMConfig, router, wi_gate, wi_up, wo_ffn, x, e0,
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Group-local sort-based top-k dispatch for a contiguous expert slice.

    x [G, Tg, D]; router scores ALL E experts; this shard computes only
    experts [e0, e0 + E_local) where E_local = wi_gate.shape[0]. Non-local
    assignments contribute zero — the caller psums over the expert shards.
    Everything here is local array math (sort along the last axis, scatter
    into a per-group capacity buffer, batched expert GEMMs).
    """
    G, Tg, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    El = wi_gate.shape[0]
    act = ACTIVATIONS[cfg.act]

    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)                   # [G, Tg, E]
    weights, ids = jax.lax.top_k(probs, K)                    # [G, Tg, K]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # load-balance aux (Switch): E * sum_e f_e * P_e  (local-token means;
    # callers pmean over the batch shards)
    f_e = jnp.mean(jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32),
                   axis=(0, 1))
    P_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * P_e)

    # per-group, per-expert capacity (multiple of 8 keeps layouts tidy)
    C = int(max(8, np.ceil(Tg * K / E * cfg.capacity_factor / 8) * 8)) \
        if Tg * K >= 8 * E else int(max(1, np.ceil(K * cfg.capacity_factor)))

    A = Tg * K
    flat_ids = ids.reshape(G, A)
    order = jnp.argsort(flat_ids, axis=-1)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    starts = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E)))(sorted_ids)  # [G, E]
    rank = (jnp.arange(A)[None, :]
            - jnp.take_along_axis(starts, sorted_ids, axis=-1))
    local_e = sorted_ids - e0
    keep = (rank < C) & (local_e >= 0) & (local_e < El)
    dest = jnp.where(keep, local_e * C + rank, El * C)        # El*C == drop
    token_of = order // K                                     # [G, A]

    g_idx = jnp.arange(G)[:, None]
    src = jnp.take_along_axis(x, token_of[..., None], axis=1)  # [G, A, D]
    buf = jnp.zeros((G, El * C + 1, D), x.dtype).at[
        g_idx, dest].set(src)[:, :El * C].reshape(G, El, C, D)

    h = act(jnp.einsum("gecd,edf->gecf", buf, wi_gate)) \
        * jnp.einsum("gecd,edf->gecf", buf, wi_up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, wo_ffn)

    flat_out = out_buf.reshape(G, El * C, D)
    gathered = jnp.take_along_axis(
        flat_out, jnp.minimum(dest, El * C - 1)[..., None], axis=1)
    w_sorted = jnp.take_along_axis(weights.reshape(G, A), order,
                                   axis=-1).astype(x.dtype)
    contrib = gathered * (w_sorted * keep)[..., None]
    y = jnp.zeros((G, Tg, D), x.dtype).at[g_idx, token_of].add(contrib)
    return y, aux


def moe_ffn(cfg: LMConfig, lp: dict, x: jnp.ndarray,
            rules: AxisRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via explicit SPMD (shard_map).

    Activations are batch-sharded and replicated over TP; experts live on
    TP ranks (EP). Each rank dispatches its local tokens to its local expert
    slice — all bookkeeping is shard-local — and one ``psum`` over the TP
    axis combines expert outputs (the exact cost of a row-parallel matmul
    all-reduce). FSDP weight shards are all-gathered explicitly.

    GSPMD cannot shard the dispatch scatter/gather well on its own (it
    replicates multi-GB operands — measured in EXPERIMENTS.md §Perf); the
    shard_map formulation pins the memory to the intended layout. Without a
    mesh (CPU tests) the single-shard core runs directly.
    """
    mesh = rules.mesh
    tp = rules.tp
    use_smap = (mesh is not None and tp in tuple(mesh.axis_names)
                and cfg.n_experts % mesh.shape[tp] == 0)
    if not use_smap:
        y, aux = _moe_core(cfg, lp["router"], lp["wi_gate"], lp["wi_up"],
                           lp["wo_ffn"], x, 0)
        return y, aux

    from jax.sharding import PartitionSpec as P
    fsdp, batch = rules.fsdp, rules.batch
    El = cfg.n_experts // mesh.shape[tp]

    def body(router, wig, wiu, wof, xb):
        if fsdp is not None:
            wig = jax.lax.all_gather(wig, fsdp, axis=1, tiled=True)
            wiu = jax.lax.all_gather(wiu, fsdp, axis=1, tiled=True)
            wof = jax.lax.all_gather(wof, fsdp, axis=2, tiled=True)
        e0 = jax.lax.axis_index(tp) * El
        y, aux = _moe_core(cfg, router, wig, wiu, wof, xb, e0)
        y = jax.lax.psum(y, tp)
        aux = jax.lax.pmean(aux, batch)
        return y, aux

    y, aux = compat_shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(tp, fsdp, None), P(tp, fsdp, None),
                  P(tp, None, fsdp), P(batch, None, None)),
        out_specs=(P(batch, None, None), P()),
    )(lp["router"], lp["wi_gate"], lp["wi_up"], lp["wo_ffn"], x)
    return y, aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer(cfg: LMConfig, lp: dict, x: jnp.ndarray, window,
           positions: jnp.ndarray, rules: AxisRules,
           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    H, Kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    h = rms_norm(x, lp["ln_attn"])
    q = (h @ lp["wq"]).reshape(B, S, H, dh)
    k = (h @ lp["wk"]).reshape(B, S, Kh, dh)
    v = (h @ lp["wv"]).reshape(B, S, Kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, rules.batch, None, rules.tp, None)
    attn = chunked_causal_attention(q, k, v, window, cfg.attn_softcap,
                                    cfg.q_chunk, rules)
    attn = (attn.reshape(B, S, H * dh) @ lp["wo"])
    if cfg.sandwich_norm:
        attn = rms_norm(attn, lp["ln_attn_post"])
    x = x + attn
    x = constrain(x, rules.batch, None, None)

    h = rms_norm(x, lp["ln_mlp"])
    ffn = moe_ffn if cfg.moe else dense_ffn
    out, aux = ffn(cfg, lp, h, rules)
    if cfg.sandwich_norm:
        out = rms_norm(out, lp["ln_mlp_post"])
    x = x + out
    return constrain(x, rules.batch, None, None), aux


def lm_forward(cfg: LMConfig, params: dict, tokens: jnp.ndarray,
               rules: AxisRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] -> (logits [B, S, V_padded], aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens]                  # gather, vocab-sharded
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    x = constrain(x, rules.batch, None, None)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, scanned):
        x, aux = carry
        lp, window = scanned
        x, aux_l = _layer(cfg, lp, x, window, positions, rules)
        return (x, aux + aux_l), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], windows))
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = softcap(logits, cfg.final_softcap)
    logits = constrain(logits, rules.batch, None, rules.tp)
    return logits, aux / cfg.n_layers


def lm_loss(cfg: LMConfig, params: dict, tokens: jnp.ndarray,
            rules: AxisRules) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy over [B, S] tokens."""
    logits, aux = lm_forward(cfg, params, tokens, rules)
    labels = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = (lse - picked).mean()
    loss = nll + cfg.aux_loss_weight * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_shardings(cfg: LMConfig, rules: AxisRules, seq_shard: bool = False):
    """KV cache specs [L, B, S, Hkv, dh].

    Batch over the DP axes AND sequence over the TP axis — kv-head counts
    (4-8) cannot fill a 16-way TP axis, but the cache *sequence* can; this
    is what keeps 32k-cache decode under HBM (§Perf iteration D1).
    ``seq_shard`` (B == 1 long-context): all axes go to the sequence dim.
    """
    from jax.sharding import PartitionSpec as P
    if seq_shard:
        axes = (rules.fsdp, rules.tp) if rules.fsdp else (rules.tp,)
        spec = P(None, None, axes, None, None)
    else:
        spec = P(None, rules.batch, rules.tp, None, None)
    return {"k": spec, "v": spec}


def lm_decode_step(cfg: LMConfig, params: dict, cache: dict,
                   tokens: jnp.ndarray, pos: jnp.ndarray,
                   rules: AxisRules) -> tuple[jnp.ndarray, dict]:
    """One decode step. tokens [B, 1]; pos: scalar int32 (current index).

    Returns (logits [B, 1, V], updated cache). The per-layer KV gets written
    at ``pos`` and attention sees positions <= pos.
    """
    B = tokens.shape[0]
    H, Kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows())

    def body(x, scanned):
        lp, window, kc, vc = scanned
        h = rms_norm(x, lp["ln_attn"])
        q = (h @ lp["wq"]).reshape(B, 1, H, dh)
        k = (h @ lp["wk"]).reshape(B, 1, Kh, dh)
        v = (h @ lp["wv"]).reshape(B, 1, Kh, dh)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        attn = cache_attention(q, kc, vc, pos, window, cfg.attn_softcap)
        attn = attn.reshape(B, 1, H * dh) @ lp["wo"]
        if cfg.sandwich_norm:
            attn = rms_norm(attn, lp["ln_attn_post"])
        x = x + attn
        h2 = rms_norm(x, lp["ln_mlp"])
        ffn = moe_ffn if cfg.moe else dense_ffn
        out, _ = ffn(cfg, lp, h2, rules)
        if cfg.sandwich_norm:
            out = rms_norm(out, lp["ln_mlp_post"])
        return x + out, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(x @ head, cfg.final_softcap)
    return logits, {"k": new_k, "v": new_v}


def lm_prefill(cfg: LMConfig, params: dict, tokens: jnp.ndarray,
               rules: AxisRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Prefill pass: logits only (cache fill elided in dry-run shapes)."""
    return lm_forward(cfg, params, tokens, rules)
