"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""

from __future__ import annotations

import jax


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """Compat shim: ``jax.sharding.AxisType`` only exists in newer JAX.

    Older releases (e.g. 0.4.x) neither expose ``AxisType`` nor accept an
    ``axis_types=`` argument — there every axis is implicitly Auto, which is
    exactly what we request on newer JAX, so omitting the kwarg is
    semantics-preserving. Returns ``{"axis_types": (Auto,) * n_axes}`` when
    available, else ``{}``; splat into ``jax.make_mesh``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_compat_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types on any supported JAX version."""
    return jax.make_mesh(shape, axes, **mesh_axis_types_kwargs(len(axes)))


def compat_shard_map(f, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    Call sites in this repo only pass (f, mesh, in_specs, out_specs), which
    both implementations accept with identical semantics.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        # pre-pvary JAX cannot type scan carries that start replicated and
        # become varying (compat_pvary is the identity there), so its
        # replication checker must be off; new JAX keeps full checking
        kwargs.setdefault("check_rep", False)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def compat_pvary(x, axes):
    """``jax.lax.pvary`` where it exists; identity on older JAX.

    ``pvary`` only adjusts replication-typing metadata (varying-axis sets)
    introduced alongside explicit sharding; pre-AxisType JAX has no such
    typing, so the identity is exact there.
    """
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is None:
        return x
    return pvary(x, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_mesh_for_devices(devices: list, model_axis: int = 16,
                          pod_axis: int = 1):
    """Elastic variant: biggest legal mesh for a surviving device list."""
    from ..runtime.fault_tolerance import plan_mesh
    import numpy as np
    shape = plan_mesh(len(devices), model_axis, pod_axis)
    n = int(np.prod(shape))
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
