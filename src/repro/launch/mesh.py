"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for_devices(devices: list, model_axis: int = 16,
                          pod_axis: int = 1):
    """Elastic variant: biggest legal mesh for a surviving device list."""
    from ..runtime.fault_tolerance import plan_mesh
    import numpy as np
    shape = plan_mesh(len(devices), model_axis, pod_axis)
    n = int(np.prod(shape))
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
