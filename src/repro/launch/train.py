"""Training driver: any registered arch, any mesh, full runtime stack.

Wires config -> model -> data pipeline -> AdamW -> train loop with
checkpointing / resume / straggler monitoring. On this CPU container use a
reduced preset (--preset smoke) — the full configs are exercised by the
dry-run; the driver logic is identical either way.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --preset smoke --steps 50 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_spec
from ..models.common import AxisRules
from ..models.gnn import GNNConfig, gnn_init, gnn_loss
from ..models.recsys import RecsysConfig, init_recsys_params, recsys_loss
from ..models.transformer import LMConfig, init_lm_params, lm_loss
from ..optim.adamw import AdamWConfig
from ..runtime.train_loop import TrainLoopConfig, train


def reduce_config(spec):
    """Shrink a full config to smoke scale (same family/topology)."""
    cfg = spec.config
    if spec.family == "lm":
        return dataclasses.replace(
            cfg, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(4, cfg.n_kv_heads), d_head=16,
            d_ff=128 if not cfg.moe else 32, vocab=503,
            n_experts=min(cfg.n_experts, 4),
            top_k=min(cfg.top_k, 2), window=8, q_chunk=64)
    if spec.family == "gnn":
        return dataclasses.replace(cfg, n_layers=min(cfg.n_layers, 2),
                                   d_hidden=16, d_feat=32, n_classes=5)
    return dataclasses.replace(cfg, n_sparse=6, vocab_per_field=1000,
                               embed_dim=8, n_dense=4, mlp_dims=(32, 16),
                               n_candidates=500, retrieval_dim=16)


def make_batch_iter(spec, cfg, batch_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if spec.family == "lm":
        def it():
            while True:
                yield jnp.asarray(rng.integers(0, cfg.vocab,
                                               (batch_size, 128)),
                                  jnp.int32)
        return it()
    if spec.family == "gnn":
        from ..data.graphs import cora_like, molecule_batch
        if cfg.model in ("gcn", "pna"):
            data = cora_like(n_nodes=256, n_edges=1024, d_feat=cfg.d_feat,
                             n_classes=cfg.n_classes, seed=seed)
        else:
            data = molecule_batch(batch=8, n_nodes=12, n_edges=32, seed=seed)
        batch = {k: jnp.asarray(v) for k, v in data.items()}

        def it():
            while True:
                yield batch
        return it()
    from ..data.recsys import recsys_batch

    def it():
        i = 0
        while True:
            b = recsys_batch(batch_size, n_sparse=cfg.n_sparse,
                             vocab=cfg.vocab_per_field, n_dense=cfg.n_dense,
                             seed=seed + i)
            i += 1
            yield {k: jnp.asarray(v) for k, v in b.items()}
    return it()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    cfg = spec.config if args.preset == "full" else reduce_config(spec)
    rules = AxisRules(batch=(), fsdp=None, tp=None)  # single-device default
    key = jax.random.PRNGKey(0)

    if spec.family == "lm":
        params = init_lm_params(cfg, key)
        loss_fn = lambda p, b: lm_loss(cfg, p, b, rules)       # noqa: E731
    elif spec.family == "gnn":
        params = gnn_init(cfg, key)
        loss_fn = lambda p, b: gnn_loss(cfg, p, b, rules)      # noqa: E731
    else:
        params = init_recsys_params(cfg, key)
        loss_fn = lambda p, b: recsys_loss(cfg, p, b, rules)   # noqa: E731

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={n_params:,}")
    result = train(
        loss_fn, params, make_batch_iter(spec, cfg, args.batch),
        AdamWConfig(peak_lr=args.lr, warmup_steps=5,
                    total_steps=args.steps),
        TrainLoopConfig(total_steps=args.steps, log_every=10,
                        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir))
    first = result.history[0]["loss"] if result.history else float("nan")
    last = result.history[-1]["loss"] if result.history else float("nan")
    print(f"[train] done: loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
