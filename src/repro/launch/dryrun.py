import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")  # silence SPMD warnings

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init; the dry-run needs 512 placeholder host devices to build
the production meshes. (Smoke tests and benches see 1 device — this env var
is set here and nowhere else.)

Per cell this driver records:
  - compiled.memory_analysis()  (per-device bytes — proves the cell fits)
  - compiled.cost_analysis()    (per-device HLO FLOPs / bytes accessed)
  - collective bytes parsed from the post-SPMD HLO text
  - the single-layer probe costs for the scan trip-count correction
    (XLA counts a while-loop body once; roofline total = module + (L-1) x
    probe — methodology in EXPERIMENTS.md §Roofline)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results go to artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs.registry import all_cells, build_cell, get_spec, skipped_cells
from .mesh import make_production_mesh

# matches "<name> = <shape-or-tuple> <collective-op>(...)" — keyed on the
# OPCODE on the right-hand side, robust to custom instruction names
COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|f64|s64|pred|s16|u16)"
                      r"\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
               "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of collective ops in post-SPMD HLO text."""
    out: dict[str, float] = {}
    n_ops: dict[str, int] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for sm in SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
        n_ops[kind] = n_ops.get(kind, 0) + 1
    return {"bytes_by_kind": out, "ops_by_kind": n_ops,
            "total_bytes": sum(out.values())}


def analyze(lowered, compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_bytes": int(mem.argument_size_in_bytes
                          + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes),
        "collectives": coll,
    }


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}".replace("/", "_")
    path = os.path.join(out_dir, f"{tag}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    spec = get_spec(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                    "mesh_shape": list(mesh.devices.shape)}
    t0 = time.time()
    try:
        with mesh:
            cell = build_cell(spec, shape, mesh)
            lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                              out_shardings=cell.out_shardings
                              ).lower(*cell.abstract_args)
            compiled = lowered.compile()
            record.update(analyze(lowered, compiled))
            record["description"] = cell.description
            record["cost_multiplier"] = cell.cost_multiplier
            if cell.probe is not None:
                pfn, pargs, pshard, repeat = cell.probe
                pl_ = jax.jit(pfn, in_shardings=pshard).lower(*pargs)
                pc = pl_.compile()
                record["probe"] = analyze(pl_, pc)
                record["probe_repeat"] = repeat
            record["ok"] = True
    except Exception as e:  # noqa: BLE001 — record failures as data
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]
    record["seconds"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = "OK " if record.get("ok") else "FAIL"
    print(f"[dryrun] {status} {tag} ({record['seconds']}s)", flush=True)
    if not record.get("ok"):
        print("   ", record["error"], flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(f"{a:26s} {s}")
        for a, s, why in skipped_cells():
            print(f"{a:26s} {s}  SKIPPED: {why}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (all_cells() if args.all
             else [(args.arch, args.shape)])
    n_fail = 0
    for mesh_kind in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mesh_kind, args.out,
                           skip_existing=not args.force)
            n_fail += 0 if rec.get("ok") else 1
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
