"""AdamW + schedules, pure JAX, mixed-precision aware.

Moments are kept in fp32 regardless of parameter dtype (bf16 params get
fp32 updates cast back), with global-norm clipping and decoupled weight
decay. State is a plain pytree -> checkpointing and resharding are trivial.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to end_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.peak_lr * (cfg.end_lr_frac
                         + (1 - cfg.end_lr_frac) * 0.5
                         * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: dict, params
                 ) -> tuple[dict, dict, dict]:
    """Returns (new_params, new_state, info)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    info = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, info
