"""Int8 error-feedback gradient compression for data-parallel all-reduce.

1-bit/int8 SGD-style compression (Seide et al.; Karimireddy et al. EF-SGD):
quantize (grad + residual) to int8 with a per-tensor scale before the DP
all-reduce, keep the quantization error as local residual for the next step.
Cuts DP gradient traffic 4x (fp32) / 2x (bf16) at ~zero quality cost when
error feedback is on.

``compressed_psum`` is written against ``shard_map`` (explicit collectives);
the jit/GSPMD training path uses it through ``make_compressed_grad_reduce``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(grad: jnp.ndarray, residual: jnp.ndarray
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression of one tensor.

    Returns (q_int8, scale, new_residual)."""
    g = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(g)
    new_residual = g - dequantize_int8(q, scale)
    return q, scale, new_residual


def ef_compress_tree(grads, residuals):
    """Tree version. Returns (quantized tree, scales tree, residual tree)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    qs, ss, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = ef_compress(g, r)
        qs.append(q)
        ss.append(s)
        rs.append(nr)
    return (jax.tree.unflatten(tree, qs), jax.tree.unflatten(tree, ss),
            jax.tree.unflatten(tree, rs))


def ef_decompress_tree(qtree, stree):
    return jax.tree.map(dequantize_int8, qtree, stree)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jnp.ndarray, residual: jnp.ndarray, axis_name: str
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: int8-compress locally, all-reduce the dequantized
    int32 sum (wire format int8 + fp32 scale), return mean + new residual."""
    q, scale, new_res = ef_compress(x, residual)
    # all-reduce in integer domain with per-shard scales: sum(q_i * s_i)
    summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return summed / n, new_res
