"""Batched, backend-pluggable BGP query execution engine.

The paper's edge-cloud design (§3, Eq. 5) has every edge server execute a
*stream* of queries against its pattern-induced subgraphs, and the cloud the
rest against G. This module turns the single-query matcher into a serving
engine with three layers:

**1. Backend registry.** :class:`MatcherBackend` abstracts the per-pattern
candidate scan — the hot spot that touches every stored triple. Backends take
any :class:`repro.rdf.graph.RDFStore` (the monolithic :class:`TripleStore` or
:class:`repro.rdf.sharding.ShardedTripleStore`) and are registered by name
(``register_backend``) / constructed via ``get_backend(name)``:

- ``"numpy"`` — :class:`NumpyBackend`, the portable per-predicate-slice path
  (exactly :func:`repro.sparql.matcher._candidates`). On a sharded store it
  scans shards independently and concatenates global triple ids — one shard
  for a bound predicate, a fan-out across shards for wildcard predicates.
- ``"jax"`` — :class:`JaxBackend`, routes scans through the ``triple_scan``
  Pallas kernel (interpret mode on CPU, compiled on TPU). The pattern arrives
  as scalar prefetch, so ONE compiled kernel serves every pattern; batches of
  deduplicated scans go through ``triple_scan_many``. On a sharded store the
  backend stages *per-shard* device arrays and fuses each shard's scans into
  one launch per **touched** shard — a bound-predicate scan streams only the
  owning shard's triples (partition pruning), not the whole store.

Both backends return identical candidate-id *sets* (order may differ), so
join results are identical as solution multisets.

**2. Batching with scan dedup + a cross-round scan LRU.** Candidate scans
are keyed by their *scan key* — the pattern's constant components plus its
repeated-variable equality structure (variable *names* don't matter for the
scan). :meth:`QueryEngine.execute_batch` runs each distinct scan of a batch
once; results additionally land in a byte-bounded LRU keyed
``(store.version, scan key)``, so hot candidate scans survive *between*
batches (``scan_cache_hits`` / ``scan_cache_misses`` in
:class:`EngineStats`). Cached candidate arrays are shared — read-only.

**3. LRU result cache.** Full match results are memoized under the key
``(store.version, pattern-key)`` where *pattern-key* is the query's BGP
canonicalized by renaming variables in first-occurrence order — so
alpha-equivalent queries (same shape, same constants, different variable
names) share an entry, while queries differing in any constant do not.
``store.version`` is a hashable token unique per store instance (a composite
tuple over shard versions for sharded stores); rebalancing deploys a *new*
store, so stale entries can never be served (they age out of the LRU).
Cached arrays are shared between hits — treat :class:`MatchResult` buffers
as read-only.

Semantics: identical to per-query :func:`repro.sparql.matcher.match_bgp` —
solution multisets are equal on every backend and store kind, asserted
against the oracle in ``tests/test_engine.py`` / ``tests/test_sharding.py``.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..rdf.graph import RDFStore
from .matcher import MatchResult, _candidates, match_bgp
from .query import QueryGraph, TriplePattern

# ---------------------------------------------------------------------------
# scan / query keys
# ---------------------------------------------------------------------------


def scan_key(tp: TriplePattern) -> tuple:
    """Identity of a candidate scan: constants + repeated-variable structure.

    Two patterns with the same constants and the same variable-repetition
    shape (e.g. ``(?x p ?x)`` vs ``(?y p ?y)``) select the same triple ids.
    """
    s = tp.s if isinstance(tp.s, int) else None
    p = tp.p if isinstance(tp.p, int) else None
    o = tp.o if isinstance(tp.o, int) else None
    rep_so = isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o
    rep_sp = isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p
    rep_op = isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p
    return (s, p, o, rep_so, rep_sp, rep_op)


def query_key(q: QueryGraph) -> tuple[tuple, dict[str, str]]:
    """(canonical BGP key, canonical->actual variable name map).

    Variables are renamed ``?_0, ?_1, ...`` in first-occurrence order over
    the patterns (s, p, o), so alpha-equivalent BGPs share a key. Projection
    is excluded: a :class:`MatchResult` binds *all* variables.
    """
    ren: dict[str, str] = {}

    def canon(t):
        if isinstance(t, int):
            return t
        if t not in ren:
            ren[t] = f"?_{len(ren)}"
        return ren[t]

    key = tuple((canon(tp.s), canon(tp.p), canon(tp.o)) for tp in q.patterns)
    return key, {v: k for k, v in ren.items()}


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class MatcherBackend:
    """Candidate-scan provider behind :class:`QueryEngine`.

    Contract: ``candidates(store, tp)`` returns exactly the *global* triple
    ids of ``store`` whose constant components match ``tp`` and whose
    repeated variables (if any) are satisfiable — the same *set* NumPy's
    ``_candidates`` yields, in any order. ``store`` is any
    :class:`repro.rdf.graph.RDFStore`; shard-aware backends may exploit a
    sharded store's layout (``store.shards`` / ``store.shard_offsets``).
    """

    name = "abstract"

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        raise NotImplementedError

    def prescan(self, store: RDFStore,
                tps: list[TriplePattern]) -> dict[tuple, np.ndarray]:
        """Scan many deduplicated patterns up front; default: one by one."""
        out: dict[tuple, np.ndarray] = {}
        for tp in tps:
            k = scan_key(tp)
            if k not in out:
                out[k] = self.candidates(store, tp)
        return out


class NumpyBackend(MatcherBackend):
    """Portable path: per-predicate CSR slice + constant masks.

    Sharded stores are scanned shard-by-shard with local ``_candidates``
    calls whose results are lifted to global ids — exactly one shard for a
    bound-predicate pattern, all (non-empty) shards for a wildcard one.
    """

    name = "numpy"

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        shards = getattr(store, "shards", None)
        if shards is None:
            return _candidates(store, tp)
        # A sharded store's global accessors would give the same answer, but
        # scanning shard-locally is the access shape a distributed deployment
        # needs (shards on separate hosts have no global arrays) — keep the
        # fan-out explicit and lift local ids by the shard offset.
        if isinstance(tp.p, int):       # partition pruning: one owning shard
            k = store.shard_of_pred(tp.p)
            return _candidates(shards[k], tp) + store.shard_offsets[k]
        parts = [_candidates(sh, tp) + off
                 for sh, off in zip(shards, store.shard_offsets)
                 if sh.num_triples]
        return (np.concatenate(parts) if parts
                else np.zeros(0, dtype=np.int64))


class JaxBackend(MatcherBackend):
    """Scans via the ``triple_scan`` Pallas kernel.

    [T, 3] triple arrays are staged to the device once per (shard) store
    version; every scan then evaluates a constant/wildcard mask on-device
    (VPU on TPU, interpret mode on CPU) followed by host-side compaction and
    repeated-variable filters. ``bt`` is the stream block size.

    On a :class:`~repro.rdf.sharding.ShardedTripleStore` each shard is staged
    as its own device array, and a scan streams only the shards it can touch:
    the single predicate-owning shard for bound-predicate patterns, every
    non-empty shard for wildcard-predicate ones. ``prescan`` groups a batch's
    deduplicated scans by touched shard and fuses each group through
    ``triple_scan_many`` — one kernel launch per *touched shard*, not per
    pattern.
    """

    name = "jax"

    # device copies of (shard) triple arrays kept alive at once: one engine
    # serves cloud + K edge stores interleaved — and a sharded store stages
    # one array per shard — so a single slot would re-upload [T, 3] arrays
    # on every store switch within a round
    MAX_STAGED_STORES = 16

    def __init__(self, bt: int = 2048, interpret: bool | None = None,
                 max_staged: int | None = None) -> None:
        import jax

        self.bt = int(bt)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.max_staged = int(max_staged if max_staged is not None
                              else self.MAX_STAGED_STORES)
        self._staged: OrderedDict[int, object] = OrderedDict()  # version->arr

    def _triples(self, store, min_slots: int = 1):
        """Device [T, 3] int32 copy of one *flat* store (a shard or a
        monolithic :class:`TripleStore`), LRU-kept by store version.

        ``min_slots`` widens the eviction limit to the number of flat
        arrays the *current* store needs at once, so a sharded store with
        more shards than ``max_staged`` never evicts its own shards
        mid-round (which would re-upload the full store every scan).
        """
        import jax.numpy as jnp

        arr = self._staged.get(store.version)
        if arr is None:
            if max(store.num_entities, store.num_predicates) >= 2 ** 31:
                raise ValueError("dictionary ids exceed int32 kernel range")
            arr = jnp.asarray(store.triples(), dtype=jnp.int32)
            self._staged[store.version] = arr
            limit = max(self.max_staged, min_slots)
            while len(self._staged) > limit:
                self._staged.popitem(last=False)
        else:
            self._staged.move_to_end(store.version)
        return arr

    @staticmethod
    def _store_slots(store: RDFStore) -> int:
        """Flat device arrays ``store`` occupies when fully staged."""
        shards = getattr(store, "shards", None)
        if shards is None:
            return 1
        return max(1, sum(1 for sh in shards if sh.num_triples))

    @staticmethod
    def _scan_parts(store: RDFStore, tp: TriplePattern
                    ) -> list[tuple[object, int]]:
        """(flat store, global offset) pairs a scan for ``tp`` must touch."""
        shards = getattr(store, "shards", None)
        if shards is None:
            return [(store, 0)]
        if isinstance(tp.p, int):       # partition pruning: one owning shard
            k = store.shard_of_pred(tp.p)
            pair = (shards[k], int(store.shard_offsets[k]))
            return [pair] if shards[k].num_triples else []
        return [(sh, int(off))
                for sh, off in zip(shards, store.shard_offsets)
                if sh.num_triples]

    @staticmethod
    def _pattern_vec(tp: TriplePattern) -> np.ndarray:
        return np.asarray(
            [tp.s if isinstance(tp.s, int) else -1,
             tp.p if isinstance(tp.p, int) else -1,
             tp.o if isinstance(tp.o, int) else -1], dtype=np.int32)

    @staticmethod
    def _repeated_var_filter(store: RDFStore, tp: TriplePattern,
                             tids: np.ndarray) -> np.ndarray:
        if isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o:
            tids = tids[store.s[tids] == store.o[tids]]
        if isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p:
            tids = tids[store.s[tids] == store.p[tids]]
        if isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p:
            tids = tids[store.o[tids] == store.p[tids]]
        return tids

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        from ..kernels.triple_scan import triple_scan
        import jax.numpy as jnp

        pat = jnp.asarray(self._pattern_vec(tp))
        slots = self._store_slots(store)
        parts: list[np.ndarray] = []
        for flat, off in self._scan_parts(store, tp):
            mask = triple_scan(self._triples(flat, min_slots=slots), pat,
                               bt=self.bt, interpret=self.interpret)
            parts.append(np.flatnonzero(np.asarray(mask)).astype(np.int64)
                         + off)
        tids = (np.concatenate(parts) if parts
                else np.zeros(0, dtype=np.int64))
        return self._repeated_var_filter(store, tp, tids)

    def prescan(self, store: RDFStore,
                tps: list[TriplePattern]) -> dict[tuple, np.ndarray]:
        from ..kernels.triple_scan import triple_scan_many
        import jax.numpy as jnp

        uniq: dict[tuple, TriplePattern] = {}
        for tp in tps:
            uniq.setdefault(scan_key(tp), tp)
        if not uniq:
            return {}

        # group deduplicated scans by the flat store (shard) they touch;
        # a monolithic store is a single group
        groups: dict[int, tuple[object, int, list[tuple]]] = {}
        for k, tp in uniq.items():
            for flat, off in self._scan_parts(store, tp):
                g = groups.get(id(flat))
                if g is None:
                    g = groups[id(flat)] = (flat, off, [])
                g[2].append(k)

        slots = self._store_slots(store)
        parts: dict[tuple, list[np.ndarray]] = {k: [] for k in uniq}
        for flat, off, keys in groups.values():     # one launch per group
            pats = np.stack([self._pattern_vec(uniq[k]) for k in keys])
            masks = np.asarray(triple_scan_many(
                self._triples(flat, min_slots=slots), jnp.asarray(pats),
                bt=self.bt, interpret=self.interpret))
            for i, k in enumerate(keys):
                parts[k].append(
                    np.flatnonzero(masks[i]).astype(np.int64) + off)
        out: dict[tuple, np.ndarray] = {}
        for k, tp in uniq.items():
            tids = (np.concatenate(parts[k]) if parts[k]
                    else np.zeros(0, dtype=np.int64))
            out[k] = self._repeated_var_filter(store, tp, tids)
        return out


_BACKENDS: dict[str, Callable[..., MatcherBackend]] = {}


def register_backend(name: str,
                     factory: Callable[..., MatcherBackend]) -> None:
    _BACKENDS[name] = factory


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **kw) -> MatcherBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown matcher backend {name!r}; "
                       f"have {available_backends()}")
    return _BACKENDS[name](**kw)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclass
class EngineStats:
    queries: int = 0
    batches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    scans_requested: int = 0
    scans_executed: int = 0
    scan_cache_hits: int = 0
    scan_cache_misses: int = 0
    scan_cache_evictions: int = 0
    exec_seconds: float = 0.0

    @property
    def scans_deduped(self) -> int:
        return self.scans_requested - self.scans_executed


class QueryEngine:
    """Batched BGP executor with scan dedup and an LRU result cache.

    See the module docstring for batching semantics and cache keying.
    ``cache_size`` bounds the number of memoized :class:`MatchResult`s
    (0 disables caching). One engine instance may serve many stores — cache
    keys embed ``store.version``.
    """

    def __init__(self, backend: str | MatcherBackend = "numpy",
                 cache_size: int = 256, max_rows: int = 5_000_000,
                 cache_bytes: int = 512 * 1024 * 1024,
                 scan_cache_bytes: int = 64 * 1024 * 1024,
                 scan_cache_size: int = 4096) -> None:
        self.backend = (backend if isinstance(backend, MatcherBackend)
                        else get_backend(backend))
        self.cache_size = int(cache_size)
        # one result near max_rows can be hundreds of MB of int64 bindings,
        # so the LRU is bounded by bytes as well as entry count
        self.cache_bytes = int(cache_bytes)
        # candidate-scan LRU keyed (store.version, scan key): hot scans
        # survive between batches (scan_cache_bytes=0 disables). The count
        # bound matters independently of the byte bound: empty candidate
        # arrays are 0 bytes, so probe-miss workloads would otherwise grow
        # the dict without limit as store versions churn.
        self.scan_cache_bytes = int(scan_cache_bytes)
        self.scan_cache_size = int(scan_cache_size)
        self.max_rows = int(max_rows)
        self.stats = EngineStats()
        self._cache: OrderedDict[tuple, MatchResult] = OrderedDict()
        self._cached_bytes = 0
        self._scan_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._scan_cached_bytes = 0

    # -- cache ---------------------------------------------------------------
    def clear_cache(self) -> None:
        self._cache.clear()
        self._cached_bytes = 0
        self._scan_cache.clear()
        self._scan_cached_bytes = 0

    def _cache_get(self, key: tuple) -> MatchResult | None:
        res = self._cache.get(key)
        if res is not None:
            self._cache.move_to_end(key)
            self.stats.cache_hits += 1
        else:
            self.stats.cache_misses += 1
        return res

    @staticmethod
    def _result_bytes(res: MatchResult) -> int:
        return int(res.bindings.nbytes + res.edge_ids.nbytes)

    def _cache_put(self, key: tuple, res: MatchResult) -> None:
        if self.cache_size <= 0:
            return
        nbytes = self._result_bytes(res)
        if nbytes > self.cache_bytes:
            return                       # would evict everything; skip
        displaced = self._cache.pop(key, None)
        if displaced is not None:        # overwrite: release the old bytes
            self._cached_bytes -= self._result_bytes(displaced)
        self._cache[key] = res
        self._cached_bytes += nbytes
        while (len(self._cache) > self.cache_size
               or self._cached_bytes > self.cache_bytes):
            _, old = self._cache.popitem(last=False)
            self._cached_bytes -= self._result_bytes(old)
            self.stats.cache_evictions += 1

    # -- scan cache ----------------------------------------------------------
    def _scan_cache_get(self, key: tuple) -> np.ndarray | None:
        arr = self._scan_cache.get(key)
        if arr is not None:
            self._scan_cache.move_to_end(key)
            self.stats.scan_cache_hits += 1
        else:
            self.stats.scan_cache_misses += 1
        return arr

    def _scan_cache_put(self, key: tuple, tids: np.ndarray) -> None:
        if self.scan_cache_bytes <= 0:
            return
        nbytes = int(tids.nbytes)
        if nbytes > self.scan_cache_bytes:
            return
        displaced = self._scan_cache.pop(key, None)
        if displaced is not None:
            self._scan_cached_bytes -= int(displaced.nbytes)
        self._scan_cache[key] = tids
        self._scan_cached_bytes += nbytes
        while (len(self._scan_cache) > self.scan_cache_size
               or self._scan_cached_bytes > self.scan_cache_bytes):
            _, old = self._scan_cache.popitem(last=False)
            self._scan_cached_bytes -= int(old.nbytes)
            self.stats.scan_cache_evictions += 1

    @staticmethod
    def _remap(res: MatchResult, canon_to_actual: dict[str, str]
               ) -> MatchResult:
        """Re-label a cached canonical result with a query's variable names."""
        return MatchResult(
            var_names=[canon_to_actual[v] for v in res.var_names],
            bindings=res.bindings, edge_ids=res.edge_ids)

    # -- execution -----------------------------------------------------------
    def execute(self, store: RDFStore, q: QueryGraph) -> MatchResult:
        return self.execute_batch(store, [q])[0]

    def execute_batch(self, store: RDFStore,
                      queries: list[QueryGraph]) -> list[MatchResult]:
        """Execute ``queries`` against ``store``; results align by index.

        Identical candidate scans run once per batch and are retained in the
        cross-batch scan LRU; alpha-equivalent queries resolve from the
        result cache (within the batch and across calls, until the store
        version changes).
        """
        t0 = time.perf_counter()
        self.stats.batches += 1
        self.stats.queries += len(queries)

        keyed = [query_key(q) for q in queries]
        misses = [i for i, (ck, _) in enumerate(keyed)
                  if (store.version, ck) not in self._cache]

        # scan memo for this batch, seeded from the cross-batch scan LRU;
        # the remaining distinct scan keys execute once via prescan
        memo: dict[tuple, np.ndarray] = {}
        if misses:
            need = [tp for i in misses for tp in queries[i].patterns]
            self.stats.scans_requested += len(need)
            uniq: dict[tuple, TriplePattern] = {}
            for tp in need:
                uniq.setdefault(scan_key(tp), tp)
            fresh: list[TriplePattern] = []
            for k, tp in uniq.items():
                hit = self._scan_cache_get((store.version, k))
                if hit is not None:
                    memo[k] = hit
                else:
                    fresh.append(tp)
            if fresh:
                scanned = self.backend.prescan(store, fresh)
                self.stats.scans_executed += len(scanned)
                memo.update(scanned)
                for k, tids in scanned.items():
                    self._scan_cache_put((store.version, k), tids)

        def scan(st: RDFStore, tp: TriplePattern) -> np.ndarray:
            k = scan_key(tp)
            if k not in memo:          # cache-missed pattern added mid-join
                self.stats.scans_requested += 1
                tids = self._scan_cache_get((st.version, k))
                if tids is None:
                    self.stats.scans_executed += 1
                    tids = self.backend.candidates(st, tp)
                    self._scan_cache_put((st.version, k), tids)
                memo[k] = tids
            return memo[k]

        out: list[MatchResult | None] = [None] * len(queries)
        for i, q in enumerate(queries):
            ck, canon_to_actual = keyed[i]
            cached = self._cache_get((store.version, ck))
            if cached is None:
                # execute under canonical names so the cached entry is
                # independent of this query's variable spelling
                actual_to_canon = {a: c for c, a in canon_to_actual.items()}
                canon_q = QueryGraph(
                    patterns=[TriplePattern(
                        *(actual_to_canon.get(t, t) if isinstance(t, str)
                          else t for t in (tp.s, tp.p, tp.o)))
                        for tp in q.patterns],
                    projection=[])
                cached = match_bgp(store, canon_q, max_rows=self.max_rows,
                                   candidates=scan)
                self._cache_put((store.version, ck), cached)
            out[i] = self._remap(cached, canon_to_actual)
        self.stats.exec_seconds += time.perf_counter() - t0
        return out
