"""Batched, backend-pluggable BGP query execution engine.

The paper's edge-cloud design (§3, Eq. 5) has every edge server execute a
*stream* of queries against its pattern-induced subgraphs, and the cloud the
rest against G. This module turns the single-query matcher into a serving
engine with three layers:

**1. Backend registry.** :class:`MatcherBackend` abstracts the per-pattern
candidate scan — the hot spot that touches every stored triple. Backends take
any :class:`repro.rdf.graph.RDFStore` (the monolithic :class:`TripleStore` or
:class:`repro.rdf.sharding.ShardedTripleStore`) and are registered by name
(``register_backend``) / constructed via ``get_backend(name)``:

- ``"numpy"`` — :class:`NumpyBackend`, the portable per-predicate-slice path
  (exactly :func:`repro.sparql.matcher._candidates`). On a sharded store it
  scans shards independently and concatenates global triple ids — one shard
  for a bound predicate, a fan-out across shards for wildcard predicates.
- ``"jax"`` — :class:`JaxBackend`, routes scans through the ``triple_scan``
  Pallas kernel (interpret mode on CPU, compiled on TPU). The pattern arrives
  as scalar prefetch, so ONE compiled kernel serves every pattern; batches of
  deduplicated scans go through ``triple_scan_many``. On a sharded store the
  backend stages *per-shard* device arrays and fuses each shard's scans into
  one launch per **touched** shard — a bound-predicate scan streams only the
  owning shard's triples (partition pruning), not the whole store.

Both backends return identical candidate-id *sets* (order may differ), so
join results are identical as solution multisets.

**2. Batching with scan dedup + a cross-round scan LRU.** Candidate scans
are keyed by their *scan key* — the pattern's constant components plus its
repeated-variable equality structure (variable *names* don't matter for the
scan). :meth:`QueryEngine.execute_batch` runs each distinct scan of a batch
once; results additionally land in a byte-bounded LRU so hot candidate
scans survive *between* batches (``scan_cache_hits`` /
``scan_cache_misses`` in :class:`EngineStats`). LRU keys are
**version-granular**: a bound-predicate scan on a sharded store keys on the
predicate's OWNING SHARD's version and stores shard-local ids (re-lifted by
the store's current offset at hit time), so a placement delta
(:mod:`repro.rdf.deltas`) mutating other shards invalidates nothing here;
wildcard scans and monolithic stores key on the full store version. Cached
candidate arrays are shared — read-only.

**3. LRU result cache.** Full match results are memoized under the key
``(store.version, pattern-key)`` where *pattern-key* is the query's BGP
canonicalized by renaming variables in first-occurrence order — so
alpha-equivalent queries (same shape, same constants, different variable
names) share an entry, while queries differing in any constant do not.
``store.version`` is a hashable token unique to the store's *contents* (a
composite tuple over shard versions for sharded stores); rebalancing either
deploys a new store or mutates one in place through the delta protocol —
both take fresh version tokens, so stale entries can never be served (they
age out of the LRU).
Cached arrays are shared between hits — treat :class:`MatchResult` buffers
as read-only.

**4. Shard-parallel join pipeline.** Candidate scans are returned as
:class:`repro.sparql.matcher.CandidateParts` — per-shard partitions instead
of one concatenated global id array — and each query executes under a
:func:`repro.sparql.matcher.plan_bgp` plan: bound-predicate equi-joins run
shard-locally (probing the owning shard's presorted ``PredIndex``, no scan
and no per-join sort), and partial binding tables are merged only at
variable-predicate / cross-shard joins. ``shard_local_joins=False`` falls
back to the global scan+sort join (the ``--join`` baseline in
``benchmarks/bench_engine.py``). Per-phase stats land in
:class:`EngineStats`: ``prescan_seconds`` / ``join_seconds`` and the
``join`` :class:`~repro.sparql.matcher.JoinStats` counters.

**5. Device-resident join pipeline (jax backend).** With
``JaxBackend(device_resident=True)`` (the default) and
``shard_local_joins`` on, every cache-missed query that
:func:`repro.sparql.device_join.device_eligible` accepts — bound-predicate
star/path shapes with no repeated variables, whose every non-seed plan
step is a presorted probe — executes entirely on the accelerator: the seed
scan (fused with its first probe via ``scan_probe`` where possible),
on-device compaction, and ``probe_sorted`` Pallas joins over staged
shard-local ``PredIndex`` views. All such queries of a batch share ONE
bulk device->host transfer (``EngineStats.host_transfers``; O(1)-byte
control scalars are counted separately as ``scalar_syncs``). Everything
else — variable predicates, repeated variables, equality-masked closing
joins — transparently falls back to the host pipeline above
(``device_queries`` / ``device_fallbacks`` record the split, and
``JoinStats.joins_device`` marks where each presorted join ran). Force
the host path with ``device_resident=False``; force interpret-mode
kernels off-TPU with ``JaxBackend(interpret=True)`` (the default via
:func:`repro.kernels.default_interpret` — compiled on TPU/GPU, interpret
on CPU; the resolved mode is reported in ``EngineStats.backend_mode``).

**Cache key contracts.**

- *scan key* (:func:`scan_key`): constants + repeated-variable structure
  only — it deliberately ignores variable *spelling*, so ``(?x p ?y)`` and
  ``(?u p ?v)`` share one candidate scan.
- *query key* (:func:`query_key`): the BGP canonicalized by first-occurrence
  variable renaming; the projection is deliberately **excluded** — a cached
  :class:`MatchResult` binds all variables, and projection is applied by the
  caller, so queries differing only in ``SELECT`` lists share an entry.

**Thread safety.** One engine may serve overlapped server batches
(``EdgeCloudSystem.run_round_batched(overlap=True)``) from multiple
threads: the result/scan caches and stats are guarded by an internal lock,
while the NumPy/JAX hot paths run outside it (they release the GIL on
large arrays, which is what makes overlapped rounds pay off).

Semantics: identical to per-query :func:`repro.sparql.matcher.match_bgp` —
solution multisets are equal on every backend and store kind, asserted
against the oracle in ``tests/test_engine.py`` / ``tests/test_sharding.py``
/ ``tests/test_join_pipeline.py``.

**Layering.** This engine executes BGPs only. The SPARQL algebra layer
(:mod:`repro.sparql.algebra`, surfaced by
:class:`repro.sparql.endpoint.SparqlEndpoint`) sits on top: operator trees
whose BGP leaves are batched through :meth:`QueryEngine.execute_batch`, so
every cache and backend here serves full SELECT/ASK queries unchanged.
``QueryEngine.execute(QueryGraph)`` remains the thin BGP-subset shim.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..rdf.graph import RDFStore
from .device_join import DeviceBatch, device_eligible
from .matcher import (CandidateParts, JoinStats, MatchResult, _candidates,
                      match_bgp, plan_bgp)
from .query import QueryGraph, TriplePattern

# ---------------------------------------------------------------------------
# scan / query keys
# ---------------------------------------------------------------------------


def scan_key(tp: TriplePattern) -> tuple:
    """Identity of a candidate scan: constants + repeated-variable structure.

    Two patterns with the same constants and the same variable-repetition
    shape (e.g. ``(?x p ?x)`` vs ``(?y p ?y)``) select the same triple ids.
    """
    s = tp.s if isinstance(tp.s, int) else None
    p = tp.p if isinstance(tp.p, int) else None
    o = tp.o if isinstance(tp.o, int) else None
    rep_so = isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o
    rep_sp = isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p
    rep_op = isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p
    return (s, p, o, rep_so, rep_sp, rep_op)


def query_key(q: QueryGraph) -> tuple[tuple, dict[str, str]]:
    """(canonical BGP key, canonical->actual variable name map).

    Variables are renamed ``?_0, ?_1, ...`` in first-occurrence order over
    the patterns (s, p, o), so alpha-equivalent BGPs share a key. Projection
    is excluded: a :class:`MatchResult` binds *all* variables.
    """
    ren: dict[str, str] = {}

    def canon(t):
        if isinstance(t, int):
            return t
        if t not in ren:
            ren[t] = f"?_{len(ren)}"
        return ren[t]

    key = tuple((canon(tp.s), canon(tp.p), canon(tp.o)) for tp in q.patterns)
    return key, {v: k for k, v in ren.items()}


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class MatcherBackend:
    """Candidate-scan provider behind :class:`QueryEngine`.

    Contract: ``candidates(store, tp)`` returns exactly the *global* triple
    ids of ``store`` whose constant components match ``tp`` and whose
    repeated variables (if any) are satisfiable — the same *set* NumPy's
    ``_candidates`` yields, in any order. ``store`` is any
    :class:`repro.rdf.graph.RDFStore`; shard-aware backends may exploit a
    sharded store's layout (``store.shards`` / ``store.shard_offsets``).
    """

    name = "abstract"

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        raise NotImplementedError

    def candidate_parts(self, store: RDFStore,
                        tp: TriplePattern) -> CandidateParts:
        """Partitioned scan: per-shard global-id arrays (default: one part).

        Shard-aware backends override this so the matcher can join each
        partition shard-locally and merge partial binding tables only at
        variable-predicate / cross-shard joins.
        """
        return CandidateParts([self.candidates(store, tp)])

    def prescan_parts(self, store: RDFStore, tps: list[TriplePattern],
                      ) -> dict[tuple, CandidateParts]:
        """Partitioned scan of many deduplicated patterns up front."""
        out: dict[tuple, CandidateParts] = {}
        for tp in tps:
            k = scan_key(tp)
            if k not in out:
                out[k] = self.candidate_parts(store, tp)
        return out

    def prescan(self, store: RDFStore,
                tps: list[TriplePattern]) -> dict[tuple, np.ndarray]:
        """Scan many deduplicated patterns up front (concatenated ids)."""
        return {k: parts.concat()
                for k, parts in self.prescan_parts(store, tps).items()}


class NumpyBackend(MatcherBackend):
    """Portable path: per-predicate CSR slice + constant masks.

    Sharded stores are scanned shard-by-shard with local ``_candidates``
    calls whose results are lifted to global ids — exactly one shard for a
    bound-predicate pattern, all (non-empty) shards for a wildcard one.
    """

    name = "numpy"

    def candidate_parts(self, store: RDFStore,
                        tp: TriplePattern) -> CandidateParts:
        shards = getattr(store, "shards", None)
        if shards is None:
            return CandidateParts([_candidates(store, tp)])
        # A sharded store's global accessors would give the same answer, but
        # scanning shard-locally is the access shape a distributed deployment
        # needs (shards on separate hosts have no global arrays) — keep the
        # fan-out explicit and lift local ids by the shard offset. The parts
        # stay separate so the join can run shard-locally as well.
        if isinstance(tp.p, int):       # partition pruning: one owning shard
            k = store.shard_of_pred(tp.p)
            return CandidateParts(
                [_candidates(shards[k], tp) + store.shard_offsets[k]])
        return CandidateParts([_candidates(sh, tp) + off
                               for sh, off in store.parts()])

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        return self.candidate_parts(store, tp).concat()


class JaxBackend(MatcherBackend):
    """Scans via the ``triple_scan`` Pallas kernel, joins optionally
    device-resident via the ``probe_sorted`` / ``scan_probe`` kernels.

    [T, 3] triple arrays are staged to the device once per (shard) store
    version; every scan then evaluates a constant/wildcard mask on-device
    (VPU on TPU, interpret mode on CPU — resolved by
    :func:`repro.kernels.default_interpret` unless ``interpret`` is forced)
    followed by compaction and repeated-variable filters. ``bt`` is the
    stream block size.

    On a :class:`~repro.rdf.sharding.ShardedTripleStore` each shard is staged
    as its own device array, and a scan streams only the shards it can touch:
    the single predicate-owning shard for bound-predicate patterns, every
    non-empty shard for wildcard-predicate ones. ``prescan`` groups a batch's
    deduplicated scans by touched shard and fuses each group through
    ``triple_scan_many`` — one kernel launch per *touched shard*, not per
    pattern — then materializes every group's masks in ONE bulk
    device->host transfer.

    ``device_resident=True`` (default) additionally lets the engine run
    device-eligible queries fully on the accelerator through
    :mod:`repro.sparql.device_join` — shard-local ``pred_index`` sorted
    views get their own staged-on-device LRU keyed by (shard version,
    predicate), so a placement delta invalidates only touched shards'
    views. ``host_transfers`` / ``host_transfer_bytes`` count bulk
    device->host array materializations; ``scalar_syncs`` counts the O(1)
    control scalars (row counts) host-driven allocation needs — see the
    :mod:`~repro.sparql.device_join` docstring for the accounting contract.
    """

    name = "jax"

    # device copies of (shard) triple arrays kept alive at once: one engine
    # serves cloud + K edge stores interleaved — and a sharded store stages
    # one array per shard — so a single slot would re-upload [T, 3] arrays
    # on every store switch within a round
    MAX_STAGED_STORES = 16
    # staged (shard version, predicate) sorted-view tuples for the device
    # join path; four small int32 arrays per hot predicate
    MAX_STAGED_VIEWS = 256

    def __init__(self, bt: int = 2048, interpret: bool | None = None,
                 max_staged: int | None = None,
                 device_resident: bool = True) -> None:
        from ..kernels import default_interpret

        self.bt = int(bt)
        if interpret is None:
            interpret = default_interpret()
        self.interpret = bool(interpret)
        self.device_resident = bool(device_resident)
        self.max_staged = int(max_staged if max_staged is not None
                              else self.MAX_STAGED_STORES)
        self.max_staged_views = self.MAX_STAGED_VIEWS
        self._staged: OrderedDict[int, object] = OrderedDict()  # version->arr
        self._staged_views: OrderedDict[tuple, tuple] = OrderedDict()
        # transfer accounting (see class docstring); cumulative totals are
        # mirrored into EngineStats at every batch end
        self.host_transfers = 0
        self.host_transfer_bytes = 0
        self.scalar_syncs = 0
        # staging LRU is shared across overlapped server batches
        self._stage_lock = threading.Lock()

    def _fetch(self, tree):
        """ONE bulk device->host materialization of a pytree of arrays —
        every mask / binding-column transfer must route through here so
        ``host_transfers`` counts actual transfer events."""
        import jax

        out = jax.device_get(tree)
        nbytes = sum(int(a.nbytes)
                     for a in jax.tree_util.tree_leaves(out)
                     if hasattr(a, "nbytes"))
        with self._stage_lock:
            self.host_transfers += 1
            self.host_transfer_bytes += nbytes
        return out

    def _scalar(self, x) -> int:
        """Sync one O(1) control scalar off the device (counted separately
        from bulk transfers — see the class docstring)."""
        with self._stage_lock:
            self.scalar_syncs += 1
        return int(x)

    def _triples(self, store, min_slots: int = 1):
        """Device [T, 3] int32 copy of one *flat* store (a shard or a
        monolithic :class:`TripleStore`), LRU-kept by store version.

        ``min_slots`` widens the eviction limit to the number of flat
        arrays the *current* store needs at once, so a sharded store with
        more shards than ``max_staged`` never evicts its own shards
        mid-round (which would re-upload the full store every scan).
        """
        import jax.numpy as jnp

        with self._stage_lock:
            arr = self._staged.get(store.version)
            if arr is not None:
                self._staged.move_to_end(store.version)
                return arr
        if max(store.num_entities, store.num_predicates) >= 2 ** 31:
            raise ValueError("dictionary ids exceed int32 kernel range")
        arr = jnp.asarray(store.triples(), dtype=jnp.int32)
        with self._stage_lock:
            self._staged[store.version] = arr
            limit = max(self.max_staged, min_slots)
            while len(self._staged) > limit:
                self._staged.popitem(last=False)
        return arr

    def _pred_views(self, store: RDFStore, pid: int):
        """Device copies of predicate ``pid``'s shard-LOCAL ``PredIndex``
        sorted views: ``((s_sorted, s_order, o_sorted, o_order), offset,
        flat_store)``, LRU-kept by (owning shard version, pid) — the same
        version-granular discipline as the scan LRU, so a delta-rebalance
        invalidates only touched shards' staged views."""
        import jax.numpy as jnp

        flat, off = store.owning_part(pid)
        key = (flat.version, pid)
        with self._stage_lock:
            views = self._staged_views.get(key)
            if views is not None:
                self._staged_views.move_to_end(key)
                return views, off, flat
        idx = flat.pred_index(pid)
        views = tuple(jnp.asarray(a, dtype=jnp.int32)
                      for a in (idx.s_sorted, idx.s_order,
                                idx.o_sorted, idx.o_order))
        with self._stage_lock:
            self._staged_views[key] = views
            while len(self._staged_views) > self.max_staged_views:
                self._staged_views.popitem(last=False)
        return views, off, flat

    @staticmethod
    def _store_slots(store: RDFStore) -> int:
        """Flat device arrays ``store`` occupies when fully staged."""
        shards = getattr(store, "shards", None)
        if shards is None:
            return 1
        return max(1, sum(1 for sh in shards if sh.num_triples))

    @staticmethod
    def _scan_parts(store: RDFStore, tp: TriplePattern
                    ) -> list[tuple[object, int]]:
        """(flat store, global offset) pairs a scan for ``tp`` must touch."""
        shards = getattr(store, "shards", None)
        if shards is None:
            return [(store, 0)]
        if isinstance(tp.p, int):       # partition pruning: one owning shard
            k = store.shard_of_pred(tp.p)
            pair = (shards[k], int(store.shard_offsets[k]))
            return [pair] if shards[k].num_triples else []
        return [(sh, int(off)) for sh, off in store.parts()]

    @staticmethod
    def _pattern_vec(tp: TriplePattern) -> np.ndarray:
        return np.asarray(
            [tp.s if isinstance(tp.s, int) else -1,
             tp.p if isinstance(tp.p, int) else -1,
             tp.o if isinstance(tp.o, int) else -1], dtype=np.int32)

    @staticmethod
    def _repeated_var_filter(store: RDFStore, tp: TriplePattern,
                             tids: np.ndarray) -> np.ndarray:
        if isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o:
            tids = tids[store.s[tids] == store.o[tids]]
        if isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p:
            tids = tids[store.s[tids] == store.p[tids]]
        if isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p:
            tids = tids[store.o[tids] == store.p[tids]]
        return tids

    def candidate_parts(self, store: RDFStore,
                        tp: TriplePattern) -> CandidateParts:
        from ..kernels.triple_scan import triple_scan
        import jax.numpy as jnp

        pat = jnp.asarray(self._pattern_vec(tp))
        slots = self._store_slots(store)
        scan_parts = self._scan_parts(store, tp)
        masks = [triple_scan(self._triples(flat, min_slots=slots), pat,
                             bt=self.bt, interpret=self.interpret)
                 for flat, _off in scan_parts]
        parts: list[np.ndarray] = []
        for (flat, off), mask in zip(scan_parts,
                                     self._fetch(masks) if masks else []):
            tids = np.flatnonzero(mask).astype(np.int64) + off
            # the repeated-variable filter distributes over partitions
            parts.append(self._repeated_var_filter(store, tp, tids))
        return CandidateParts(parts)

    def candidates(self, store: RDFStore, tp: TriplePattern) -> np.ndarray:
        return self.candidate_parts(store, tp).concat()

    def prescan_parts(self, store: RDFStore, tps: list[TriplePattern],
                      ) -> dict[tuple, CandidateParts]:
        from ..kernels.triple_scan import triple_scan_many
        import jax.numpy as jnp

        uniq: dict[tuple, TriplePattern] = {}
        for tp in tps:
            uniq.setdefault(scan_key(tp), tp)
        if not uniq:
            return {}

        # group deduplicated scans by the flat store (shard) they touch;
        # a monolithic store is a single group
        groups: dict[int, tuple[object, int, list[tuple]]] = {}
        for k, tp in uniq.items():
            for flat, off in self._scan_parts(store, tp):
                g = groups.get(id(flat))
                if g is None:
                    g = groups[id(flat)] = (flat, off, [])
                g[2].append(k)

        slots = self._store_slots(store)
        parts: dict[tuple, list[np.ndarray]] = {k: [] for k in uniq}
        launches = []
        for flat, off, keys in groups.values():     # one launch per group
            pats = np.stack([self._pattern_vec(uniq[k]) for k in keys])
            launches.append((off, keys, triple_scan_many(
                self._triples(flat, min_slots=slots), jnp.asarray(pats),
                bt=self.bt, interpret=self.interpret)))
        # ONE bulk transfer materializes every group's masks together
        fetched = self._fetch([m for _, _, m in launches]) if launches else []
        for (off, keys, _), masks in zip(launches, fetched):
            for i, k in enumerate(keys):
                tids = np.flatnonzero(masks[i]).astype(np.int64) + off
                parts[k].append(
                    self._repeated_var_filter(store, uniq[k], tids))
        return {k: CandidateParts(parts[k]) for k in uniq}


_BACKENDS: dict[str, Callable[..., MatcherBackend]] = {}


def register_backend(name: str,
                     factory: Callable[..., MatcherBackend]) -> None:
    _BACKENDS[name] = factory


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **kw) -> MatcherBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown matcher backend {name!r}; "
                       f"have {available_backends()}")
    return _BACKENDS[name](**kw)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


@dataclass
class EngineStats:
    """Engine counters.

    Scan-counter contract (asserted in ``tests/test_join_pipeline.py``):
    ``scans_requested`` counts per-pattern scan *requests* — once per
    planned scannable pattern of each result-cache-missed query at batch
    start, plus once per unplanned mid-join lookup in the ``scan()``
    closure (a key not covered by the batch's prescan). Planned patterns
    are never re-counted by the closure (their keys are always memoized
    before execution), so ``scans_requested >= scans_executed`` and
    ``scans_deduped`` can never go negative; every executed scan
    corresponds to exactly one scan-LRU miss (``scans_executed ==
    scan_cache_misses``). Patterns taking the shard-local presorted join
    (``JoinStep.use_pred_index``) never request a scan at all.

    Per-phase timings: ``prescan_seconds`` (candidate-scan phase),
    ``join_seconds`` (time inside ``match_bgp`` joins), ``exec_seconds``
    (whole ``execute_batch`` calls, summed across overlapped threads).
    ``join`` aggregates the :class:`~repro.sparql.matcher.JoinStats`
    pipeline counters.

    Per-operator algebra counters (incremented by
    :mod:`repro.sparql.algebra` through :meth:`QueryEngine.bump_stats`):
    ``bgp_leaves`` — BGP leaves executed through this engine on behalf of
    algebra plans (each also counts once in ``queries``);
    ``filters_applied`` / ``optional_joins`` — FILTER / OPTIONAL
    (left-join) operator applications; ``union_branches`` — branches
    fed into UNION concatenations; ``values_joins`` — inline VALUES
    tables materialized into joins.

    Device-residency counters: ``backend_mode`` is the resolved execution
    mode (``"numpy"``, ``"jax-interpret"``, ``"jax-compiled"``).
    ``device_queries`` / ``device_fallbacks`` split the cache-missed
    queries of a device-capable backend into those served by the
    device-resident pipeline (:mod:`repro.sparql.device_join`) vs those
    that fell back to the host join path (ineligible shape: variable
    predicates, repeated variables, masked joins, wildcard seed on a
    sharded store). ``host_transfers`` / ``host_transfer_bytes`` /
    ``scalar_syncs`` MIRROR the backend's cumulative totals (absolute
    values re-copied at every batch end, so per-batch deltas are
    meaningful): ``host_transfers`` counts bulk device->host array
    materializations — exactly ONE per batch when every missed query is
    device-eligible, one more for the host path's fused prescan when the
    batch is mixed — while ``scalar_syncs`` counts the O(1)-byte row-count
    reads host-driven allocation needs (excluded from the one-transfer
    contract; see :mod:`repro.sparql.device_join`).
    """

    queries: int = 0
    batches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    scans_requested: int = 0
    scans_executed: int = 0
    scan_cache_hits: int = 0
    scan_cache_misses: int = 0
    scan_cache_evictions: int = 0
    exec_seconds: float = 0.0
    prescan_seconds: float = 0.0
    join_seconds: float = 0.0
    join: JoinStats = field(default_factory=JoinStats)
    bgp_leaves: int = 0
    filters_applied: int = 0
    optional_joins: int = 0
    union_branches: int = 0
    values_joins: int = 0
    backend_mode: str = ""
    device_queries: int = 0
    device_fallbacks: int = 0
    host_transfers: int = 0
    host_transfer_bytes: int = 0
    scalar_syncs: int = 0

    @property
    def scans_deduped(self) -> int:
        return self.scans_requested - self.scans_executed


class QueryEngine:
    """Batched BGP executor with scan dedup and an LRU result cache.

    See the module docstring for batching semantics and cache keying.
    ``cache_size`` bounds the number of memoized :class:`MatchResult`s
    (0 disables caching). One engine instance may serve many stores — cache
    keys embed ``store.version``.
    """

    def __init__(self, backend: str | MatcherBackend = "numpy",
                 cache_size: int = 256, max_rows: int = 5_000_000,
                 cache_bytes: int = 512 * 1024 * 1024,
                 scan_cache_bytes: int = 64 * 1024 * 1024,
                 scan_cache_size: int = 4096,
                 shard_local_joins: bool = True) -> None:
        self.backend = (backend if isinstance(backend, MatcherBackend)
                        else get_backend(backend))
        self.cache_size = int(cache_size)
        # one result near max_rows can be hundreds of MB of int64 bindings,
        # so the LRU is bounded by bytes as well as entry count
        self.cache_bytes = int(cache_bytes)
        # candidate-scan LRU keyed (store.version, scan key): hot scans
        # survive between batches (scan_cache_bytes=0 disables). The count
        # bound matters independently of the byte bound: empty candidate
        # arrays are 0 bytes, so probe-miss workloads would otherwise grow
        # the dict without limit as store versions churn.
        self.scan_cache_bytes = int(scan_cache_bytes)
        self.scan_cache_size = int(scan_cache_size)
        self.max_rows = int(max_rows)
        # False = global scan+sort joins (the pre-shard-parallel baseline,
        # kept as the --join benchmark reference)
        self.shard_local_joins = bool(shard_local_joins)
        self.stats = EngineStats()
        interp = getattr(self.backend, "interpret", None)
        self.stats.backend_mode = (
            self.backend.name if interp is None else
            f"{self.backend.name}-{'interpret' if interp else 'compiled'}")
        self._cache: OrderedDict[tuple, MatchResult] = OrderedDict()
        self._cached_bytes = 0
        # values are (CandidateParts, put-time global-id offset)
        self._scan_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._scan_cached_bytes = 0
        # join plans keyed (store.version, canonical BGP key): planning is
        # pure-Python (GIL-bound), so memoizing it both speeds cold batches
        # and shrinks the serialized fraction of overlapped rounds
        self._plan_cache: OrderedDict[tuple, list] = OrderedDict()
        self._plan_cache_size = 4096
        # guards caches + stats when one engine serves overlapped server
        # batches from multiple threads; the matcher hot path runs unlocked
        self._lock = threading.RLock()

    def cache_probe(self, store: RDFStore, q: QueryGraph) -> dict:
        """Non-mutating cache provenance for one BGP: would this query hit
        the result cache, and how many of its planned candidate scans sit
        in the scan LRU? Counters are NOT incremented — this is the
        read-only surface ``explain`` (:func:`repro.sparql.algebra.
        explain_plan`) builds on, keeping the cache representation private
        to this module.

        Returns ``{"result_cached": bool, "scans_cached": int,
        "scans_total": int}``.
        """
        ck, _ = query_key(q)
        with self._lock:
            hit = (store.version, ck) in self._cache
        plan = plan_bgp(store, q, shard_local=self.shard_local_joins)
        scannable = [q.patterns[st.pattern] for st in plan if st.needs_scan]
        cached = 0
        for tp in scannable:
            key, _off = self._scan_entry(store, tp, scan_key(tp))
            with self._lock:
                cached += key in self._scan_cache
        return {"result_cached": hit, "scans_cached": cached,
                "scans_total": len(scannable)}

    def bump_stats(self, **counters: int) -> None:
        """Thread-safely increment :class:`EngineStats` integer counters —
        how the algebra evaluator (:mod:`repro.sparql.algebra`) reports
        per-operator counts into the shared engine stats."""
        with self._lock:
            for name, n in counters.items():
                setattr(self.stats, name, getattr(self.stats, name) + n)

    # -- cache ---------------------------------------------------------------
    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self._cached_bytes = 0
            self._scan_cache.clear()
            self._scan_cached_bytes = 0
            # join plans survive: like store.pred_index they are derived
            # metadata (store-version-keyed, never stale), not cached data

    def _plan_for(self, store: RDFStore, q: QueryGraph, ck: tuple) -> list:
        key = (store.version, ck)
        with self._lock:
            plan = self._plan_cache.get(key)
            if plan is not None:
                self._plan_cache.move_to_end(key)
                return plan
        plan = plan_bgp(store, q, shard_local=self.shard_local_joins)
        with self._lock:
            self._plan_cache[key] = plan
            while len(self._plan_cache) > self._plan_cache_size:
                self._plan_cache.popitem(last=False)
        return plan

    def _cache_get(self, key: tuple) -> MatchResult | None:
        with self._lock:
            res = self._cache.get(key)
            if res is not None:
                self._cache.move_to_end(key)
                self.stats.cache_hits += 1
            else:
                self.stats.cache_misses += 1
            return res

    @staticmethod
    def _result_bytes(res: MatchResult) -> int:
        return int(res.bindings.nbytes + res.edge_ids.nbytes)

    def _cache_put(self, key: tuple, res: MatchResult) -> None:
        if self.cache_size <= 0:
            return
        nbytes = self._result_bytes(res)
        if nbytes > self.cache_bytes:
            return                       # would evict everything; skip
        with self._lock:
            displaced = self._cache.pop(key, None)
            if displaced is not None:    # overwrite: release the old bytes
                self._cached_bytes -= self._result_bytes(displaced)
            self._cache[key] = res
            self._cached_bytes += nbytes
            while (len(self._cache) > self.cache_size
                   or self._cached_bytes > self.cache_bytes):
                _, old = self._cache.popitem(last=False)
                self._cached_bytes -= self._result_bytes(old)
                self.stats.cache_evictions += 1

    # -- scan cache ----------------------------------------------------------
    @staticmethod
    def _scan_entry(store: RDFStore, tp: TriplePattern,
                    k: tuple) -> tuple[tuple, int]:
        """(cache key, global-id offset) for one candidate scan.

        Version-granular invalidation: a bound-predicate scan on a sharded
        store touches exactly the predicate's owning shard, so its entry is
        keyed by that SHARD's version and stored in shard-local ids — a
        placement delta (:mod:`repro.rdf.deltas`) mutating other shards
        leaves the entry valid, and the store's *current* offset re-lifts
        the ids at hit time (offsets shift when earlier shards grow). All
        other scans (wildcard predicate, monolithic store) key on the full
        store version with offset 0. Shard version tokens are globally
        unique, so entries can never collide across stores — and a shard
        queried directly as a flat store shares its entries for free.
        """
        shards = getattr(store, "shards", None)
        if shards is not None and isinstance(tp.p, int):
            sid = store.shard_of_pred(tp.p)
            return ((shards[sid].version, k),
                    int(store.shard_offsets[sid]))
        return (store.version, k), 0

    def _scan_lookup(self, store: RDFStore, tp: TriplePattern,
                     k: tuple) -> CandidateParts | None:
        key, off = self._scan_entry(store, tp, k)
        hit = self._scan_cache_get(key)
        if hit is None:
            return None
        parts, stored_off = hit
        # ids stored at put-time offsets: zero-copy (shift 0) until a delta
        # actually moves this shard's offset or another store reuses the
        # shard at a different global position
        return parts.shifted(off - stored_off)

    def _scan_store(self, store: RDFStore, tp: TriplePattern, k: tuple,
                    parts: CandidateParts) -> None:
        key, off = self._scan_entry(store, tp, k)
        self._scan_cache_put(key, (parts, off))

    def _scan_cache_get(self, key: tuple):
        with self._lock:
            parts = self._scan_cache.get(key)
            if parts is not None:
                self._scan_cache.move_to_end(key)
                self.stats.scan_cache_hits += 1
            else:
                self.stats.scan_cache_misses += 1
            return parts

    def _scan_cache_put(self, key: tuple, entry) -> None:
        """``entry`` is ``(CandidateParts, put_time_offset)`` — see
        :meth:`_scan_lookup`."""
        if self.scan_cache_bytes <= 0:
            return
        nbytes = int(entry[0].nbytes)
        if nbytes > self.scan_cache_bytes:
            return
        with self._lock:
            displaced = self._scan_cache.pop(key, None)
            if displaced is not None:
                self._scan_cached_bytes -= int(displaced[0].nbytes)
            self._scan_cache[key] = entry
            self._scan_cached_bytes += nbytes
            while (len(self._scan_cache) > self.scan_cache_size
                   or self._scan_cached_bytes > self.scan_cache_bytes):
                _, old = self._scan_cache.popitem(last=False)
                self._scan_cached_bytes -= int(old[0].nbytes)
                self.stats.scan_cache_evictions += 1

    @staticmethod
    def _remap(res: MatchResult, canon_to_actual: dict[str, str]
               ) -> MatchResult:
        """Re-label a cached canonical result with a query's variable names."""
        return MatchResult(
            var_names=[canon_to_actual[v] for v in res.var_names],
            bindings=res.bindings, edge_ids=res.edge_ids)

    @staticmethod
    def _canonical(q: QueryGraph, canon_to_actual: dict[str, str]
                   ) -> QueryGraph:
        """``q`` under canonical variable names, so execution results are
        independent of this query's variable spelling (cache-entry form)."""
        actual_to_canon = {a: c for c, a in canon_to_actual.items()}
        return QueryGraph(
            patterns=[TriplePattern(
                *(actual_to_canon.get(t, t) if isinstance(t, str)
                  else t for t in (tp.s, tp.p, tp.o)))
                for tp in q.patterns],
            projection=[])

    # -- execution -----------------------------------------------------------
    def execute(self, store: RDFStore, q: QueryGraph) -> MatchResult:
        return self.execute_batch(store, [q])[0]

    def execute_batch(self, store: RDFStore,
                      queries: list[QueryGraph]) -> list[MatchResult]:
        """Execute ``queries`` against ``store``; results align by index.

        Identical candidate scans run once per batch and are retained in the
        cross-batch scan LRU; alpha-equivalent queries resolve from the
        result cache (within the batch and across calls, until the store
        version changes).
        """
        t0 = time.perf_counter()
        with self._lock:
            self.stats.batches += 1
            self.stats.queries += len(queries)

        keyed = [query_key(q) for q in queries]
        with self._lock:
            misses = [i for i, (ck, _) in enumerate(keyed)
                      if (store.version, ck) not in self._cache]

        # plan each cache-missed query so only the patterns the join
        # pipeline will actually scan are prescanned (shard-local presorted
        # joins skip the scan entirely); scan memo seeded from the
        # cross-batch scan LRU, the remaining distinct keys execute once.
        # Device-eligible queries peel off into the device-resident pipeline
        # instead — their scans and joins never touch the host scan path
        # (or its counters), and their bindings leave the device in one
        # bulk transfer at the end of the device phase.
        memo: dict[tuple, CandidateParts] = {}
        plans: dict[int, list] = {}
        device_jobs: dict[tuple, tuple] = {}    # ck -> (canonical q, plan)
        join_stats = JoinStats()
        join_dt = 0.0
        use_device = (self.shard_local_joins
                      and getattr(self.backend, "device_resident", False))
        if misses:
            need: list[TriplePattern] = []
            for i in misses:
                ck, canon_to_actual = keyed[i]
                plans[i] = self._plan_for(store, queries[i], ck)
                if use_device:
                    if ck in device_jobs:
                        with self._lock:
                            self.stats.device_queries += 1
                        continue
                    cq = self._canonical(queries[i], canon_to_actual)
                    if device_eligible(store, cq, plans[i]):
                        device_jobs[ck] = (cq, plans[i])
                        with self._lock:
                            self.stats.device_queries += 1
                        continue
                    with self._lock:
                        self.stats.device_fallbacks += 1
                need += [queries[i].patterns[st.pattern]
                         for st in plans[i] if st.needs_scan]
            with self._lock:
                self.stats.scans_requested += len(need)
            uniq: dict[tuple, TriplePattern] = {}
            for tp in need:
                uniq.setdefault(scan_key(tp), tp)
            fresh: list[TriplePattern] = []
            for k, tp in uniq.items():
                hit = self._scan_lookup(store, tp, k)
                if hit is not None:
                    memo[k] = hit
                else:
                    fresh.append(tp)
            if fresh:
                t_scan = time.perf_counter()
                scanned = self.backend.prescan_parts(store, fresh)
                memo.update(scanned)
                for k, parts in scanned.items():
                    self._scan_store(store, uniq[k], k, parts)
                with self._lock:
                    self.stats.scans_executed += len(scanned)
                    self.stats.prescan_seconds += (time.perf_counter()
                                                   - t_scan)

        # device-resident phase: all queued queries execute on device, then
        # ONE bulk device->host transfer materializes their results
        device_results: dict[tuple, MatchResult] = {}
        if device_jobs:
            t_dev = time.perf_counter()
            dbatch = DeviceBatch(self.backend, store)
            for ck, (cq, plan) in device_jobs.items():
                dbatch.add(ck, cq, plan)
            device_results = dbatch.run(max_rows=self.max_rows,
                                        stats=join_stats)
            join_dt += time.perf_counter() - t_dev

        def scan(st: RDFStore, tp: TriplePattern) -> CandidateParts:
            k = scan_key(tp)
            if k not in memo:          # unplanned pattern added mid-join
                with self._lock:
                    self.stats.scans_requested += 1
                parts = self._scan_lookup(st, tp, k)
                if parts is None:
                    parts = self.backend.candidate_parts(st, tp)
                    self._scan_store(st, tp, k, parts)
                    with self._lock:
                        self.stats.scans_executed += 1
                memo[k] = parts
            return memo[k]

        out: list[MatchResult | None] = [None] * len(queries)
        for i, q in enumerate(queries):
            ck, canon_to_actual = keyed[i]
            cached = self._cache_get((store.version, ck))
            if cached is None:
                dres = device_results.get(ck)
                if dres is not None:
                    cached = dres
                else:
                    # execute under canonical names so the cached entry is
                    # independent of this query's variable spelling
                    canon_q = self._canonical(q, canon_to_actual)
                    t_join = time.perf_counter()
                    cached = match_bgp(store, canon_q,
                                       max_rows=self.max_rows,
                                       candidates=scan, plan=plans.get(i),
                                       stats=join_stats,
                                       shard_local=self.shard_local_joins)
                    join_dt += time.perf_counter() - t_join
                self._cache_put((store.version, ck), cached)
            out[i] = self._remap(cached, canon_to_actual)
        with self._lock:
            self.stats.join_seconds += join_dt
            self.stats.join.merge(join_stats)
            bk = self.backend
            if hasattr(bk, "host_transfers"):
                # absolute backend totals, re-mirrored each batch so
                # callers can take per-batch deltas
                self.stats.host_transfers = bk.host_transfers
                self.stats.host_transfer_bytes = bk.host_transfer_bytes
                self.stats.scalar_syncs = bk.scalar_syncs
            self.stats.exec_seconds += time.perf_counter() - t0
        return out
