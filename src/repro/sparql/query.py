"""SPARQL BGP query graphs (paper Def. 2) + a minimal parser.

A query is a directed multigraph whose vertices are entity constants or
variables and whose edge labels are predicates (constant or variable).  The
parser covers the BGP subset used throughout the paper: ``SELECT``
projections and a ``WHERE`` block of dot-separated triple patterns with
``<uri>`` / ``?var`` / ``"literal"`` terms and optional ``PREFIX``es.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from ..rdf.dictionary import Dictionary

VAR_S = -1  # sentinel id for "this position is a variable"


@dataclass(frozen=True)
class TriplePattern:
    """One edge of the query graph. Ids are dictionary ids or names for vars."""

    s: str | int   # int entity id (constant) or "?name"
    p: str | int   # int predicate id or "?name"
    o: str | int

    def variables(self) -> list[str]:
        return [t for t in (self.s, self.p, self.o)
                if isinstance(t, str)]


@dataclass
class QueryGraph:
    """A BGP query: triple patterns + projection list."""

    patterns: list[TriplePattern]
    projection: list[str]  # variable names; empty == SELECT *

    @property
    def variables(self) -> list[str]:
        seen: dict[str, None] = {}
        for tp in self.patterns:
            for v in tp.variables():
                seen.setdefault(v)
        return list(seen)

    @property
    def vertex_variables(self) -> list[str]:
        seen: dict[str, None] = {}
        for tp in self.patterns:
            for t in (tp.s, tp.o):
                if isinstance(t, str):
                    seen.setdefault(t)
        return list(seen)

    def n_edges(self) -> int:
        return len(self.patterns)

    # -- structural views used by pattern canonicalization ------------------
    def vertices(self) -> list[str | int]:
        seen: dict[str | int, None] = {}
        for tp in self.patterns:
            seen.setdefault(tp.s)
            seen.setdefault(tp.o)
        return list(seen)

    def edge_array(self) -> np.ndarray:
        """[E, 3] array over *local vertex indices*; predicate -2 if variable.

        Constants keep identity through a vertex table returned alongside by
        ``vertex_table``.
        """
        vmap = {v: i for i, v in enumerate(self.vertices())}
        out = np.zeros((len(self.patterns), 3), dtype=np.int64)
        for i, tp in enumerate(self.patterns):
            out[i, 0] = vmap[tp.s]
            out[i, 1] = -2 if isinstance(tp.p, str) else tp.p
            out[i, 2] = vmap[tp.o]
        return out

    def is_weakly_connected(self) -> bool:
        verts = self.vertices()
        if not verts:
            return True
        vmap = {v: i for i, v in enumerate(verts)}
        parent = list(range(len(verts)))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for tp in self.patterns:
            ra, rb = find(vmap[tp.s]), find(vmap[tp.o])
            if ra != rb:
                parent[ra] = rb
        return len({find(i) for i in range(len(verts))}) == 1


_TERM = r"""(\?[A-Za-z_][\w]*|<[^>\s]+>|"[^"]*"|[A-Za-z_][\w]*:[\w\-.]*)"""
_TRIPLE_RE = re.compile(rf"\s*{_TERM}\s+{_TERM}\s+{_TERM}\s*")
_PREFIX_RE = re.compile(r"PREFIX\s+([A-Za-z_][\w]*):\s*<([^>]*)>",
                        re.IGNORECASE)
_SELECT_RE = re.compile(r"SELECT\s+(.*?)\s+WHERE\s*\{(.*)\}",
                        re.IGNORECASE | re.DOTALL)


class ParseError(ValueError):
    pass


def parse_sparql(text: str, dictionary: Dictionary) -> QueryGraph:
    """Parse a BGP SELECT query against a dictionary.

    Unknown constants raise ``ParseError`` — a query mentioning an entity not
    in the graph has no matches anywhere, and the paper's system routes on
    encoded ids.
    """
    prefixes = dict(_PREFIX_RE.findall(text))
    m = _SELECT_RE.search(text)
    if not m:
        raise ParseError("not a SELECT ... WHERE { ... } query")
    proj_raw, body = m.group(1), m.group(2)
    projection = ([] if proj_raw.strip() == "*"
                  else re.findall(r"\?[\w]+", proj_raw))

    def decode(tok: str, position: str) -> str | int:
        if tok.startswith("?"):
            return tok
        if tok.startswith("<"):
            term = tok[1:-1]
        elif tok.startswith('"'):
            term = tok[1:-1]
        else:  # prefixed name
            pfx, _, local = tok.partition(":")
            if pfx not in prefixes:
                raise ParseError(f"unknown prefix {pfx!r}")
            term = prefixes[pfx] + local
        if position == "p":
            if not dictionary.has_predicate(term):
                raise ParseError(f"unknown predicate {term!r}")
            return dictionary.predicate_id(term)
        if not dictionary.has_entity(term):
            raise ParseError(f"unknown entity {term!r}")
        return dictionary.entity_id(term)

    patterns: list[TriplePattern] = []
    for chunk in body.split("."):
        chunk = chunk.strip()
        if not chunk:
            continue
        tm = _TRIPLE_RE.fullmatch(chunk)
        if not tm:
            raise ParseError(f"bad triple pattern: {chunk!r}")
        s, p, o = (tm.group(1), tm.group(2), tm.group(3))
        patterns.append(TriplePattern(decode(s, "s"), decode(p, "p"),
                                      decode(o, "o")))
    if not patterns:
        raise ParseError("empty WHERE block")
    q = QueryGraph(patterns=patterns, projection=projection)
    return q
