"""SPARQL query parsing: BGP query graphs (paper Def. 2) + the extended
algebra grammar behind :class:`repro.sparql.endpoint.SparqlEndpoint`.

A BGP query is a directed multigraph whose vertices are entity constants or
variables and whose edge labels are predicates (constant or variable) —
:class:`QueryGraph`. On top of that Def.-2 subset, :func:`parse_query`
understands the algebra surface compiled by
:mod:`repro.sparql.algebra`:

- ``SELECT [DISTINCT] ?v ... | *`` and ``ASK`` query forms;
- group graph patterns with ``FILTER`` (comparisons ``= != < <= > >=``,
  ``&& || !``, ``BOUND(?v)``, ``REGEX(?v, "pat"[, "i"])``), ``OPTIONAL``
  groups, ``{ A } UNION { B }`` chains, nested groups, and inline
  ``VALUES`` data blocks (``VALUES ?v { t ... }`` and
  ``VALUES (?v ?w) { (t t) (UNDEF t) ... }``);
- solution modifiers ``ORDER BY [ASC|DESC](?v)``, ``LIMIT`` / ``OFFSET``.

Input is **tokenized first** (strings, IRIs, vars, numbers, prefixed names,
punctuation), so quoted literals containing ``.``, ``;``, ``?``, braces, or
whitespace can never break pattern splitting — the historical dot-split
parser mis-tokenized them (regression-tested in ``tests/test_algebra.py``).

:func:`parse_sparql` remains the stable BGP-only entry point: it accepts
exactly the Def.-2 subset (plain ``SELECT`` + triple patterns) and raises
:class:`ParseError` for algebra constructs, pointing callers at
:func:`parse_query` / ``SparqlEndpoint``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from ..rdf.dictionary import Dictionary

VAR_S = -1  # sentinel id for "this position is a variable"


@dataclass(frozen=True)
class TriplePattern:
    """One edge of the query graph. Ids are dictionary ids or names for vars."""

    s: str | int   # int entity id (constant) or "?name"
    p: str | int   # int predicate id or "?name"
    o: str | int

    def variables(self) -> list[str]:
        return [t for t in (self.s, self.p, self.o)
                if isinstance(t, str)]


@dataclass
class QueryGraph:
    """A BGP query: triple patterns + projection list."""

    patterns: list[TriplePattern]
    projection: list[str]  # variable names; empty == SELECT *

    @property
    def variables(self) -> list[str]:
        seen: dict[str, None] = {}
        for tp in self.patterns:
            for v in tp.variables():
                seen.setdefault(v)
        return list(seen)

    @property
    def vertex_variables(self) -> list[str]:
        seen: dict[str, None] = {}
        for tp in self.patterns:
            for t in (tp.s, tp.o):
                if isinstance(t, str):
                    seen.setdefault(t)
        return list(seen)

    def n_edges(self) -> int:
        return len(self.patterns)

    # -- structural views used by pattern canonicalization ------------------
    def vertices(self) -> list[str | int]:
        seen: dict[str | int, None] = {}
        for tp in self.patterns:
            seen.setdefault(tp.s)
            seen.setdefault(tp.o)
        return list(seen)

    def edge_array(self) -> np.ndarray:
        """[E, 3] array over *local vertex indices*; predicate -2 if variable.

        Constants keep identity through a vertex table returned alongside by
        ``vertex_table``.
        """
        vmap = {v: i for i, v in enumerate(self.vertices())}
        out = np.zeros((len(self.patterns), 3), dtype=np.int64)
        for i, tp in enumerate(self.patterns):
            out[i, 0] = vmap[tp.s]
            out[i, 1] = -2 if isinstance(tp.p, str) else tp.p
            out[i, 2] = vmap[tp.o]
        return out

    def is_weakly_connected(self) -> bool:
        verts = self.vertices()
        if not verts:
            return True
        vmap = {v: i for i, v in enumerate(verts)}
        parent = list(range(len(verts)))

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for tp in self.patterns:
            ra, rb = find(vmap[tp.s]), find(vmap[tp.o])
            if ra != rb:
                parent[ra] = rb
        return len({find(i) for i in range(len(verts))}) == 1


class ParseError(ValueError):
    pass


# ---------------------------------------------------------------------------
# FILTER expression AST (evaluated by repro.sparql.algebra)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Operand:
    """A FILTER operand: a variable or a constant term.

    ``kind`` is ``"var"`` (``value`` holds ``?name``) or ``"term"``
    (``value`` holds the *decoded* term string — IRI text, literal text, or
    numeral). ``ent_id`` / ``pred_id`` carry the dictionary ids when the
    constant is known in the respective space (``None`` otherwise —
    FILTER constants need not exist in the graph, unlike triple constants).
    """

    kind: str
    value: str
    ent_id: int | None = None
    pred_id: int | None = None


@dataclass(frozen=True)
class Comparison:
    op: str          # one of = != < <= > >=
    lhs: Operand
    rhs: Operand


@dataclass(frozen=True)
class BoundExpr:
    var: str


@dataclass(frozen=True)
class RegexExpr:
    var: str
    pattern: str
    flags: str = ""


@dataclass(frozen=True)
class NotExpr:
    arg: object


@dataclass(frozen=True)
class AndExpr:
    args: tuple


@dataclass(frozen=True)
class OrExpr:
    args: tuple


# ---------------------------------------------------------------------------
# parsed-query AST
# ---------------------------------------------------------------------------


@dataclass
class GroupPattern:
    """One ``{ ... }`` group: an ordered element list.

    Elements are tagged tuples —
    ``("bgp", [TriplePattern, ...])``, ``("filter", expr)``,
    ``("optional", GroupPattern)``, ``("union", [GroupPattern, ...])``,
    ``("group", GroupPattern)``, ``("values", [var, ...], [row, ...])``
    where each VALUES row is a tuple of entity ids with ``None`` for
    ``UNDEF`` cells. Consecutive triple patterns accumulate into one
    ``"bgp"`` element (one BGP leaf after compilation).
    """

    elements: list = field(default_factory=list)

    def is_plain_bgp(self) -> bool:
        return (len(self.elements) == 1 and self.elements[0][0] == "bgp")


@dataclass
class ParsedQuery:
    """Syntax-level query AST (input to ``algebra.compile_query``)."""

    form: str                           # "select" | "ask"
    distinct: bool
    projection: list[str]               # [] == SELECT *
    where: GroupPattern
    order_by: list[tuple[str, bool]]    # (var, ascending)
    limit: int | None
    offset: int
    text: str = ""

    def is_plain_bgp_select(self) -> bool:
        """True iff this is exactly the Def.-2 subset ``parse_sparql`` covers."""
        return (self.form == "select" and not self.distinct
                and not self.order_by and self.limit is None
                and not self.offset and self.where.is_plain_bgp())


@dataclass
class ParsedUpdate:
    """Syntax-level SPARQL UPDATE AST (input to
    ``repro.sparql.update.compile_update``).

    ``kind`` is ``"insert_data"``, ``"delete_data"``, or ``"delete_where"``.
    ``triples`` holds ``(s, p, o)`` tuples whose positions are tagged
    ``("term", text)`` for constants (prefix-expanded term strings, NOT
    dictionary ids — ``INSERT DATA`` may mention brand-new terms) or
    ``("var", "?name")`` (``delete_where`` only).
    """

    kind: str
    triples: list[tuple]
    text: str = ""


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+|\#[^\n]*)
    | (?P<string>"(?:[^"\\]|\\.)*")
    | (?P<iri><[^<>\s]*>)
    | (?P<var>\?\w+)
    | (?P<num>-?\d+(?:\.\d+)?)
    | (?P<pname>[A-Za-z_]\w*:[\w\-.]*)
    | (?P<name>[A-Za-z_]\w*)
    | (?P<op>&&|\|\||!=|<=|>=|[{}().,;=<>!*])
    """,
    re.VERBOSE,
)

_KEYWORDS = {"select", "ask", "where", "filter", "optional", "union",
             "distinct", "order", "by", "asc", "desc", "limit", "offset",
             "bound", "regex", "prefix", "insert", "delete", "data",
             "values", "undef"}


def _tokenize(text: str) -> list[tuple[str, str]]:
    """``(type, text)`` tokens; strings are recognized before any other
    syntax, so literal contents can never be split as punctuation."""
    out: list[tuple[str, str]] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise ParseError(f"cannot tokenize at: {text[pos:pos + 20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        out.append((kind, m.group()))
    return out


def _unquote(tok: str) -> str:
    return tok[1:-1].replace('\\"', '"').replace("\\\\", "\\")


# ---------------------------------------------------------------------------
# recursive-descent parser
# ---------------------------------------------------------------------------


class _Parser:
    def __init__(self, text: str, dictionary: Dictionary) -> None:
        self.toks = _tokenize(text)
        self.pos = 0
        self.d = dictionary
        self.prefixes: dict[str, str] = {}

    # -- token helpers ------------------------------------------------------
    def peek(self, ahead: int = 0) -> tuple[str, str]:
        i = self.pos + ahead
        return self.toks[i] if i < len(self.toks) else ("eof", "")

    def next(self) -> tuple[str, str]:
        t = self.peek()
        self.pos += 1
        return t

    def at_keyword(self, *kws: str) -> bool:
        kind, txt = self.peek()
        return kind == "name" and txt.lower() in kws

    def expect_keyword(self, kw: str) -> None:
        if not self.at_keyword(kw):
            raise ParseError(f"expected {kw.upper()!r}, got {self.peek()[1]!r}")
        self.next()

    def expect_op(self, op: str) -> None:
        kind, txt = self.peek()
        if kind != "op" or txt != op:
            raise ParseError(f"expected {op!r}, got {txt!r}")
        self.next()

    def at_op(self, *ops: str) -> bool:
        kind, txt = self.peek()
        return kind == "op" and txt in ops

    # -- term decoding ------------------------------------------------------
    def _expand(self, kind: str, txt: str) -> str:
        """Token -> term string (IRI text / literal text / numeral)."""
        if kind == "iri":
            return txt[1:-1]
        if kind == "string":
            return _unquote(txt)
        if kind == "num":
            return txt
        if kind == "pname":
            pfx, _, local = txt.partition(":")
            if pfx not in self.prefixes:
                raise ParseError(f"unknown prefix {pfx!r}")
            return self.prefixes[pfx] + local
        raise ParseError(f"not a term: {txt!r}")

    def _decode_triple_term(self, position: str) -> str | int:
        kind, txt = self.next()
        if kind == "var":
            return txt
        term = self._expand(kind, txt)
        if position == "p":
            if not self.d.has_predicate(term):
                raise ParseError(f"unknown predicate {term!r}")
            return self.d.predicate_id(term)
        if not self.d.has_entity(term):
            raise ParseError(f"unknown entity {term!r}")
        return self.d.entity_id(term)

    # -- grammar ------------------------------------------------------------
    def parse_prologue(self) -> None:
        while self.at_keyword("prefix"):
            self.next()
            kind, txt = self.next()
            if kind != "pname" or not txt.endswith(":"):
                raise ParseError(f"bad PREFIX name {txt!r}")
            ikind, itxt = self.next()
            if ikind != "iri":
                raise ParseError(f"bad PREFIX IRI {itxt!r}")
            self.prefixes[txt[:-1]] = itxt[1:-1]

    def parse(self) -> ParsedQuery:
        self.parse_prologue()

        if self.at_keyword("ask"):
            self.next()
            form, distinct, projection = "ask", False, []
        elif self.at_keyword("select"):
            self.next()
            form = "select"
            distinct = False
            if self.at_keyword("distinct"):
                self.next()
                distinct = True
            projection = []
            if self.at_op("*"):
                self.next()
            else:
                while self.peek()[0] == "var":
                    projection.append(self.next()[1])
                if not projection:
                    raise ParseError("SELECT needs a projection (?vars or *)")
        else:
            raise ParseError("not a SELECT ... WHERE { ... } query")

        if self.at_keyword("where"):
            self.next()
        where = self.parse_group()

        order_by: list[tuple[str, bool]] = []
        limit: int | None = None
        offset = 0
        while self.peek()[0] != "eof":
            if self.at_keyword("order"):
                self.next()
                self.expect_keyword("by")
                while True:
                    if self.at_keyword("asc", "desc"):
                        asc = self.next()[1].lower() == "asc"
                        self.expect_op("(")
                        kind, var = self.next()
                        if kind != "var":
                            raise ParseError("ORDER BY key must be a ?var")
                        self.expect_op(")")
                        order_by.append((var, asc))
                    elif self.peek()[0] == "var":
                        order_by.append((self.next()[1], True))
                    else:
                        break
                if not order_by:
                    raise ParseError("empty ORDER BY")
            elif self.at_keyword("limit"):
                self.next()
                kind, txt = self.next()
                if kind != "num" or not txt.isdigit():
                    raise ParseError(f"LIMIT needs a non-negative integer, "
                                     f"got {txt!r}")
                limit = int(txt)
            elif self.at_keyword("offset"):
                self.next()
                kind, txt = self.next()
                if kind != "num" or not txt.isdigit():
                    raise ParseError(f"OFFSET needs a non-negative integer, "
                                     f"got {txt!r}")
                offset = int(txt)
            else:
                raise ParseError(f"trailing tokens: {self.peek()[1]!r}")
        if form == "ask" and (distinct or order_by or limit is not None
                              or offset):
            raise ParseError("ASK takes no solution modifiers")
        return ParsedQuery(form=form, distinct=distinct,
                           projection=projection, where=where,
                           order_by=order_by, limit=limit, offset=offset)

    def parse_group(self) -> GroupPattern:
        self.expect_op("{")
        g = GroupPattern()
        bgp: list[TriplePattern] = []

        def flush() -> None:
            if bgp:
                g.elements.append(("bgp", list(bgp)))
                bgp.clear()

        while True:
            if self.at_op("}"):
                self.next()
                flush()
                return g
            if self.peek()[0] == "eof":
                raise ParseError("unterminated group (missing '}')")
            if self.at_keyword("filter"):
                self.next()
                g.elements.append(("filter", self.parse_filter_expr()))
            elif self.at_keyword("values"):
                self.next()
                flush()
                g.elements.append(self.parse_values())
            elif self.at_keyword("optional"):
                self.next()
                flush()
                g.elements.append(("optional", self.parse_group()))
            elif self.at_op("{"):
                flush()
                branches = [self.parse_group()]
                while self.at_keyword("union"):
                    self.next()
                    branches.append(self.parse_group())
                g.elements.append(("union", branches) if len(branches) > 1
                                  else ("group", branches[0]))
            elif self.at_op("."):
                self.next()         # triple separator (also allowed trailing)
            else:
                s = self._decode_triple_term("s")
                p = self._decode_triple_term("p")
                o = self._decode_triple_term("o")
                bgp.append(TriplePattern(s, p, o))

    def parse_values(self) -> tuple:
        """``VALUES ?v { term ... }`` or ``VALUES (?v ...) { (term ...) ... }``.

        Terms resolve to entity ids at parse time (a VALUES binding naming a
        term the dictionary has never seen can match nothing anywhere — same
        contract as triple constants, and it keeps the inline table in the
        engine's id space). ``UNDEF`` cells become ``None`` (compiled to
        :data:`repro.sparql.algebra.UNBOUND`, so they are compatible with
        any binding in the join).
        """
        vars_: list[str] = []
        grouped = self.at_op("(")
        if grouped:
            self.next()
            while self.peek()[0] == "var":
                vars_.append(self.next()[1])
            self.expect_op(")")
        elif self.peek()[0] == "var":
            vars_.append(self.next()[1])
        if not vars_:
            raise ParseError("VALUES needs ?vars")
        if len(set(vars_)) != len(vars_):
            raise ParseError("duplicate variable in VALUES")
        self.expect_op("{")
        rows: list[tuple] = []
        while not self.at_op("}"):
            if self.peek()[0] == "eof":
                raise ParseError("unterminated VALUES block (missing '}')")
            if grouped:
                self.expect_op("(")
                row: list[int | None] = []
                while not self.at_op(")"):
                    if self.peek()[0] == "eof":
                        raise ParseError("unterminated VALUES row "
                                         "(missing ')')")
                    row.append(self._values_cell())
                self.next()
                if len(row) != len(vars_):
                    raise ParseError(
                        f"VALUES row has {len(row)} terms for "
                        f"{len(vars_)} variables")
                rows.append(tuple(row))
            else:
                rows.append((self._values_cell(),))
        self.next()
        return ("values", vars_, rows)

    def _values_cell(self) -> int | None:
        if self.at_keyword("undef"):
            self.next()
            return None
        kind, txt = self.next()
        term = self._expand(kind, txt)
        if not self.d.has_entity(term):
            raise ParseError(f"unknown entity {term!r} in VALUES")
        return self.d.entity_id(term)

    # -- UPDATE grammar -----------------------------------------------------
    def parse_update(self) -> ParsedUpdate:
        """``PREFIX* (INSERT DATA | DELETE DATA | DELETE WHERE) { ... }``."""
        self.parse_prologue()
        if self.at_keyword("insert"):
            self.next()
            self.expect_keyword("data")
            kind = "insert_data"
        elif self.at_keyword("delete"):
            self.next()
            if self.at_keyword("data"):
                self.next()
                kind = "delete_data"
            elif self.at_keyword("where"):
                self.next()
                kind = "delete_where"
            else:
                raise ParseError("DELETE needs DATA { ... } or WHERE { ... }")
        else:
            raise ParseError("not an update (INSERT DATA / DELETE DATA / "
                             "DELETE WHERE)")
        triples = self.parse_data_block(allow_vars=(kind == "delete_where"))
        if self.peek()[0] != "eof":
            raise ParseError(f"trailing tokens: {self.peek()[1]!r}")
        if kind == "delete_where" and not triples:
            raise ParseError("DELETE WHERE needs at least one triple pattern")
        return ParsedUpdate(kind=kind, triples=triples)

    def parse_data_block(self, allow_vars: bool) -> list[tuple]:
        """``{ (term term term .)* }`` — terms stay prefix-expanded strings
        (no dictionary resolution: INSERT DATA may mint new terms)."""
        self.expect_op("{")
        triples: list[tuple] = []
        while True:
            if self.at_op("}"):
                self.next()
                return triples
            if self.peek()[0] == "eof":
                raise ParseError("unterminated data block (missing '}')")
            if self.at_op("."):
                self.next()         # triple separator (also allowed trailing)
                continue
            trip = []
            for _ in ("s", "p", "o"):
                kind, txt = self.next()
                if kind == "var":
                    if not allow_vars:
                        raise ParseError(
                            f"variables not allowed in ground data: {txt!r}")
                    trip.append(("var", txt))
                else:
                    trip.append(("term", self._expand(kind, txt)))
            triples.append(tuple(trip))

    # -- FILTER expressions -------------------------------------------------
    def parse_filter_expr(self):
        """``FILTER`` body: parenthesized expression or bare function call."""
        if self.at_op("("):
            self.next()
            e = self.parse_or()
            self.expect_op(")")
            return e
        if self.at_keyword("bound", "regex"):
            return self.parse_primary()
        raise ParseError("FILTER needs (expr), BOUND(...), or REGEX(...)")

    def parse_or(self):
        args = [self.parse_and()]
        while self.at_op("||"):
            self.next()
            args.append(self.parse_and())
        return args[0] if len(args) == 1 else OrExpr(tuple(args))

    def parse_and(self):
        args = [self.parse_unary()]
        while self.at_op("&&"):
            self.next()
            args.append(self.parse_unary())
        return args[0] if len(args) == 1 else AndExpr(tuple(args))

    def parse_unary(self):
        if self.at_op("!"):
            self.next()
            return NotExpr(self.parse_unary())
        return self.parse_primary()

    def parse_primary(self):
        if self.at_op("("):
            self.next()
            e = self.parse_or()
            self.expect_op(")")
            return e
        if self.at_keyword("bound"):
            self.next()
            self.expect_op("(")
            kind, var = self.next()
            if kind != "var":
                raise ParseError("BOUND takes a ?var")
            self.expect_op(")")
            return BoundExpr(var)
        if self.at_keyword("regex"):
            self.next()
            self.expect_op("(")
            kind, var = self.next()
            if kind != "var":
                raise ParseError("REGEX takes a ?var first")
            self.expect_op(",")
            pkind, ptxt = self.next()
            if pkind != "string":
                raise ParseError("REGEX pattern must be a string literal")
            flags = ""
            if self.at_op(","):
                self.next()
                fkind, ftxt = self.next()
                if fkind != "string":
                    raise ParseError("REGEX flags must be a string literal")
                flags = _unquote(ftxt)
            self.expect_op(")")
            return RegexExpr(var, _unquote(ptxt), flags)
        lhs = self.parse_operand()
        if self.at_op("=", "!=", "<", "<=", ">", ">="):
            op = self.next()[1]
            rhs = self.parse_operand()
            return Comparison(op, lhs, rhs)
        raise ParseError("bare FILTER operand is not a boolean expression")

    def parse_operand(self) -> Operand:
        kind, txt = self.next()
        if kind == "var":
            return Operand("var", txt)
        if kind in ("iri", "string", "num", "pname"):
            term = self._expand(kind, txt)
            return Operand(
                "term", term,
                ent_id=(self.d.entity_id(term)
                        if self.d.has_entity(term) else None),
                pred_id=(self.d.predicate_id(term)
                         if self.d.has_predicate(term) else None))
        raise ParseError(f"bad FILTER operand {txt!r}")


def parse_query(text: str, dictionary: Dictionary) -> ParsedQuery:
    """Parse the full supported SPARQL grammar into a :class:`ParsedQuery`.

    Constants in *triple* positions must exist in the dictionary (a query
    mentioning an unknown entity has no matches anywhere; the system routes
    on encoded ids) — unknown constants raise :class:`ParseError`. FILTER
    constants may be unknown (they compare by decoded term).
    Compile the result with :func:`repro.sparql.algebra.compile_query`, or
    use :class:`repro.sparql.endpoint.SparqlEndpoint` for the whole
    parse -> compile -> execute pipeline.
    """
    parsed = _Parser(text, dictionary).parse()
    parsed.text = text
    return parsed


_UPDATE_HEAD_RE = re.compile(
    r"^\s*(?:prefix\s+[A-Za-z_]\w*:[\w\-.]*\s*<[^<>\s]*>\s*)*(insert|delete)"
    r"\b", re.IGNORECASE)


def is_update_text(text: str) -> bool:
    """Cheap syntactic router: does ``text`` start an UPDATE request
    (after an optional PREFIX prologue) rather than a query?"""
    return _UPDATE_HEAD_RE.match(text) is not None


def parse_update(text: str, dictionary: Dictionary) -> ParsedUpdate:
    """Parse ``INSERT DATA`` / ``DELETE DATA`` / ``DELETE WHERE`` into a
    :class:`ParsedUpdate`.

    Constants are kept as prefix-expanded term *strings* — unlike query
    parsing, no dictionary lookup happens here, because ``INSERT DATA``
    legitimately mentions terms the dictionary has never seen. Resolution
    (and version bumps for new terms) happens in
    :func:`repro.sparql.update.compile_update`.
    """
    p = _Parser(text, dictionary)
    parsed = p.parse_update()
    parsed.text = text
    return parsed


def parse_sparql(text: str, dictionary: Dictionary) -> QueryGraph:
    """Parse a plain BGP SELECT query (paper Def. 2) into a `QueryGraph`.

    This is the stable entry point of the original BGP-only engine — kept as
    a thin shim over :func:`parse_query`. Algebra constructs (FILTER /
    OPTIONAL / UNION / DISTINCT / ORDER BY / LIMIT / OFFSET / ASK) raise
    :class:`ParseError` here; route those through
    :class:`repro.sparql.endpoint.SparqlEndpoint` (or
    ``parse_query`` + ``repro.sparql.algebra.compile_query``).
    """
    parsed = parse_query(text, dictionary)
    if parsed.form == "select" and not parsed.where.elements:
        raise ParseError("empty WHERE block")
    if not parsed.is_plain_bgp_select():
        raise ParseError(
            "not a plain BGP SELECT query — algebra features (FILTER/"
            "OPTIONAL/UNION/DISTINCT/ORDER BY/LIMIT/OFFSET/ASK) need "
            "parse_query + repro.sparql.algebra, or SparqlEndpoint")
    # is_plain_bgp_select guarantees exactly one non-empty "bgp" element
    return QueryGraph(patterns=list(parsed.where.elements[0][1]),
                      projection=list(parsed.projection))
