"""SPARQL algebra: logical operator trees compiled onto the BGP engine.

The paper's system (and everything built in PRs 1-4) executes *basic graph
patterns* — the Def.-2 subset. Real SPARQL engines layer an algebra on top
(Ali et al.'s survey of RDF stores; Perez/Arenas/Gutierrez's semantics):
FILTER selection, OPTIONAL left-joins, UNION, projection, DISTINCT, and
solution modifiers. This module adds that layer **without touching the hot
path**: a query compiles to a small operator tree whose leaves are whole
BGPs, each leaf executes through :class:`repro.sparql.engine.QueryEngine`
(shard-parallel scans, scan/plan/result LRUs), and the operators combine
leaf binding tables with vectorized NumPy joins.

Operator tree (:func:`compile_query` lowers a
:class:`repro.sparql.query.ParsedQuery`):

- :class:`BGPNode` — one BGP match per leaf. Leaves are executed *batched*
  (:func:`evaluate_many` collects every leaf of every query into ONE
  ``engine.execute_batch`` call), so alpha-equivalent sub-BGPs across
  queries share result-cache entries and identical scans dedup exactly as
  plain BGP batches do.
- :class:`JoinNode` / :class:`OptionalNode` — SPARQL compatibility
  (natural) join / left-join, vectorized as a sort/``searchsorted``
  equi-join over composite keys; rows with unbound shared variables
  (possible under nested OPTIONAL / UNION) join per bound-mask group.
- :class:`UnionNode` — column-aligned concatenation (multiset union).
- :class:`ValuesNode` — an inline solution table (``VALUES``): the parsed
  binding rows become a constant :class:`SolutionTable` (``UNDEF`` cells
  are :data:`UNBOUND`) joined into its group through the same vectorized
  compatibility join as any other operand — an UNDEF cell is compatible
  with every binding, exactly the bound-mask group-join semantics below.
- :class:`FilterNode` — vectorized row mask from the expression AST
  (:class:`~repro.sparql.query.Comparison` / ``BOUND`` / ``REGEX`` /
  boolean connectives) over dictionary-decoded terms.
- :class:`ProjectNode`, :class:`DistinctNode`, :class:`OrderSliceNode`,
  :class:`AskNode` — solution modifiers and the ASK form.

**Semantics.** Solutions are the homomorphism multisets of the leaf BGPs
combined per Perez et al.'s compatibility semantics, with the documented
simplifications of the *well-designed* fragment: a FILTER inside an
OPTIONAL group applies to the optional side before the left-join, and
error-valued FILTER comparisons (unbound operands, type-mixed order
comparisons) evaluate to plain ``False`` (two-valued logic). Term order for
``< <= > >=`` and ORDER BY is numeric when both terms parse as numbers,
lexicographic otherwise, with unbound sorting first. A brute-force
reference evaluator in ``tests/test_algebra.py`` pins every operator
against these rules on both backends and both store kinds.

**Unbound cells.** Binding tables are dense ``int64`` with
:data:`UNBOUND` (= -1) marking cells OPTIONAL / UNION left unbound —
dictionary ids are non-negative, so the sentinel can never collide.

**Edge feasibility** is per-leaf: :func:`repro.core.pattern.
feasibility_patterns` certifies a tree edge-executable iff every *required*
BGP leaf's pattern is resident (OPTIONAL right sides excluded — they can
only add bindings, and an edge lacking them returns fewer optional
bindings, a documented relaxation; parity tests deploy optional leaves
too). The scheduler then routes algebra queries exactly like BGPs.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from ..rdf.dictionary import Dictionary
from ..rdf.graph import RDFStore
from .matcher import MatchCapacityError, MatchResult
from .query import (AndExpr, BoundExpr, Comparison, GroupPattern, NotExpr,
                    Operand, OrExpr, ParseError, ParsedQuery, QueryGraph,
                    RegexExpr, TriplePattern)

UNBOUND = np.int64(-1)

_NUM_RE = re.compile(r"-?\d+(\.\d+)?\Z")


def _term_key(term: str):
    """Total order on decoded terms: numerals numerically first, then
    strings lexicographically (SPARQL's numeric/string split without the
    spec's full type ladder)."""
    if _NUM_RE.match(term):
        return (0, float(term), term)
    return (1, term)


# ---------------------------------------------------------------------------
# solution tables
# ---------------------------------------------------------------------------


@dataclass
class SolutionTable:
    """A SPARQL solution multiset: named columns of dictionary ids.

    ``bindings`` is ``[R, V]`` int64 with :data:`UNBOUND` for cells a
    solution does not bind. ``pred_vars`` names the variables bound in
    predicate-id space (everything else decodes as an entity).
    ``dictionary`` (when attached by the evaluator) enables term decoding.
    Duck-types the :class:`~repro.sparql.matcher.MatchResult` surface the
    servers' cost accounting consumes (``num_matches``, ``result_bytes``).
    """

    var_names: list[str]
    bindings: np.ndarray
    pred_vars: frozenset = frozenset()
    dictionary: Dictionary | None = None

    @property
    def num_matches(self) -> int:
        return int(self.bindings.shape[0])

    def __len__(self) -> int:
        return self.num_matches

    def column(self, var: str) -> np.ndarray:
        return self.bindings[:, self.var_names.index(var)]

    def result_bytes(self, projection: list[str] | None = None) -> int:
        """Modeled result size: 8 bytes per binding cell (the table is
        already projected, so the argument is accepted only for
        :class:`MatchResult` signature compatibility)."""
        r, v = self.bindings.shape
        return int(r * max(1, v) * 8)

    def decode_term(self, var: str, vid: int) -> str | None:
        if vid < 0:
            return None
        if self.dictionary is None:
            raise ValueError("SolutionTable has no dictionary attached")
        return (self.dictionary.predicate(int(vid)) if var in self.pred_vars
                else self.dictionary.entity(int(vid)))

    def rows(self, decoded: bool = True) -> list[tuple]:
        """Solution rows in ``var_names`` order; unbound cells are ``None``
        when decoding, :data:`UNBOUND` otherwise."""
        if not decoded:
            return [tuple(int(x) for x in row) for row in self.bindings]
        cols = [self._decoded_column(v) for v in self.var_names]
        return list(zip(*cols)) if cols else [()] * self.num_matches

    def _decoded_column(self, var: str) -> list[str | None]:
        ids = self.column(var)
        uniq, inv = np.unique(ids, return_inverse=True)
        terms = [self.decode_term(var, int(u)) for u in uniq]
        return [terms[i] for i in inv]

    def take(self, idx: np.ndarray) -> "SolutionTable":
        return SolutionTable(self.var_names, self.bindings[idx],
                             self.pred_vars, self.dictionary)


def _unit_table() -> SolutionTable:
    return SolutionTable([], np.zeros((1, 0), dtype=np.int64))


def _from_match(res: MatchResult, pred_vars: frozenset) -> SolutionTable:
    # cached MatchResult buffers are shared read-only; SolutionTable
    # operations only ever index into them (never write in place)
    return SolutionTable(list(res.var_names), res.bindings, pred_vars)


# ---------------------------------------------------------------------------
# operator tree
# ---------------------------------------------------------------------------


class Node:
    """Base operator. ``projection`` on the root mirrors
    ``QueryGraph.projection`` so servers account result bytes uniformly
    (unannotated on purpose: it must not become a dataclass field)."""

    projection = ()

    def children(self) -> list["Node"]:
        return []

    def bgp_leaves(self, required_only: bool = False) -> list["BGPNode"]:
        """Leaf BGPs in evaluation order. ``required_only`` drops leaves
        under OPTIONAL right sides — the ones edge feasibility must not
        depend on (they only ever extend solutions)."""
        out: list[BGPNode] = []
        self._collect(out, required_only)
        return out

    def _collect(self, out: list, required_only: bool) -> None:
        for c in self.children():
            c._collect(out, required_only)

    def label(self) -> str:
        return type(self).__name__


@dataclass
class BGPNode(Node):
    """One BGP leaf — matched via the shard-parallel engine pipeline."""

    query: QueryGraph

    def children(self) -> list[Node]:
        return []

    def _collect(self, out: list, required_only: bool) -> None:
        out.append(self)

    @property
    def patterns(self) -> list[TriplePattern]:
        return self.query.patterns

    def label(self) -> str:
        return (f"BGP({len(self.patterns)} patterns, "
                f"vars={' '.join(self.query.variables) or '-'})")


@dataclass
class JoinNode(Node):
    left: Node
    right: Node

    def children(self) -> list[Node]:
        return [self.left, self.right]

    def label(self) -> str:
        return "Join"


@dataclass
class OptionalNode(Node):
    """SPARQL left-join: keep every left solution, extend where the right
    side matches compatibly."""

    left: Node
    right: Node

    def children(self) -> list[Node]:
        return [self.left, self.right]

    def _collect(self, out: list, required_only: bool) -> None:
        self.left._collect(out, required_only)
        if not required_only:
            self.right._collect(out, required_only)

    def label(self) -> str:
        return "Optional (left-join)"


@dataclass
class UnionNode(Node):
    branches: list[Node]

    def children(self) -> list[Node]:
        return list(self.branches)

    def label(self) -> str:
        return f"Union({len(self.branches)} branches)"


@dataclass
class ValuesNode(Node):
    """Inline bindings (``VALUES``): a constant solution multiset.

    ``rows`` is ``[R, V]`` int64 over ``var_names`` with :data:`UNBOUND`
    for ``UNDEF`` cells. Not a BGP leaf: it never reaches the engine, so
    edge feasibility ignores it (the inline table is part of the plan and
    travels with it to whichever server executes)."""

    var_names: list[str]
    rows: np.ndarray

    def children(self) -> list[Node]:
        return []

    def label(self) -> str:
        return (f"Values([{' '.join(self.var_names)}], "
                f"{len(self.rows)} rows)")


@dataclass
class FilterNode(Node):
    child: Node
    expr: object

    def children(self) -> list[Node]:
        return [self.child]

    def label(self) -> str:
        return f"Filter {format_expr(self.expr)}"


@dataclass
class ProjectNode(Node):
    child: Node
    projection: list[str]

    def children(self) -> list[Node]:
        return [self.child]

    def label(self) -> str:
        return f"Project [{' '.join(self.projection) or '*'}]"


@dataclass
class DistinctNode(Node):
    """Dedup on ``on`` columns (``None`` = all), keeping first occurrence.

    Compiled *below* the final projection with ``on`` = the projection
    list, which is exactly SELECT DISTINCT's semantics."""

    child: Node
    on: list[str] | None = None

    def children(self) -> list[Node]:
        return [self.child]

    def label(self) -> str:
        return f"Distinct on=[{' '.join(self.on) if self.on else '*'}]"


@dataclass
class OrderSliceNode(Node):
    """ORDER BY + LIMIT/OFFSET (order applied first, then the slice)."""

    child: Node
    order: list[tuple[str, bool]] = field(default_factory=list)
    limit: int | None = None
    offset: int = 0

    def children(self) -> list[Node]:
        return [self.child]

    def label(self) -> str:
        keys = " ".join(f"{v}{'' if asc else ' DESC'}"
                        for v, asc in self.order)
        parts = [p for p in (
            f"order=[{keys}]" if self.order else "",
            f"limit={self.limit}" if self.limit is not None else "",
            f"offset={self.offset}" if self.offset else "") if p]
        return f"OrderSlice {' '.join(parts) or '(noop)'}"


@dataclass
class AskNode(Node):
    """ASK form: evaluates to a 0/1-row zero-column table (truthiness)."""

    child: Node

    def children(self) -> list[Node]:
        return [self.child]

    def label(self) -> str:
        return "Ask"


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------


def compile_query(parsed: ParsedQuery,
                  dictionary: Dictionary | None = None) -> Node:
    """Lower a :class:`~repro.sparql.query.ParsedQuery` to an operator tree.

    Pipeline (inside-out): WHERE group -> DISTINCT (on the projection) ->
    ORDER BY + LIMIT/OFFSET -> projection (or ASK). The returned root
    carries ``dictionary`` (FILTER/ORDER term decoding), ``parsed``, and
    ``projection`` so it travels self-contained through servers and pools.
    """
    node = _compile_group(parsed.where)
    if parsed.form == "ask":
        root: Node = AskNode(node)
    else:
        if parsed.distinct:
            node = DistinctNode(node, list(parsed.projection) or None)
        if parsed.order_by or parsed.limit is not None or parsed.offset:
            node = OrderSliceNode(node, list(parsed.order_by),
                                  parsed.limit, parsed.offset)
        root = ProjectNode(node, list(parsed.projection))
    root.dictionary = dictionary
    root.parsed = parsed
    ent_vars: set[str] = set()
    pred_vars: set[str] = set()
    for leaf in root.bgp_leaves():
        for tp in leaf.patterns:
            for t in (tp.s, tp.o):
                if isinstance(t, str):
                    ent_vars.add(t)
            if isinstance(tp.p, str):
                pred_vars.add(tp.p)
    # VALUES cells are resolved in entity-id space at parse time, so their
    # variables are entity-space by construction
    for vn in _values_nodes(root):
        ent_vars.update(vn.var_names)
    mixed = ent_vars & pred_vars
    if mixed:
        # entity and predicate ids live in disjoint spaces; a column mixing
        # them cannot be decoded (FILTER/ORDER/rows would read the wrong
        # dictionary) — reject at compile time instead of mis-decoding
        raise ParseError(
            f"variable(s) {sorted(mixed)} appear in both predicate and "
            f"subject/object positions — unsupported (dictionary id spaces "
            f"are disjoint)")
    root.pred_vars = frozenset(pred_vars)
    return root


def _values_nodes(node: Node) -> list["ValuesNode"]:
    out = [node] if isinstance(node, ValuesNode) else []
    for c in node.children():
        out += _values_nodes(c)
    return out


def _compile_group(g: GroupPattern) -> Node:
    node: Node | None = None
    filters: list = []

    def join(a: Node | None, b: Node) -> Node:
        return b if a is None else JoinNode(a, b)

    for el in g.elements:
        tag = el[0]
        if tag == "bgp":
            node = join(node, BGPNode(QueryGraph(patterns=list(el[1]),
                                                 projection=[])))
        elif tag == "filter":
            filters.append(el[1])
        elif tag == "optional":
            left = node if node is not None else BGPNode(QueryGraph([], []))
            node = OptionalNode(left, _compile_group(el[1]))
        elif tag == "union":
            node = join(node, UnionNode([_compile_group(b) for b in el[1]]))
        elif tag == "group":
            node = join(node, _compile_group(el[1]))
        elif tag == "values":
            var_names, raw = el[1], el[2]
            rows = np.full((len(raw), len(var_names)), UNBOUND,
                           dtype=np.int64)
            for i, row in enumerate(raw):
                for j, cell in enumerate(row):
                    if cell is not None:
                        rows[i, j] = cell
            node = join(node, ValuesNode(list(var_names), rows))
        else:  # pragma: no cover - parser emits only the tags above
            raise ValueError(f"unknown group element {tag!r}")
    if node is None:
        node = BGPNode(QueryGraph([], []))
    for f in filters:
        node = FilterNode(node, f)
    return node


def is_algebra_plan(q) -> bool:
    """True for compiled operator trees (vs plain :class:`QueryGraph`)."""
    return isinstance(q, Node)


# ---------------------------------------------------------------------------
# vectorized joins
# ---------------------------------------------------------------------------


def _equi_pairs(lk: np.ndarray, rk: np.ndarray, budget: int,
                ) -> tuple[np.ndarray, np.ndarray]:
    """(left_idx, right_idx) of all key-equal pairs; composite keys are
    encoded to dense codes via one ``np.unique`` over both sides, then
    expanded with a sorted ``searchsorted`` probe. ``budget`` caps the
    produced pairs (:class:`MatchCapacityError` beyond it)."""
    nl, nr = len(lk), len(rk)
    if nl == 0 or nr == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy()
    if lk.shape[1] == 0:               # no join columns: full product
        total = nl * nr
        if total > budget:
            raise MatchCapacityError(f"join would produce {total} rows")
        return (np.repeat(np.arange(nl, dtype=np.int64), nr),
                np.tile(np.arange(nr, dtype=np.int64), nl))
    _, inv = np.unique(np.concatenate([lk, rk]), axis=0, return_inverse=True)
    lcode, rcode = inv[:nl], inv[nl:]
    order = np.argsort(rcode, kind="stable")
    rsorted = rcode[order]
    lo = np.searchsorted(rsorted, lcode, side="left")
    hi = np.searchsorted(rsorted, lcode, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total > budget:
        raise MatchCapacityError(f"join would produce {total} rows")
    if not total:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy()
    li = np.repeat(np.arange(nl, dtype=np.int64), counts)
    within = (np.arange(total, dtype=np.int64)
              - np.repeat(np.cumsum(counts) - counts, counts))
    return li, order[np.repeat(lo, counts) + within]


def _join_tables(L: SolutionTable, R: SolutionTable, how: str,
                 max_rows: int) -> SolutionTable:
    """Compatibility (natural) join of two solution tables.

    ``how``: ``"inner"`` (Join) or ``"left"`` (Optional / left-join).
    Shared variables join by equality over cells bound on BOTH sides; a
    cell unbound on one side is compatible with anything and the merged row
    takes the bound value (Perez et al.'s compatibility). Fully-bound
    inputs (the common case — BGP leaves bind everything) take a single
    vectorized equi-join; otherwise rows group by their bound-mask pattern
    and each group pair joins on its mutually-bound columns.
    """
    shared = [v for v in L.var_names if v in R.var_names]
    right_only = [v for v in R.var_names if v not in L.var_names]
    li_idx = [L.var_names.index(v) for v in shared]
    ri_idx = [R.var_names.index(v) for v in shared]
    ro_idx = [R.var_names.index(v) for v in right_only]
    lk_all = L.bindings[:, li_idx]
    rk_all = R.bindings[:, ri_idx]
    lmask = lk_all != UNBOUND
    rmask = rk_all != UNBOUND

    if lmask.all() and rmask.all():
        li, ri = _equi_pairs(lk_all, rk_all, max_rows)
        fill = False
    else:
        # group rows by bound-mask pattern; for each (left, right) group
        # pair join on the columns bound in BOTH masks — the remaining
        # shared columns are unbound on one side, hence compatible
        lpat, linv = np.unique(lmask, axis=0, return_inverse=True)
        rpat, rinv = np.unique(rmask, axis=0, return_inverse=True)
        lis: list[np.ndarray] = []
        ris: list[np.ndarray] = []
        budget = max_rows
        for a in range(len(lpat)):
            lrows = np.flatnonzero(linv == a)
            for b in range(len(rpat)):
                rrows = np.flatnonzero(rinv == b)
                both = lpat[a] & rpat[b]
                gl, gr = _equi_pairs(lk_all[lrows][:, both],
                                     rk_all[rrows][:, both], budget)
                budget = max(budget - len(gl), 0)
                lis.append(lrows[gl])
                ris.append(rrows[gr])
        li = (np.concatenate(lis) if lis
              else np.zeros(0, dtype=np.int64))
        ri = (np.concatenate(ris) if ris
              else np.zeros(0, dtype=np.int64))
        fill = True

    out_vars = L.var_names + right_only
    blocks = [L.bindings[li]]
    if ro_idx:
        blocks.append(R.bindings[ri][:, ro_idx])
    out = np.concatenate(blocks, axis=1) if len(blocks) > 1 else blocks[0]
    if out.base is not None or out is L.bindings:
        out = out.copy()               # cached leaf buffers are read-only
    if fill and shared:
        # shared cells unbound on the left take the right side's binding
        for ci, rci in zip(range(len(shared)), ri_idx):
            col = out[:, li_idx[ci]]
            need = col == UNBOUND
            if need.any():
                col[need] = R.bindings[ri[need], rci]

    if how == "left":
        matched = np.zeros(len(L.bindings), dtype=bool)
        matched[li] = True
        rest = np.flatnonzero(~matched)
        if len(rest):
            pad = np.full((len(rest), len(right_only)), UNBOUND,
                          dtype=np.int64)
            lone = np.concatenate([L.bindings[rest], pad], axis=1)
            out = np.concatenate([out, lone], axis=0)
    return SolutionTable(out_vars, out, L.pred_vars | R.pred_vars,
                         L.dictionary or R.dictionary)


def _union_tables(tables: list[SolutionTable]) -> SolutionTable:
    var_names: list[str] = []
    for t in tables:
        for v in t.var_names:
            if v not in var_names:
                var_names.append(v)
    blocks = []
    for t in tables:
        block = np.full((t.num_matches, len(var_names)), UNBOUND,
                        dtype=np.int64)
        for j, v in enumerate(var_names):
            if v in t.var_names:
                block[:, j] = t.column(v)
        blocks.append(block)
    out = (np.concatenate(blocks, axis=0) if blocks
           else np.zeros((0, len(var_names)), dtype=np.int64))
    pv = frozenset().union(*(t.pred_vars for t in tables))
    d = next((t.dictionary for t in tables if t.dictionary is not None), None)
    return SolutionTable(var_names, out, pv, d)


# ---------------------------------------------------------------------------
# FILTER expression evaluation (vectorized)
# ---------------------------------------------------------------------------


def _decode_uniques(uniq: np.ndarray, space: str,
                    d: Dictionary) -> list[str | None]:
    return [None if u < 0
            else (d.predicate(int(u)) if space == "pred"
                  else d.entity(int(u)))
            for u in uniq]


def _operand_info(op: Operand, table: SolutionTable):
    """-> ("var", ids, bound_mask, space) | ("const", term, id_in_space)."""
    if op.kind == "var":
        if op.value not in table.var_names:
            r = table.num_matches
            return ("var", np.full(r, UNBOUND), np.zeros(r, dtype=bool), "ent")
        ids = table.column(op.value)
        space = "pred" if op.value in table.pred_vars else "ent"
        return ("var", ids, ids != UNBOUND, space)
    return ("const", op.value, None)


_CMP = {"=": lambda a, b: a == b, "!=": lambda a, b: a != b,
        "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
        ">": lambda a, b: a > b, ">=": lambda a, b: a >= b}


def compare_terms(op: str, a: str, b: str) -> bool:
    """Scalar comparison over decoded terms (the single definition both the
    vectorized evaluator and the tests' brute-force reference use)."""
    if op in ("=", "!="):
        return _CMP[op](a, b)
    return _CMP[op](_term_key(a), _term_key(b))


def _eval_comparison(c: Comparison, table: SolutionTable,
                     d: Dictionary | None) -> np.ndarray:
    r = table.num_matches
    left = _operand_info(c.lhs, table)
    right = _operand_info(c.rhs, table)
    if left[0] == "const" and right[0] == "const":
        return np.full(r, compare_terms(c.op, left[1], right[1]))

    if left[0] == "const":             # normalize: variable on the left
        flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}
        c = Comparison(flip.get(c.op, c.op), c.rhs, c.lhs)
        left, right = right, left

    _, ids, bound, space = left
    if right[0] == "const":
        term = right[1]
        if c.op in ("=", "!="):
            # id fast path: dictionary encoding is bijective per space
            cid = (c.rhs.pred_id if space == "pred" else c.rhs.ent_id)
            if cid is None:            # unknown constant: no bound id equals
                return (bound & False) if c.op == "=" else bound.copy()
            eq = ids == cid
            return (eq & bound) if c.op == "=" else (~eq & bound)
        if d is None:
            raise ValueError("order comparison needs a dictionary")
        uniq, inv = np.unique(ids, return_inverse=True)
        terms = _decode_uniques(uniq, space, d)
        per = np.array([False if t is None else compare_terms(c.op, t, term)
                        for t in terms], dtype=bool)
        return per[inv] & bound
    _, rids, rbound, rspace = right
    both = bound & rbound
    if space == rspace:
        if c.op in ("=", "!="):
            eq = ids == rids
            return (eq & both) if c.op == "=" else (~eq & both)
        if d is None:
            raise ValueError("order comparison needs a dictionary")
        # rank both columns' ids on ONE term-key order, then compare the
        # int ranks vectorized (term keys are injective per space, so rank
        # order == term order); unbound rows are masked by ``both``
        allu = np.unique(np.concatenate([ids, rids]))
        keys = [(0,) if t is None else (1, _term_key(t))
                for t in _decode_uniques(allu, space, d)]
        rank = np.empty(len(allu), dtype=np.int64)
        rank[sorted(range(len(allu)), key=keys.__getitem__)] = \
            np.arange(len(allu))
        lrank = rank[np.searchsorted(allu, ids)]
        rrank = rank[np.searchsorted(allu, rids)]
        return _CMP[c.op](lrank, rrank) & both
    if d is None:
        raise ValueError("cross-space comparison needs a dictionary")
    lu, li = np.unique(ids, return_inverse=True)
    ru_, ri = np.unique(rids, return_inverse=True)
    lt = _decode_uniques(lu, space, d)
    rt = _decode_uniques(ru_, rspace, d)
    return np.fromiter(
        (bool(b) and compare_terms(c.op, lt[a1], rt[b1])
         for a1, b1, b in zip(li, ri, both)), dtype=bool, count=r)


def eval_expr_mask(expr, table: SolutionTable,
                   d: Dictionary | None) -> np.ndarray:
    """Row mask for a FILTER expression (two-valued: errors are False)."""
    r = table.num_matches
    if isinstance(expr, Comparison):
        return _eval_comparison(expr, table, d)
    if isinstance(expr, BoundExpr):
        if expr.var not in table.var_names:
            return np.zeros(r, dtype=bool)
        return table.column(expr.var) != UNBOUND
    if isinstance(expr, RegexExpr):
        if expr.var not in table.var_names:
            return np.zeros(r, dtype=bool)
        if d is None:
            raise ValueError("REGEX needs a dictionary")
        ids = table.column(expr.var)
        space = "pred" if expr.var in table.pred_vars else "ent"
        flags = re.IGNORECASE if "i" in expr.flags else 0
        rx = re.compile(expr.pattern, flags)
        uniq, inv = np.unique(ids, return_inverse=True)
        per = np.array([t is not None and rx.search(t) is not None
                        for t in _decode_uniques(uniq, space, d)],
                       dtype=bool)
        return per[inv]
    if isinstance(expr, NotExpr):
        return ~eval_expr_mask(expr.arg, table, d)
    if isinstance(expr, AndExpr):
        m = eval_expr_mask(expr.args[0], table, d)
        for a in expr.args[1:]:
            m = m & eval_expr_mask(a, table, d)
        return m
    if isinstance(expr, OrExpr):
        m = eval_expr_mask(expr.args[0], table, d)
        for a in expr.args[1:]:
            m = m | eval_expr_mask(a, table, d)
        return m
    raise TypeError(f"unknown FILTER expression {expr!r}")


def format_expr(expr) -> str:
    if isinstance(expr, Comparison):
        def f(o: Operand) -> str:
            return o.value if o.kind == "var" else repr(o.value)
        return f"({f(expr.lhs)} {expr.op} {f(expr.rhs)})"
    if isinstance(expr, BoundExpr):
        return f"BOUND({expr.var})"
    if isinstance(expr, RegexExpr):
        fl = f", {expr.flags!r}" if expr.flags else ""
        return f"REGEX({expr.var}, {expr.pattern!r}{fl})"
    if isinstance(expr, NotExpr):
        return f"!{format_expr(expr.arg)}"
    if isinstance(expr, AndExpr):
        return "(" + " && ".join(format_expr(a) for a in expr.args) + ")"
    if isinstance(expr, OrExpr):
        return "(" + " || ".join(format_expr(a) for a in expr.args) + ")"
    return repr(expr)


# ---------------------------------------------------------------------------
# solution modifiers
# ---------------------------------------------------------------------------


def _order_table(table: SolutionTable, keys: list[tuple[str, bool]],
                 d: Dictionary | None) -> SolutionTable:
    if not keys or table.num_matches <= 1:
        return table
    if d is None:
        raise ValueError("ORDER BY needs a dictionary")
    ranks = []
    for var, asc in keys:
        if var not in table.var_names:
            continue                   # constant key: no effect
        ids = table.column(var)
        space = "pred" if var in table.pred_vars else "ent"
        uniq, inv = np.unique(ids, return_inverse=True)
        terms = _decode_uniques(uniq, space, d)
        order = sorted(range(len(uniq)),
                       key=lambda i: ((0,) if terms[i] is None
                                      else (1, _term_key(terms[i]))))
        rank = np.empty(len(uniq), dtype=np.int64)
        rank[order] = np.arange(len(uniq))
        col = rank[inv]
        ranks.append(col if asc else -col)
    if not ranks:
        return table
    idx = np.lexsort(tuple(reversed(ranks)))   # first key = primary
    return table.take(idx)


def _distinct_table(table: SolutionTable,
                    on: list[str] | None) -> SolutionTable:
    cols = [v for v in (on or table.var_names) if v in table.var_names]
    if table.num_matches <= 1:
        return table
    sub = (table.bindings[:, [table.var_names.index(v) for v in cols]]
           if cols else np.zeros((table.num_matches, 0), dtype=np.int64))
    if sub.shape[1] == 0:
        return table.take(np.zeros(1, dtype=np.int64))
    _, first = np.unique(sub, axis=0, return_index=True)
    return table.take(np.sort(first))


def _project_table(table: SolutionTable,
                   projection: list[str]) -> SolutionTable:
    if not projection:
        return table
    r = table.num_matches
    cols = []
    for v in projection:
        cols.append(table.column(v) if v in table.var_names
                    else np.full(r, UNBOUND))
    out = (np.stack(cols, axis=1) if cols
           else np.zeros((r, 0), dtype=np.int64))
    return SolutionTable(list(projection), out, table.pred_vars,
                         table.dictionary)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------


def _eval(node: Node, leaf_results: dict[int, MatchResult], engine,
          d: Dictionary | None, pred_vars: frozenset,
          max_rows: int) -> SolutionTable:
    if isinstance(node, BGPNode):
        if not node.patterns:
            t = _unit_table()
        else:
            t = _from_match(leaf_results[id(node)], pred_vars)
        t.dictionary = d
        return t
    if isinstance(node, ValuesNode):
        if engine is not None:
            engine.bump_stats(values_joins=1)
        return SolutionTable(list(node.var_names), node.rows,
                             dictionary=d)
    if isinstance(node, JoinNode):
        return _join_tables(
            _eval(node.left, leaf_results, engine, d, pred_vars, max_rows),
            _eval(node.right, leaf_results, engine, d, pred_vars, max_rows),
            "inner", max_rows)
    if isinstance(node, OptionalNode):
        out = _join_tables(
            _eval(node.left, leaf_results, engine, d, pred_vars, max_rows),
            _eval(node.right, leaf_results, engine, d, pred_vars, max_rows),
            "left", max_rows)
        if engine is not None:
            engine.bump_stats(optional_joins=1)
        return out
    if isinstance(node, UnionNode):
        tabs = [_eval(b, leaf_results, engine, d, pred_vars, max_rows)
                for b in node.branches]
        if engine is not None:
            engine.bump_stats(union_branches=len(tabs))
        return _union_tables(tabs)
    if isinstance(node, FilterNode):
        t = _eval(node.child, leaf_results, engine, d, pred_vars, max_rows)
        if engine is not None:
            engine.bump_stats(filters_applied=1)
        return t.take(np.flatnonzero(eval_expr_mask(node.expr, t, d)))
    if isinstance(node, ProjectNode):
        return _project_table(
            _eval(node.child, leaf_results, engine, d, pred_vars, max_rows),
            node.projection)
    if isinstance(node, DistinctNode):
        return _distinct_table(
            _eval(node.child, leaf_results, engine, d, pred_vars, max_rows),
            node.on)
    if isinstance(node, OrderSliceNode):
        t = _order_table(
            _eval(node.child, leaf_results, engine, d, pred_vars, max_rows),
            node.order, d)
        lo = max(0, node.offset)
        hi = None if node.limit is None else lo + max(0, node.limit)
        return t.take(np.arange(t.num_matches)[lo:hi])
    if isinstance(node, AskNode):
        t = _eval(node.child, leaf_results, engine, d, pred_vars, max_rows)
        n = 1 if t.num_matches else 0
        return SolutionTable([], np.zeros((n, 0), dtype=np.int64),
                             dictionary=d)
    raise TypeError(f"unknown algebra node {node!r}")


def evaluate_many(roots: list[Node], store: RDFStore, engine,
                  max_rows: int | None = None) -> list[SolutionTable]:
    """Evaluate compiled plans against ``store``; results align by index.

    ALL leaf BGPs across the batch execute as ONE
    ``engine.execute_batch`` call — identical scans dedup across queries
    and alpha-equivalent sub-BGPs share result-cache entries exactly like
    plain BGP batches (the core cache-reuse property of the algebra
    layer). The all-plans special case of :func:`execute_any_batch`.
    """
    return execute_any_batch(store, engine, roots, max_rows)


def evaluate_plan(root: Node, store: RDFStore, engine,
                  max_rows: int | None = None) -> SolutionTable:
    """Evaluate one compiled plan (see :func:`evaluate_many`)."""
    return evaluate_many([root], store, engine, max_rows)[0]


def execute_any_batch(store: RDFStore, engine, queries: list,
                      max_rows: int | None = None) -> list:
    """Execute a mixed batch of plain :class:`QueryGraph`\\ s and compiled
    algebra plans; results align by index (``MatchResult`` for BGPs,
    :class:`SolutionTable` for plans).

    Plain BGPs and every plan's leaf BGPs go through ONE
    ``engine.execute_batch`` call, so scan dedup and result-cache sharing
    span the whole mixed batch — this is what the servers
    (:mod:`repro.edge.server`) and the serving pool runner
    (:func:`repro.runtime.serving.make_sparql_runner`) call.
    """
    plans = [(i, q) for i, q in enumerate(queries) if is_algebra_plan(q)]
    plain = [(i, q) for i, q in enumerate(queries) if not is_algebra_plan(q)]
    leaves: list[BGPNode] = []
    for _, root in plans:
        leaves += [l for l in root.bgp_leaves() if l.patterns]
    batch = [q for _, q in plain] + [l.query for l in leaves]
    results = engine.execute_batch(store, batch) if batch else []
    if leaves:
        engine.bump_stats(bgp_leaves=len(leaves))
    out: list = [None] * len(queries)
    for (i, _), res in zip(plain, results[:len(plain)]):
        out[i] = res
    lookup = dict(zip(map(id, leaves), results[len(plain):]))
    cap = int(max_rows if max_rows is not None
              else getattr(engine, "max_rows", 5_000_000))
    for i, root in plans:
        d = getattr(root, "dictionary", None)
        pv = getattr(root, "pred_vars", frozenset())
        out[i] = _eval(root, lookup, engine, d, pv, cap)
    return out


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------


def explain_plan(root: Node, store: RDFStore | None = None,
                 engine=None) -> str:
    """Pretty-print an operator tree; with ``store`` + ``engine``, each BGP
    leaf line carries cache-hit provenance (result cache, scan LRU) and the
    estimated cardinality — what an admission layer reads before batching.
    """
    lines: list[str] = []

    def leaf_note(leaf: BGPNode) -> str:
        if store is None or not leaf.patterns:
            return ""
        bits = []
        from .matcher import estimate_pattern_cardinality
        est = max(estimate_pattern_cardinality(store, tp)
                  for tp in leaf.patterns)
        bits.append(f"est_rows<={est:.0f}")
        if engine is not None:
            probe = engine.cache_probe(store, leaf.query)
            hit = "hit" if probe["result_cached"] else "miss"
            bits.append(f"result-cache={hit}")
            bits.append(f"scans-cached={probe['scans_cached']}"
                        f"/{probe['scans_total']}")
        return "  [" + ", ".join(bits) + "]"

    def walk(node: Node, prefix: str, is_last: bool, is_root: bool) -> None:
        branch = "" if is_root else ("└─ " if is_last else "├─ ")
        note = leaf_note(node) if isinstance(node, BGPNode) else ""
        lines.append(prefix + branch + node.label() + note)
        kids = node.children()
        child_prefix = prefix if is_root else (
            prefix + ("   " if is_last else "│  "))
        for i, c in enumerate(kids):
            walk(c, child_prefix, i == len(kids) - 1, False)

    walk(root, "", True, True)
    return "\n".join(lines)
