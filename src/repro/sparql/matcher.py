"""Vectorized BGP homomorphism matching over any :class:`RDFStore`.

This is the query engine that runs on both the cloud and the edge servers
(the paper uses Neptune / gStore; see DESIGN.md §3 for why we re-express
matching as data-parallel binding-table joins for a TPU-native system).
In the full-SPARQL stack this matcher is the **leaf executor**: the
algebra layer (:mod:`repro.sparql.algebra`) compiles FILTER / OPTIONAL /
UNION / modifier queries to operator trees whose BGP leaves each run one
:func:`match_bgp` through the batched engine.

Algorithm: greedy selectivity-ordered left-deep join, planned by
:func:`plan_bgp`:

1. estimate cardinality of every triple pattern from per-predicate stats;
2. start from the most selective pattern, then repeatedly join in the
   connected pattern with the lowest estimated cost;
3. each join is a sort/``searchsorted`` equi-join on one shared variable
   (a vertex variable when one is bound, else a bound *predicate*
   variable), followed by equality masks for any other shared components.

**Shard-parallel joins.** Candidate scans arrive as
:class:`CandidateParts` — partition-disjoint global-id arrays, one per
touched shard of a :class:`repro.rdf.sharding.ShardedTripleStore` (a
monolithic store is a single partition). An equi-join distributes over any
partition of the probe side, so each partition is sorted and probed
*shard-locally* and the partial binding tables are merged only afterwards —
merging happens exactly at variable-predicate / cross-shard joins, since a
bound-predicate pattern's candidates always live in one shard
(predicate-hash partitioning). Bound-predicate patterns whose subject and
object are both unconstrained variables skip the scan + per-join sort
entirely and probe the owning shard's cached :class:`~repro.rdf.graph.
PredIndex` sorted views (``plan_bgp`` marks these steps
``use_pred_index``); the sort is built once per (shard, predicate) and
amortized across every query in the workload.

**Capacity.** ``max_rows`` bounds the *surviving* (post-equality-mask) rows
of each join: the expansion is processed in chunks of at most ``max_rows``
pre-mask rows, so a join whose raw fan-out is huge but whose true result is
small no longer raises :class:`MatchCapacityError`.

The per-pattern *candidate scan* (predicate slice + constant masks) is exactly
what the ``triple_scan`` Pallas kernel accelerates on TPU; the NumPy path here
is the portable implementation with identical semantics. The matcher only
touches the :class:`repro.rdf.graph.RDFStore` accessor surface (global triple
ids), so it runs unchanged over the monolithic :class:`TripleStore` or the
sharded :class:`repro.rdf.sharding.ShardedTripleStore`.

Semantics: SPARQL BGP solutions = homomorphisms (paper Def. 3). Variables may
map to the same vertex; a variable predicate matches any edge label. Each
solution row binds every variable and records the matched triple (edge) id per
pattern — the latter feeds pattern-induced subgraph construction (Def. 5).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..rdf.graph import RDFStore
from .query import QueryGraph, TriplePattern


class MatchCapacityError(RuntimeError):
    """Raised when an intermediate binding table exceeds the row cap."""


class CandidateParts:
    """Partition-disjoint candidate triple ids for one pattern scan.

    ``parts`` holds one global-id array per touched shard (a monolithic
    store contributes a single partition). Partitions are disjoint by
    construction — a triple id lives in exactly one shard — which is what
    makes the per-partition (shard-local) equi-join sound: the join
    distributes over any partition of the probe side, and the partial
    binding tables are simply concatenated.
    """

    __slots__ = ("parts",)

    def __init__(self, parts) -> None:
        self.parts: list[np.ndarray] = [
            np.asarray(p, dtype=np.int64) for p in parts if len(p)]

    @classmethod
    def of(cls, cand) -> "CandidateParts":
        """Normalize a plain tid array (legacy scan result) to one part."""
        return cand if isinstance(cand, cls) else cls([cand])

    @property
    def nbytes(self) -> int:
        return sum(int(p.nbytes) for p in self.parts)

    @property
    def total(self) -> int:
        return sum(len(p) for p in self.parts)

    def concat(self) -> np.ndarray:
        if not self.parts:
            return np.zeros(0, dtype=np.int64)
        if len(self.parts) == 1:
            return self.parts[0]
        return np.concatenate(self.parts)

    def shifted(self, delta: int) -> "CandidateParts":
        """A copy with every id shifted by ``delta`` (``0`` returns self).

        Lets the engine's scan LRU store bound-predicate candidates in
        shard-LOCAL coordinates keyed by the owning shard's version: a
        placement delta to another shard moves this shard's global-id
        offset but not its content, and the hit is re-lifted here.
        """
        if not delta:
            return self
        out = CandidateParts.__new__(CandidateParts)
        out.parts = [p + delta for p in self.parts]
        return out

    def __len__(self) -> int:  # pragma: no cover - convenience
        return self.total


@dataclass
class JoinStats:
    """Per-phase join-pipeline counters (surfaced via ``EngineStats.join``).

    ``joins_pred_index``: shard-local presorted equi-joins (no scan, no
    per-join sort — the owning shard's cached ``PredIndex`` is probed).
    ``joins_vertex``: generic sorted equi-joins on a bound vertex variable.
    ``joins_pred_var``: equi-joins on a bound *predicate* variable (the path
    that used to fall through to a cartesian expansion).
    ``joins_cartesian``: seed expansions + genuinely disconnected products.
    ``partitions_probed``: candidate partitions probed across all joins.
    ``merged_joins``: joins that merged >1 partition's partial bindings
    (variable-predicate / cross-shard joins on a sharded store).
    ``joins_device``: presorted joins executed by the device-resident
    pipeline (:mod:`repro.sparql.device_join`) through the
    ``probe_sorted`` / ``scan_probe`` Pallas kernels instead of host
    ``searchsorted``; every such join ALSO counts in ``joins_pred_index``
    (it is the same plan step), so host/device runs agree on every other
    counter and ``joins_device`` isolates where the join ran.
    """

    joins_pred_index: int = 0
    joins_vertex: int = 0
    joins_pred_var: int = 0
    joins_cartesian: int = 0
    partitions_probed: int = 0
    merged_joins: int = 0
    joins_device: int = 0

    def merge(self, other: "JoinStats") -> None:
        self.joins_pred_index += other.joins_pred_index
        self.joins_vertex += other.joins_vertex
        self.joins_pred_var += other.joins_pred_var
        self.joins_cartesian += other.joins_cartesian
        self.partitions_probed += other.partitions_probed
        self.merged_joins += other.merged_joins
        self.joins_device += other.joins_device


@dataclass
class MatchResult:
    """All homomorphic matches of a query.

    ``var_names``: binding columns (vertex + predicate variables)
    ``bindings``:  [R, V] int64 — entity/predicate ids per solution
    ``edge_ids``:  [R, E] int64 — matched triple id per original pattern
    """

    var_names: list[str]
    bindings: np.ndarray
    edge_ids: np.ndarray

    @property
    def num_matches(self) -> int:
        return self.bindings.shape[0]

    def column(self, var: str) -> np.ndarray:
        return self.bindings[:, self.var_names.index(var)]

    def project(self, projection: list[str]) -> np.ndarray:
        """[R, len(projection)] solution table (SPARQL multiset semantics)."""
        if not projection:
            return self.bindings
        idx = [self.var_names.index(v) for v in projection]
        return self.bindings[:, idx]

    def result_bytes(self, projection: list[str]) -> int:
        """Modeled result size w_n: 8 bytes per projected binding cell."""
        proj = self.project(projection)
        return int(proj.shape[0] * max(1, proj.shape[1]) * 8)


def estimate_pattern_cardinality(store: RDFStore, tp: TriplePattern) -> float:
    """Selectivity-style cardinality estimate (Stocker et al., WWW'08)."""
    if isinstance(tp.p, int):
        n = float(store.pred_count[tp.p])
        ds = max(1.0, float(store.pred_distinct_s[tp.p]))
        do = max(1.0, float(store.pred_distinct_o[tp.p]))
    else:
        n = float(store.num_triples)
        ds = max(1.0, float(np.mean(store.pred_distinct_s))
                 if store.num_predicates else 1.0)
        do = max(1.0, float(np.mean(store.pred_distinct_o))
                 if store.num_predicates else 1.0)
    if isinstance(tp.s, int):
        n /= ds
    if isinstance(tp.o, int):
        n /= do
    return max(n, 0.0)


def _candidates(store: RDFStore, tp: TriplePattern) -> np.ndarray:
    """Triple ids satisfying the constant components of ``tp``."""
    if isinstance(tp.p, int):
        tids = store.pred_tids(tp.p)
    else:
        tids = np.arange(store.num_triples, dtype=np.int64)
    if isinstance(tp.s, int):
        tids = tids[store.s[tids] == tp.s]
    if isinstance(tp.o, int):
        tids = tids[store.o[tids] == tp.o]
    # intra-pattern repeated variables, e.g. (?x, p, ?x) or (?x, ?x, ?y)
    if (isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o):
        tids = tids[store.s[tids] == store.o[tids]]
    if (isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p):
        tids = tids[store.s[tids] == store.p[tids]]
    if (isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p):
        tids = tids[store.o[tids] == store.p[tids]]
    return tids


def _order_patterns(store: RDFStore, q: QueryGraph) -> list[int]:
    """Greedy selectivity-ordered, connectivity-respecting pattern order."""
    n = len(q.patterns)
    est = [estimate_pattern_cardinality(store, tp) for tp in q.patterns]
    bound: set[str] = set()
    remaining = set(range(n))
    order: list[int] = []
    while remaining:
        def key(i: int) -> tuple:
            tp = q.patterns[i]
            shared = sum(1 for v in tp.variables() if v in bound)
            connected = 1 if (shared > 0 or not order) else 0
            return (-connected, -shared, est[i], i)
        pick = min(remaining, key=key)
        order.append(pick)
        remaining.remove(pick)
        bound.update(q.patterns[pick].variables())
    return order


@dataclass(frozen=True)
class JoinStep:
    """One planned step of the left-deep join pipeline.

    ``kind``: ``"seed"`` (first pattern / unit-table expansion),
    ``"vertex"`` (equi-join on a bound vertex variable), ``"pred"``
    (equi-join on a bound predicate variable), or ``"cartesian"``
    (disconnected component — no shared bound variable at all).
    ``use_pred_index``: the step probes the owning shard's cached
    ``PredIndex`` sorted views instead of scanning + sorting candidates;
    such steps never request a candidate scan (``needs_scan`` is False).
    ``device_probe``: the step is additionally *device-capable* — a
    ``use_pred_index`` join whose other endpoint is still unbound at this
    step, so no equality masks apply and the whole join is expressible as
    the ``probe_sorted`` kernel + XLA expansion. Backends without device
    residency (numpy, or jax with ``device_resident=False``) simply ignore
    the flag and run the step on the host — the transparent fallback.
    """

    pattern: int
    kind: str
    use_pred_index: bool = False
    device_probe: bool = False

    @property
    def needs_scan(self) -> bool:
        return not self.use_pred_index


def plan_bgp(store: RDFStore, q: QueryGraph,
             shard_local: bool = True) -> list[JoinStep]:
    """Join plan for ``q``: pattern order + join kind per step.

    Walks :func:`_order_patterns` tracking the bound-variable set, so the
    engine can know *before execution* which patterns will request a
    candidate scan (``JoinStep.needs_scan``) and which will take the
    shard-local presorted ``pred_index`` path. ``shard_local=False`` disables
    the presorted path (every step scans + sorts globally) — the baseline
    mode benchmarked by ``bench_engine.py --join``.
    """
    steps: list[JoinStep] = []
    bound: set[str] = set()
    for j, i in enumerate(_order_patterns(store, q)):
        tp = q.patterns[i]
        svar = tp.s if isinstance(tp.s, str) else None
        ovar = tp.o if isinstance(tp.o, str) else None
        pvar = tp.p if isinstance(tp.p, str) else None
        dp = False
        if j == 0:
            kind, upi = "seed", False
        elif svar in bound or ovar in bound:
            kind = "vertex"
            # presorted shard-local join: candidates are exactly the owning
            # shard's predicate slice (no constants, no repeated variables)
            upi = (shard_local and isinstance(tp.p, int)
                   and svar is not None and ovar is not None
                   and svar != ovar)
            # device-capable when exactly one endpoint is bound: no
            # equality masks, so probe + expansion covers the whole join
            dp = upi and not (svar in bound and ovar in bound)
        elif pvar in bound:
            kind, upi = "pred", False
        else:
            kind, upi = "cartesian", False
        steps.append(JoinStep(pattern=i, kind=kind, use_pred_index=upi,
                              device_probe=dp))
        bound.update(tp.variables())
    return steps


def _probe_partitions(views, tvals, checks, max_rows: int,
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Sorted-partition ``searchsorted`` probe with chunked expansion.

    ``views``: [(keys_sorted, tids_in_key_order)] — one per candidate
    partition (shard). ``tvals``: the binding column being joined.
    ``checks``: [(store_column, binding_column_values)] equality masks for
    other already-bound components, applied *per chunk* so ``max_rows``
    bounds the surviving rows, not the raw pre-mask fan-out. Returns
    (row_idx, sel_tid) of the merged partial joins.
    """
    out_rows: list[np.ndarray] = []
    out_tids: list[np.ndarray] = []
    kept = 0
    R = len(tvals)
    chunk_cap = max(int(max_rows), 1)

    def emit(row_idx: np.ndarray, sel: np.ndarray) -> None:
        nonlocal kept
        mask = None
        for col, bvals in checks:
            m = col[sel] == bvals[row_idx]
            mask = m if mask is None else (mask & m)
        if mask is not None and not mask.all():
            row_idx, sel = row_idx[mask], sel[mask]
        kept += len(sel)
        if kept > max_rows:
            raise MatchCapacityError(
                f"join would keep more than {max_rows} rows")
        if len(sel):
            out_rows.append(row_idx)
            out_tids.append(sel)

    for keys, stids in views:
        lo = np.searchsorted(keys, tvals, side="left")
        hi = np.searchsorted(keys, tvals, side="right")
        counts = hi - lo
        cum = np.cumsum(counts)
        if not len(cum) or not cum[-1]:
            continue
        r0 = 0
        while r0 < R:
            base = int(cum[r0 - 1]) if r0 else 0
            r1 = int(np.searchsorted(cum, base + chunk_cap, side="right"))
            if r1 <= r0:
                # a single row's fan-out exceeds the cap: sub-chunk its
                # candidate range so peak memory stays ~chunk_cap rows
                lo_r, hi_r = int(lo[r0]), int(hi[r0])
                for c0 in range(lo_r, hi_r, chunk_cap):
                    sel = stids[c0:min(c0 + chunk_cap, hi_r)]
                    emit(np.full(len(sel), r0, dtype=np.int64), sel)
                r0 += 1
                continue
            c_counts = counts[r0:r1]
            c_total = int(cum[r1 - 1]) - base
            if c_total:
                row_idx = np.repeat(np.arange(r0, r1), c_counts)
                starts = np.repeat(lo[r0:r1], c_counts)
                within = (np.arange(c_total)
                          - np.repeat(np.cumsum(c_counts) - c_counts,
                                      c_counts))
                emit(row_idx, stids[starts + within])
            r0 = r1
    if not out_rows:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy()
    return np.concatenate(out_rows), np.concatenate(out_tids)


def match_bgp(store: RDFStore, q: QueryGraph,
              max_rows: int = 5_000_000,
              candidates=None, plan: list[JoinStep] | None = None,
              stats: JoinStats | None = None,
              shard_local: bool = True) -> MatchResult:
    """All homomorphic matches of ``q`` over ``store`` (paper Def. 3).

    ``candidates``: optional ``(store, tp) -> tids | CandidateParts``
    override for the per-pattern candidate scan — how
    :mod:`repro.sparql.engine` routes scans through a pluggable backend
    (NumPy slicing or the ``triple_scan`` Pallas kernel) and deduplicates
    them across a query batch. Must return exactly the triple ids
    :func:`_candidates` would (any order); a :class:`CandidateParts` keeps
    per-shard partitions so the join runs shard-locally and merges partial
    binding tables only at variable-predicate / cross-shard joins.

    ``plan``: precomputed :func:`plan_bgp` output (the engine passes it so
    planning isn't repeated); ``stats``: optional :class:`JoinStats` to
    increment; ``shard_local``: forwarded to :func:`plan_bgp` when planning
    here.
    """
    if candidates is None:
        candidates = _candidates
    if plan is None:
        plan = plan_bgp(store, q, shard_local=shard_local)
    var_names: list[str] = []
    bindings = np.zeros((1, 0), dtype=np.int64)   # one empty row = unit table
    edge_cols: dict[int, np.ndarray] = {}

    for step in plan:
        pat_i = step.pattern
        tp = q.patterns[pat_i]
        svar = tp.s if isinstance(tp.s, str) else None
        ovar = tp.o if isinstance(tp.o, str) else None
        pvar = tp.p if isinstance(tp.p, str) else None
        s_bound = svar is not None and svar in var_names
        o_bound = ovar is not None and ovar in var_names
        p_bound = pvar is not None and pvar in var_names

        R = bindings.shape[0]
        if s_bound or o_bound:
            # ---- equi-join on a bound vertex variable ----------------------
            join_on_s = s_bound
            joinvar = svar if join_on_s else ovar
            tvals = bindings[:, var_names.index(joinvar)]
            if step.use_pred_index:
                # shard-local presorted join: probe the owning shard's
                # cached PredIndex — no scan, no per-join argsort
                idx = store.pred_index(tp.p)
                views = [(idx.s_sorted, idx.s_order) if join_on_s
                         else (idx.o_sorted, idx.o_order)]
                if stats is not None:
                    stats.joins_pred_index += 1
            else:
                parts = CandidateParts.of(candidates(store, tp))
                key_arr = store.s if join_on_s else store.o
                views = []
                for ptids in parts.parts:
                    kv = key_arr[ptids]
                    order_ = np.argsort(kv, kind="stable")
                    views.append((kv[order_], ptids[order_]))
                if stats is not None:
                    stats.joins_vertex += 1
                    stats.merged_joins += len(views) > 1
            checks = []
            if s_bound and o_bound:
                # joined on s above -> o must still agree with its binding
                checks.append((store.o, bindings[:, var_names.index(ovar)]))
            if p_bound:
                checks.append((store.p, bindings[:, var_names.index(pvar)]))
            if stats is not None:
                stats.partitions_probed += len(views)
            row_idx, sel_tid = _probe_partitions(views, tvals, checks,
                                                 max_rows)
        elif p_bound:
            # ---- equi-join on a bound PREDICATE variable -------------------
            # (used to fall through to the cartesian branch and could raise
            # MatchCapacityError on the pre-mask R*C count even when the true
            # result was tiny)
            tvals = bindings[:, var_names.index(pvar)]
            parts = CandidateParts.of(candidates(store, tp))
            views = []
            for ptids in parts.parts:
                kv = store.p[ptids]
                order_ = np.argsort(kv, kind="stable")
                views.append((kv[order_], ptids[order_]))
            if stats is not None:
                stats.joins_pred_var += 1
                stats.partitions_probed += len(views)
                stats.merged_joins += len(views) > 1
            row_idx, sel_tid = _probe_partitions(views, tvals, [], max_rows)
        else:
            # ---- no shared bound variable: cartesian expansion -------------
            # (no equality masks can apply here, so the pre-expansion count
            # IS the surviving count and the capacity check is exact)
            cand = CandidateParts.of(candidates(store, tp)).concat()
            C = len(cand)
            total = R * C
            if total > max_rows:
                raise MatchCapacityError(
                    f"cartesian would produce {total} rows")
            row_idx = np.repeat(np.arange(R), C)
            sel_tid = np.tile(cand, R)
            if stats is not None:
                stats.joins_cartesian += 1
                stats.partitions_probed += 1

        sel_s, sel_p, sel_o = (store.s[sel_tid], store.p[sel_tid],
                               store.o[sel_tid])
        new_bind = bindings[row_idx]

        # ---- append new variable columns -----------------------------------
        add_cols: list[np.ndarray] = []
        for varname, vals, already in (
                (svar, sel_s, s_bound), (ovar, sel_o, o_bound),
                (pvar, sel_p, p_bound)):
            if (varname is not None and not already
                    and varname not in var_names):
                var_names.append(varname)
                add_cols.append(vals)
            # (?x p ?x) with ?x new: candidates pre-filtered to s==o and the
            # column was added on the s pass, so the o pass lands here.
        bindings = (np.concatenate([new_bind] + [c[:, None] for c in add_cols],
                                   axis=1)
                    if add_cols else new_bind)
        # previously matched patterns' edge columns follow the expansion
        for k in list(edge_cols):
            edge_cols[k] = edge_cols[k][row_idx]
        edge_cols[pat_i] = sel_tid

    E = len(q.patterns)
    R = bindings.shape[0]
    edge_ids = np.zeros((R, E), dtype=np.int64)
    for i in range(E):
        edge_ids[:, i] = edge_cols[i]
    return MatchResult(var_names=var_names, bindings=bindings,
                       edge_ids=edge_ids)


# ---------------------------------------------------------------------------
# Oracle: naive backtracking matcher (tests only)
# ---------------------------------------------------------------------------

def match_oracle(store: RDFStore, q: QueryGraph) -> tuple[set[tuple], list[str]]:
    """Exponential-time reference matcher (tests only).

    Returns ``(solutions, var_order)`` where each solution is a tuple of
    bindings in ``var_order``. Compare against ``match_bgp`` as sets after
    reordering columns by variable name."""
    vs = q.variables
    triples = store.triples()

    out: set[tuple] = set()

    def rec(i: int, env: dict[str, int]) -> None:
        if i == len(q.patterns):
            out.add(tuple(env[v] for v in vs))
            return
        tp = q.patterns[i]
        for (s, p, o) in triples:
            def unify(term, val, env):
                if isinstance(term, int):
                    return env if term == val else None
                if term in env:
                    return env if env[term] == val else None
                e2 = dict(env)
                e2[term] = int(val)
                return e2
            e = unify(tp.s, s, env)
            if e is None:
                continue
            e = unify(tp.p, p, e)
            if e is None:
                continue
            e = unify(tp.o, o, e)
            if e is None:
                continue
            rec(i + 1, e)

    rec(0, {})
    return out, vs
