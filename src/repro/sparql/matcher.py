"""Vectorized BGP homomorphism matching over any :class:`RDFStore`.

This is the query engine that runs on both the cloud and the edge servers
(the paper uses Neptune / gStore; see DESIGN.md §3 for why we re-express
matching as data-parallel binding-table joins for a TPU-native system).

Algorithm: greedy selectivity-ordered left-deep join.

1. estimate cardinality of every triple pattern from per-predicate stats;
2. start from the most selective pattern, then repeatedly join in the
   connected pattern with the lowest estimated cost;
3. each join is a sort/``searchsorted`` equi-join on one shared vertex
   variable, followed by equality masks for any other shared components.

The per-pattern *candidate scan* (predicate slice + constant masks) is exactly
what the ``triple_scan`` Pallas kernel accelerates on TPU; the NumPy path here
is the portable implementation with identical semantics. The matcher only
touches the :class:`repro.rdf.graph.RDFStore` accessor surface (global triple
ids), so it runs unchanged over the monolithic :class:`TripleStore` or the
sharded :class:`repro.rdf.sharding.ShardedTripleStore` — on a sharded store,
``pred_tids`` already prunes a bound-predicate scan to the one shard owning
that predicate.

Semantics: SPARQL BGP solutions = homomorphisms (paper Def. 3). Variables may
map to the same vertex; a variable predicate matches any edge label. Each
solution row binds every variable and records the matched triple (edge) id per
pattern — the latter feeds pattern-induced subgraph construction (Def. 5).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..rdf.graph import RDFStore
from .query import QueryGraph, TriplePattern


class MatchCapacityError(RuntimeError):
    """Raised when an intermediate binding table exceeds the row cap."""


@dataclass
class MatchResult:
    """All homomorphic matches of a query.

    ``var_names``: binding columns (vertex + predicate variables)
    ``bindings``:  [R, V] int64 — entity/predicate ids per solution
    ``edge_ids``:  [R, E] int64 — matched triple id per original pattern
    """

    var_names: list[str]
    bindings: np.ndarray
    edge_ids: np.ndarray

    @property
    def num_matches(self) -> int:
        return self.bindings.shape[0]

    def column(self, var: str) -> np.ndarray:
        return self.bindings[:, self.var_names.index(var)]

    def project(self, projection: list[str]) -> np.ndarray:
        """[R, len(projection)] solution table (SPARQL multiset semantics)."""
        if not projection:
            return self.bindings
        idx = [self.var_names.index(v) for v in projection]
        return self.bindings[:, idx]

    def result_bytes(self, projection: list[str]) -> int:
        """Modeled result size w_n: 8 bytes per projected binding cell."""
        proj = self.project(projection)
        return int(proj.shape[0] * max(1, proj.shape[1]) * 8)


def estimate_pattern_cardinality(store: RDFStore, tp: TriplePattern) -> float:
    """Selectivity-style cardinality estimate (Stocker et al., WWW'08)."""
    if isinstance(tp.p, int):
        n = float(store.pred_count[tp.p])
        ds = max(1.0, float(store.pred_distinct_s[tp.p]))
        do = max(1.0, float(store.pred_distinct_o[tp.p]))
    else:
        n = float(store.num_triples)
        ds = max(1.0, float(np.mean(store.pred_distinct_s))
                 if store.num_predicates else 1.0)
        do = max(1.0, float(np.mean(store.pred_distinct_o))
                 if store.num_predicates else 1.0)
    if isinstance(tp.s, int):
        n /= ds
    if isinstance(tp.o, int):
        n /= do
    return max(n, 0.0)


def _candidates(store: RDFStore, tp: TriplePattern) -> np.ndarray:
    """Triple ids satisfying the constant components of ``tp``."""
    if isinstance(tp.p, int):
        tids = store.pred_tids(tp.p)
    else:
        tids = np.arange(store.num_triples, dtype=np.int64)
    if isinstance(tp.s, int):
        tids = tids[store.s[tids] == tp.s]
    if isinstance(tp.o, int):
        tids = tids[store.o[tids] == tp.o]
    # intra-pattern repeated variables, e.g. (?x, p, ?x) or (?x, ?x, ?y)
    if (isinstance(tp.s, str) and isinstance(tp.o, str) and tp.s == tp.o):
        tids = tids[store.s[tids] == store.o[tids]]
    if (isinstance(tp.s, str) and isinstance(tp.p, str) and tp.s == tp.p):
        tids = tids[store.s[tids] == store.p[tids]]
    if (isinstance(tp.o, str) and isinstance(tp.p, str) and tp.o == tp.p):
        tids = tids[store.o[tids] == store.p[tids]]
    return tids


def _order_patterns(store: RDFStore, q: QueryGraph) -> list[int]:
    """Greedy selectivity-ordered, connectivity-respecting pattern order."""
    n = len(q.patterns)
    est = [estimate_pattern_cardinality(store, tp) for tp in q.patterns]
    bound: set[str] = set()
    remaining = set(range(n))
    order: list[int] = []
    while remaining:
        def key(i: int) -> tuple:
            tp = q.patterns[i]
            shared = sum(1 for v in tp.variables() if v in bound)
            connected = 1 if (shared > 0 or not order) else 0
            return (-connected, -shared, est[i], i)
        pick = min(remaining, key=key)
        order.append(pick)
        remaining.remove(pick)
        bound.update(q.patterns[pick].variables())
    return order


def match_bgp(store: RDFStore, q: QueryGraph,
              max_rows: int = 5_000_000,
              candidates=None) -> MatchResult:
    """All homomorphic matches of ``q`` over ``store`` (paper Def. 3).

    ``candidates``: optional ``(store, tp) -> tids`` override for the
    per-pattern candidate scan — how :mod:`repro.sparql.engine` routes scans
    through a pluggable backend (NumPy slicing or the ``triple_scan`` Pallas
    kernel) and deduplicates them across a query batch. Must return exactly
    the triple ids :func:`_candidates` would (any order).
    """
    if candidates is None:
        candidates = _candidates
    order = _order_patterns(store, q)
    var_names: list[str] = []
    bindings = np.zeros((1, 0), dtype=np.int64)   # one empty row = unit table
    edge_cols: dict[int, np.ndarray] = {}

    for pat_i in order:
        tp = q.patterns[pat_i]
        cand = candidates(store, tp)
        cs, cp, co = store.s[cand], store.p[cand], store.o[cand]

        svar = tp.s if isinstance(tp.s, str) else None
        ovar = tp.o if isinstance(tp.o, str) else None
        pvar = tp.p if isinstance(tp.p, str) else None
        s_bound = svar is not None and svar in var_names
        o_bound = ovar is not None and ovar in var_names
        p_bound = pvar is not None and pvar in var_names

        R = bindings.shape[0]
        # ---- choose the join key (prefer a bound vertex var) --------------
        if s_bound or o_bound:
            join_on_s = s_bound
            keyvals = cs if join_on_s else co
            joinvar = svar if join_on_s else ovar
            key_sorted_idx = np.argsort(keyvals, kind="stable")
            keys = keyvals[key_sorted_idx]
            tvals = bindings[:, var_names.index(joinvar)]
            lo = np.searchsorted(keys, tvals, side="left")
            hi = np.searchsorted(keys, tvals, side="right")
            counts = hi - lo
            total = int(counts.sum())
            if total > max_rows:
                raise MatchCapacityError(f"join would produce {total} rows")
            row_idx = np.repeat(np.arange(R), counts)
            # offsets within each row's candidate range
            starts = np.repeat(lo, counts)
            within = (np.arange(total)
                      - np.repeat(np.cumsum(counts) - counts, counts))
            cand_rows = key_sorted_idx[starts + within]
        else:
            # no shared vertex variable: cartesian expansion
            C = len(cand)
            total = R * C
            if total > max_rows:
                raise MatchCapacityError(f"cartesian would produce {total} rows")
            row_idx = np.repeat(np.arange(R), C)
            cand_rows = np.tile(np.arange(C), R)

        sel_s, sel_p, sel_o = cs[cand_rows], cp[cand_rows], co[cand_rows]
        sel_tid = cand[cand_rows]
        new_bind = bindings[row_idx]

        # ---- equality masks for other already-bound components -------------
        mask = np.ones(len(row_idx), dtype=bool)
        if s_bound and o_bound:
            # joined on s above -> still need o to agree with its binding
            mask &= sel_o == new_bind[:, var_names.index(ovar)]
        if p_bound:
            mask &= sel_p == new_bind[:, var_names.index(pvar)]
        if not mask.all():
            new_bind = new_bind[mask]
            sel_s, sel_p, sel_o = sel_s[mask], sel_p[mask], sel_o[mask]
            sel_tid = sel_tid[mask]
            row_idx = row_idx[mask]

        # ---- append new variable columns -----------------------------------
        add_cols: list[np.ndarray] = []
        for varname, vals, already in (
                (svar, sel_s, s_bound), (ovar, sel_o, o_bound),
                (pvar, sel_p, p_bound)):
            if (varname is not None and not already
                    and varname not in var_names):
                var_names.append(varname)
                add_cols.append(vals)
            # (?x p ?x) with ?x new: candidates pre-filtered to s==o and the
            # column was added on the s pass, so the o pass lands here.
        bindings = (np.concatenate([new_bind] + [c[:, None] for c in add_cols],
                                   axis=1)
                    if add_cols else new_bind)
        # previously matched patterns' edge columns follow the expansion
        for k in list(edge_cols):
            edge_cols[k] = edge_cols[k][row_idx]
        edge_cols[pat_i] = sel_tid

    E = len(q.patterns)
    R = bindings.shape[0]
    edge_ids = np.zeros((R, E), dtype=np.int64)
    for i in range(E):
        edge_ids[:, i] = edge_cols[i]
    return MatchResult(var_names=var_names, bindings=bindings,
                       edge_ids=edge_ids)


# ---------------------------------------------------------------------------
# Oracle: naive backtracking matcher (tests only)
# ---------------------------------------------------------------------------

def match_oracle(store: RDFStore, q: QueryGraph) -> tuple[set[tuple], list[str]]:
    """Exponential-time reference matcher (tests only).

    Returns ``(solutions, var_order)`` where each solution is a tuple of
    bindings in ``var_order``. Compare against ``match_bgp`` as sets after
    reordering columns by variable name."""
    vs = q.variables
    triples = store.triples()

    out: set[tuple] = set()

    def rec(i: int, env: dict[str, int]) -> None:
        if i == len(q.patterns):
            out.add(tuple(env[v] for v in vs))
            return
        tp = q.patterns[i]
        for (s, p, o) in triples:
            def unify(term, val, env):
                if isinstance(term, int):
                    return env if term == val else None
                if term in env:
                    return env if env[term] == val else None
                e2 = dict(env)
                e2[term] = int(val)
                return e2
            e = unify(tp.s, s, env)
            if e is None:
                continue
            e = unify(tp.p, p, e)
            if e is None:
                continue
            e = unify(tp.o, o, e)
            if e is None:
                continue
            rec(i + 1, e)

    rec(0, {})
    return out, vs
