"""SPARQL UPDATE compilation: parsed updates -> cloud-side triple deltas.

The write half of the live-ingest path. :func:`compile_update` takes a
:class:`repro.sparql.query.ParsedUpdate` (term *strings*, prefix-expanded)
and resolves it through the shared :class:`repro.rdf.dictionary.Dictionary`:

- ``INSERT DATA`` **encodes** — brand-new terms are minted (bumping
  ``Dictionary.version`` so plan memos keyed on it invalidate, see the
  endpoint);
- ``DELETE DATA`` **resolves** — a row mentioning a term the dictionary has
  never seen cannot exist in any store, so it is dropped as a no-op (counted
  in ``dropped_rows``, never an error: SPARQL UPDATE delete of absent data
  succeeds);
- ``DELETE WHERE`` compiles its template to a :class:`QueryGraph`; an
  unknown constant makes the template unsatisfiable, so the whole update
  degenerates to a no-op.

Ground forms turn into a version-guarded :class:`TripleDelta` against the
cloud store via :func:`ground_delta`. ``DELETE WHERE`` is evaluated at
*apply* time (under the system's placement lock) by
:func:`where_evict_rows`: the matched triples of the template BGP are
exactly the triples the update removes, and the matcher already reports the
matched edge id per pattern per solution row.

The single ingest path that applies these to a live system (shard routing,
induced-index carry-forward, edge propagation) is
``repro.edge.system.EdgeCloudSystem.apply_update``; a standalone endpoint
without a system applies the delta directly to its store.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..rdf.deltas import TripleDelta, as_rows, setdiff_rows
from ..rdf.dictionary import Dictionary
from .query import ParsedUpdate, ParseError, QueryGraph, TriplePattern


def _empty_rows() -> np.ndarray:
    return np.zeros((0, 3), dtype=np.int64)


@dataclass(frozen=True)
class CompiledUpdate:
    """A dictionary-resolved update, ready to apply to any store.

    ``add`` / ``evict`` are ground ``[N, 3]`` id rows (deduplicated); for
    ``delete_where``, ``where`` holds the template BGP and the ground arrays
    stay empty — the evict set is computed against the live store at apply
    time. ``new_terms`` counts dictionary terms minted (INSERT DATA only);
    ``dropped_rows`` counts ground delete rows discarded because a term was
    unknown (plus 1 for an unsatisfiable DELETE WHERE template).
    """

    kind: str
    add: np.ndarray = field(default_factory=_empty_rows)
    evict: np.ndarray = field(default_factory=_empty_rows)
    where: QueryGraph | None = None
    new_terms: int = 0
    dropped_rows: int = 0
    text: str = ""

    @property
    def is_ground(self) -> bool:
        return self.where is None

    @property
    def is_noop(self) -> bool:
        return (self.where is None and not len(self.add)
                and not len(self.evict))

    def touched_predicates(self) -> set[int] | None:
        """Predicate ids this update can possibly touch — the feasibility
        invalidation key for pattern memos (a pattern whose edge labels are
        all bound and disjoint from this set keeps its matches verbatim).
        ``None`` means "potentially every predicate" (a DELETE WHERE
        template with a variable predicate)."""
        pids: set[int] = set()
        for rows in (self.add, self.evict):
            if len(rows):
                pids.update(int(p) for p in np.unique(rows[:, 1]))
        if self.where is not None:
            for tp in self.where.patterns:
                if isinstance(tp.p, str):       # variable predicate: any
                    return None
                pids.add(int(tp.p))
        return pids


def _require_terms(triples: list[tuple], kind: str) -> None:
    for trip in triples:
        for tag, _ in trip:
            if tag != "term":
                raise ParseError(f"{kind} takes ground triples only")


def compile_update(parsed: ParsedUpdate,
                   dictionary: Dictionary) -> CompiledUpdate:
    """Resolve a parsed update through the dictionary (see module doc)."""
    kind = parsed.kind
    if kind == "insert_data":
        _require_terms(parsed.triples, "INSERT DATA")
        v0 = dictionary.version
        rows = [(dictionary.add_entity(s), dictionary.add_predicate(p),
                 dictionary.add_entity(o))
                for ((_, s), (_, p), (_, o)) in parsed.triples]
        add = (np.unique(as_rows(np.array(rows, dtype=np.int64)), axis=0)
               if rows else _empty_rows())
        return CompiledUpdate(kind=kind, add=add,
                              new_terms=dictionary.version - v0,
                              text=parsed.text)

    if kind == "delete_data":
        _require_terms(parsed.triples, "DELETE DATA")
        rows, dropped = [], 0
        for (_, s), (_, p), (_, o) in parsed.triples:
            if (dictionary.has_entity(s) and dictionary.has_predicate(p)
                    and dictionary.has_entity(o)):
                rows.append((dictionary.entity_id(s),
                             dictionary.predicate_id(p),
                             dictionary.entity_id(o)))
            else:
                dropped += 1            # unknown term: the row cannot exist
        evict = (np.unique(as_rows(np.array(rows, dtype=np.int64)), axis=0)
                 if rows else _empty_rows())
        return CompiledUpdate(kind=kind, evict=evict, dropped_rows=dropped,
                              text=parsed.text)

    if kind == "delete_where":
        pats: list[TriplePattern] = []
        for (stag, s), (ptag, p), (otag, o) in parsed.triples:
            if ptag == "term" and not dictionary.has_predicate(p):
                return CompiledUpdate(kind=kind, dropped_rows=1,
                                      text=parsed.text)
            for tag, t in ((stag, s), (otag, o)):
                if tag == "term" and not dictionary.has_entity(t):
                    return CompiledUpdate(kind=kind, dropped_rows=1,
                                          text=parsed.text)
            pats.append(TriplePattern(
                s=s if stag == "var" else dictionary.entity_id(s),
                p=p if ptag == "var" else dictionary.predicate_id(p),
                o=o if otag == "var" else dictionary.entity_id(o)))
        return CompiledUpdate(kind=kind,
                              where=QueryGraph(patterns=pats, projection=[]),
                              text=parsed.text)

    raise ParseError(f"unknown update kind {kind!r}")


def ground_delta(cu: CompiledUpdate, store) -> TripleDelta:
    """Version-guarded delta for a ground (data-form) update against
    ``store``'s current content: adds already present and evicts already
    absent are stripped so the delta stays minimal and invertible."""
    if cu.where is not None:
        raise ValueError("DELETE WHERE needs where_evict_rows at apply time")
    current = store.triples()
    return TripleDelta(base_version=store.version,
                       add=setdiff_rows(cu.add, current),
                       evict=cu.evict[_present_mask(cu.evict, current)])


def _present_mask(rows: np.ndarray, current: np.ndarray) -> np.ndarray:
    """Boolean mask of ``rows`` present in ``current`` (both [N, 3])."""
    from ..rdf.deltas import member_rows
    return member_rows(rows, current)


def where_evict_rows(cu: CompiledUpdate, store,
                     max_rows: int = 5_000_000) -> np.ndarray:
    """Evaluate a DELETE WHERE template against ``store`` and return the
    matched triple rows (the exact rows the update removes).

    Must run under whatever lock serializes the store (the system's
    placement lock): the matched edge ids are only meaningful against the
    version they were computed on.
    """
    from .matcher import match_bgp

    if cu.where is None:
        return _empty_rows()
    res = match_bgp(store, cu.where, max_rows=max_rows)
    if res.edge_ids.size == 0:
        return _empty_rows()
    eids = np.unique(res.edge_ids.reshape(-1))
    return np.stack([store.s[eids], store.p[eids], store.o[eids]], axis=1)
