"""`SparqlEndpoint` — the one-object public query API.

Before this layer, running a query meant hand-wiring ``Dictionary`` + store
+ ``QueryEngine`` (+ ``EdgeCloudSystem`` / ``OffloadServingPool``) and
speaking :class:`~repro.sparql.query.QueryGraph`. The endpoint packages
that pipeline behind the surface real SPARQL engines expose:

>>> ep = SparqlEndpoint(store, dictionary)          # or .from_system(sys_)
>>> ep.query('SELECT ?x WHERE { ?x <likes> ?p . FILTER (?p != "P0") }')
>>> ep.ask('ASK { ?x <subgenreOf> ?y }')
>>> print(ep.explain(text))                         # plan + cache provenance
>>> ep.query_many(texts)                            # one engine batch

Everything funnels through :mod:`repro.sparql.algebra`: queries compile to
operator trees whose BGP leaves run on the shard-parallel batched engine,
so the scan/plan/result LRUs, backend registry (``numpy`` / ``jax``), and
sharded stores all apply unchanged. Compiled plans are memoized per query
text (`plan_cache_size`), making repeated text queries parse-free.

Construction from the edge-cloud stack:

- :meth:`from_system` shares an :class:`~repro.edge.system.EdgeCloudSystem`'s
  cloud store and engine; :meth:`run_round` then parses per-user query texts
  and delegates to ``system.run_round_batched`` — algebra queries are
  B&B-scheduled onto edges via per-leaf pattern feasibility
  (:func:`repro.core.pattern.feasibility_patterns`) exactly like BGPs.
- ``pool=`` attaches an :class:`~repro.runtime.serving.OffloadServingPool`
  whose replicas serve compiled plans through
  :func:`~repro.runtime.serving.make_sparql_runner`; :meth:`admit_many`
  builds the admission batch from query texts.

The old entry points (``parse_sparql`` -> ``QueryGraph`` ->
``QueryEngine.execute``) remain as thin shims for the Def.-2 BGP subset;
new code should talk to the endpoint.
"""

from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

from ..rdf.dictionary import Dictionary
from ..rdf.graph import RDFStore
from .algebra import (AskNode, Node, SolutionTable, compile_query,
                      evaluate_many, explain_plan)
from .engine import EngineStats, QueryEngine
from .query import ParseError, parse_query


class SparqlEndpoint:
    """Unified SELECT/ASK endpoint over any :class:`RDFStore`.

    ``engine`` (or ``backend``) selects the execution engine; one endpoint
    may share an engine with a running system (caches are version-keyed
    and lock-guarded, so this is safe and cache-effective). ``system`` /
    ``pool`` optionally attach the cloud-edge scheduler and the serving
    admission layer.
    """

    def __init__(self, store: RDFStore | None = None,
                 dictionary: Dictionary | None = None, *,
                 engine: QueryEngine | None = None,
                 backend: str = "numpy",
                 system=None, pool=None,
                 plan_cache_size: int = 256,
                 result_cache_size: int = 256,
                 result_cache_bytes: int = 256 * 1024 * 1024) -> None:
        if system is not None:
            store = store if store is not None else system.cloud.store
            dictionary = (dictionary if dictionary is not None
                          else system.dictionary)
            engine = engine if engine is not None else system.engine
        if store is None or dictionary is None:
            raise ValueError("SparqlEndpoint needs a store and a dictionary "
                             "(or system=...)")
        self.store = store
        self.dictionary = dictionary
        self.engine = engine or QueryEngine(backend=backend)
        self.system = system
        self.pool = pool
        # plan memo keyed (text, dictionary.version): compiled plans bake
        # dictionary ids in (triple constants, FILTER-operand ent_id /
        # pred_id), so a plan compiled before live ingest grew the
        # dictionary may hold stale/missing ids — growth invalidates
        self._plans: OrderedDict[tuple, Node] = OrderedDict()
        self._plan_cache_size = int(plan_cache_size)
        # guards the plan memo, the result memo, and the memo counters: the
        # serving layer (repro.runtime.http / .admission) drives one
        # endpoint from many threads
        self._memo_lock = threading.Lock()
        # full-result memo provenance, read by the admission layer's
        # per-batch stats (engine cache counters don't see memo hits —
        # a memo hit never reaches the engine)
        self.memo_hits = 0
        self.memo_misses = 0
        # full-query result LRU keyed (text, store.version): the algebra
        # analogue of the engine's per-BGP result cache — a hot repeated
        # query skips operator re-evaluation entirely, and the version key
        # makes entries self-invalidating across placement deltas / ingest
        # (size 0 disables). Count- AND byte-bounded like the engine's
        # LRUs: a few huge tables must not pin unbounded memory. Cached
        # tables are shared — treat as read-only.
        self._results: OrderedDict[tuple, SolutionTable] = OrderedDict()
        self._result_cache_size = int(result_cache_size)
        self._result_cache_bytes = int(result_cache_bytes)
        self._result_bytes = 0
        # store commits performed by the write path (one per applied
        # delta) — the admission layer reads the delta to report how many
        # commits a coalesced window amortized away
        self.write_commits = 0

    # -- parsing / planning --------------------------------------------------
    def parse(self, text: str) -> Node:
        """Compile ``text`` to an operator tree.

        Memoized per ``(text, dictionary.version)``: ids are baked into the
        plan at compile time, so when live ingest adds terms the memo
        self-invalidates instead of serving a plan with stale/missing ids
        (regression-tested in ``tests/test_serving_http.py``).
        """
        key = (text, self.dictionary.version)
        with self._memo_lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                return plan
        plan = compile_query(parse_query(text, self.dictionary),
                             self.dictionary)
        with self._memo_lock:
            self._plans[key] = plan
            while len(self._plans) > self._plan_cache_size:
                self._plans.popitem(last=False)
        return plan

    def explain(self, text: str, user: int = 0) -> str:
        """Operator tree + per-BGP-leaf cache-hit provenance and estimated
        cardinalities against this endpoint's store/engine state.

        With an :class:`~repro.edge.system.EdgeCloudSystem` attached, a
        scheduler dry-run for ``user`` is appended: the chosen assignment
        kind (edge / cloud / partial) and, for a partial plan, the
        per-server leaf split."""
        plan = self.parse(text)
        out = explain_plan(plan, self.store, self.engine)
        if self.system is not None:
            out += "\n" + self.system.explain_assignment(plan, user=user)
        return out

    # -- execution -----------------------------------------------------------
    def _run(self, texts: list[str]) -> list[SolutionTable]:
        """Evaluate query texts with full-result memoization: misses (and
        in-batch duplicates, once) evaluate as ONE batch, hits return the
        cached table for the CURRENT store version.

        The store version is snapshotted at dispatch and re-validated after
        evaluation: if a concurrent delta (live ingest / rebalance commit)
        moved it mid-batch, the freshly computed tables are returned but
        NOT cached — they were not computed at any single version the memo
        key could honestly claim (regression-tested in
        ``tests/test_serving_http.py``).
        """
        v = self.store.version
        found: dict[str, SolutionTable] = {}
        todo: dict[str, Node] = {}
        for t in texts:
            if t in found or t in todo:
                continue
            with self._memo_lock:
                hit = self._results.get((t, v))
                if hit is not None:
                    self._results.move_to_end((t, v))
                    self.memo_hits += 1
            if hit is not None:
                found[t] = hit
            else:
                with self._memo_lock:
                    self.memo_misses += 1
                todo[t] = self.parse(t)
        if todo:
            tables = evaluate_many(list(todo.values()), self.store,
                                   self.engine)
            # answer from the local snapshot — the LRU trim below may evict
            # entries belonging to a batch wider than the cache
            found.update(zip(todo, tables))
            if self._result_cache_size > 0 and self.store.version == v:
                with self._memo_lock:
                    for t, tbl in zip(todo, tables):
                        nbytes = int(tbl.bindings.nbytes)
                        if nbytes > self._result_cache_bytes:
                            continue   # would evict everything; skip
                        displaced = self._results.pop((t, v), None)
                        if displaced is not None:
                            self._result_bytes -= int(
                                displaced.bindings.nbytes)
                        self._results[(t, v)] = tbl
                        self._result_bytes += nbytes
                    while (len(self._results) > self._result_cache_size
                           or self._result_bytes > self._result_cache_bytes):
                        _, old = self._results.popitem(last=False)
                        self._result_bytes -= int(old.bindings.nbytes)
        return [found[t] for t in texts]

    def clear_cache(self) -> None:
        """Cold-start: drop the endpoint's result memo AND the engine's
        scan/plan/result LRUs (compiled plans survive — they are
        store-independent)."""
        with self._memo_lock:
            self._results.clear()
            self._result_bytes = 0
        self.engine.clear_cache()

    def query(self, text: str) -> SolutionTable:
        """Run a SELECT query; returns a decoded-access solution table."""
        if isinstance(self.parse(text), AskNode):
            raise ParseError("ASK query — use SparqlEndpoint.ask")
        return self._run([text])[0]

    def query_many(self, texts: list[str]) -> list[SolutionTable]:
        """Run many SELECT/ASK queries as ONE engine batch: every BGP leaf
        of every query prescans/dedups together and alpha-equivalent
        sub-BGPs share result-cache entries; repeated texts hit the
        endpoint's full-result memo."""
        return self._run(texts)

    def ask(self, text: str) -> bool:
        """Run an ASK query (a SELECT is accepted too: non-empty result)."""
        return self._run([text])[0].num_matches > 0

    # -- the write path ------------------------------------------------------
    def update(self, text: str) -> dict:
        """Execute a SPARQL UPDATE (``INSERT DATA`` / ``DELETE DATA`` /
        ``DELETE WHERE``) and return an ack dict.

        With an :class:`~repro.edge.system.EdgeCloudSystem` attached, the
        write goes through ``system.apply_update`` — the single ingest path
        (placement lock, id-stable shard routing, induced-memo
        carry-forward, version-consistent edge propagation). A standalone
        endpoint applies the delta directly to its store. Either way the
        store version moves, so this endpoint's result memo
        self-invalidates (version-keyed); new INSERT DATA terms bump the
        dictionary version, invalidating the plan memo the same way.
        """
        from .query import parse_update
        from .update import compile_update
        parsed = parse_update(text, self.dictionary)
        if self.system is not None:
            rep = self.system.apply_update(parsed)
            self.write_commits += 1
            return {
                "kind": rep.kind, "inserted": rep.n_add,
                "deleted": rep.n_evict, "new_terms": rep.new_terms,
                "dropped_rows": rep.dropped_rows,
                "edges_updated": rep.edges_updated,
                "shipped_bytes": rep.shipped_bytes,
                "placement_epoch": rep.placement_epoch,
            }
        return self._apply_standalone(compile_update(parsed,
                                                     self.dictionary))

    def _apply_standalone(self, cu) -> dict:
        """Apply one compiled update directly to the endpoint's store (no
        system attached)."""
        from ..rdf.deltas import TripleDelta
        from .update import ground_delta, where_evict_rows
        if cu.where is not None:
            delta = TripleDelta(base_version=self.store.version,
                                evict=where_evict_rows(cu, self.store))
        else:
            delta = ground_delta(cu, self.store)
        if not delta.is_noop:
            self.store.apply_delta(delta)
        self.write_commits += 1
        return {"kind": cu.kind, "inserted": delta.n_add,
                "deleted": delta.n_evict, "new_terms": cu.new_terms,
                "dropped_rows": cu.dropped_rows, "edges_updated": 0,
                "shipped_bytes": 0, "placement_epoch": 0}

    def update_many(self, texts: list[str]) -> list:
        """Execute a window of updates in arrival order, **coalescing**
        consecutive ground updates (``INSERT DATA`` / ``DELETE DATA``) into
        ONE store commit — the admission queue's write-batching path
        (ROADMAP live-ingest follow-on (b)).

        Returns one entry per text, position-aligned: an ack dict (as
        :meth:`update` returns, plus ``"coalesced"`` — the commit group
        size) or the exception that text failed with. Semantics:

        - **arrival order**: each ground run folds into net add/evict row
          sets with sequential override (a later delete of an inserted row
          cancels it); per-text ``inserted`` / ``deleted`` counts are
          computed against the *effective* store content at that text's
          position, so acks match what sequential application would report.
        - ``DELETE WHERE`` cannot be folded (its evict set depends on the
          live store), so it flushes the pending group first and runs
          individually at its position.
        - **failure isolation**: a text that fails to parse/compile rejects
          only itself; the rest of the window still commits. A failing
          *commit* rejects every text of its group (their effects are one
          delta — none applied).

        The one-commit guarantee is what amortizes remap/propagation: with
        a system attached the whole group is one ``system.apply_delta``
        (one placement-lock round, one induced-memo carry-forward, one
        version-consistent edge propagation) instead of one per text.
        """
        from ..rdf.deltas import member_rows, setdiff_rows, union_rows
        from .query import parse_update
        from .update import compile_update
        results: list = [None] * len(texts)
        group: list[tuple[int, object]] = []   # (text idx, CompiledUpdate)

        def flush() -> None:
            if not group:
                return
            idxs = [i for i, _ in group]
            cus = [cu for _, cu in group]
            group.clear()
            # fold the run into net row sets, acking each update against
            # the effective content at its position
            cur = self.store.triples()
            net_add = np.zeros((0, 3), dtype=np.int64)
            net_evict = np.zeros((0, 3), dtype=np.int64)
            acks = []
            for cu in cus:
                ev = cu.evict
                hit = ((member_rows(ev, cur) & ~member_rows(ev, net_evict))
                       | member_rows(ev, net_add))
                deleted = int(hit.sum())
                if len(ev):
                    net_add = setdiff_rows(net_add, ev)
                    net_evict = union_rows(net_evict, ev)
                ad = cu.add
                have = ((member_rows(ad, cur) & ~member_rows(ad, net_evict))
                        | member_rows(ad, net_add))
                inserted = int(len(ad) - have.sum())
                if len(ad):
                    net_evict = setdiff_rows(net_evict, ad)
                    net_add = union_rows(net_add, ad)
                acks.append({"kind": cu.kind, "inserted": inserted,
                             "deleted": deleted, "new_terms": cu.new_terms,
                             "dropped_rows": cu.dropped_rows,
                             "coalesced": len(cus)})
            try:
                if self.system is not None:
                    rep = self.system.apply_delta(add=net_add,
                                                  evict=net_evict)
                    extra = {"edges_updated": rep.edges_updated,
                             "shipped_bytes": rep.shipped_bytes,
                             "placement_epoch": rep.placement_epoch}
                else:
                    from ..rdf.deltas import TripleDelta
                    delta = TripleDelta(
                        base_version=self.store.version,
                        add=setdiff_rows(net_add, cur),
                        evict=net_evict[member_rows(net_evict, cur)])
                    if not delta.is_noop:
                        self.store.apply_delta(delta)
                    extra = {"edges_updated": 0, "shipped_bytes": 0,
                             "placement_epoch": 0}
                self.write_commits += 1
            except Exception as err:   # one delta: the whole group fails
                for i in idxs:
                    results[i] = err
                return
            for i, ack in zip(idxs, acks):
                ack.update(extra)
                results[i] = ack

        for i, text in enumerate(texts):
            try:
                cu = compile_update(parse_update(text, self.dictionary),
                                    self.dictionary)
            except Exception as err:
                results[i] = err
                continue
            if cu.where is not None:
                flush()                # preserve arrival order around it
                try:
                    if self.system is not None:
                        rep = self.system.apply_update(cu)
                        self.write_commits += 1
                        results[i] = {
                            "kind": rep.kind, "inserted": rep.n_add,
                            "deleted": rep.n_evict,
                            "new_terms": rep.new_terms,
                            "dropped_rows": rep.dropped_rows,
                            "edges_updated": rep.edges_updated,
                            "shipped_bytes": rep.shipped_bytes,
                            "placement_epoch": rep.placement_epoch,
                            "coalesced": 1}
                    else:
                        results[i] = self._apply_standalone(cu)
                        results[i]["coalesced"] = 1
                except Exception as err:
                    results[i] = err
            else:
                group.append((i, cu))
        flush()
        return results

    @property
    def stats(self) -> EngineStats:
        return self.engine.stats

    # -- cloud-edge / serving integration -------------------------------------
    @classmethod
    def from_system(cls, system, **kw) -> "SparqlEndpoint":
        """Endpoint sharing an :class:`~repro.edge.system.EdgeCloudSystem`'s
        cloud store, dictionary, and engine (one cache domain)."""
        return cls(system=system, **kw)

    def run_round(self, user_texts: list[tuple[int, str]],
                  policy: str = "bnb", **kw):
        """Parse per-user query texts and run one scheduling round through
        ``system.run_round_batched`` — algebra queries route to edges
        whenever every *required* BGP leaf's pattern is resident there."""
        if self.system is None:
            raise ValueError("endpoint has no EdgeCloudSystem attached")
        queries = [(user, self.parse(text)) for user, text in user_texts]
        return self.system.run_round_batched(queries, policy=policy, **kw)

    def admit_many(self, texts: list[str], class_of=None,
                   policy: str = "bnb", **kw):
        """Build and admit one serving batch from query texts through the
        attached :class:`~repro.runtime.serving.OffloadServingPool`.

        ``class_of``: optional ``plan -> int`` request classifier (default:
        every request is class 0). Cycles/result-bits come from the cost
        estimator over the plan's BGP leaves.
        """
        if self.pool is None:
            raise ValueError("endpoint has no OffloadServingPool attached")
        from ..core.cost import estimate_query_cost
        requests = []
        for t in texts:
            plan = self.parse(t)
            c, w = estimate_query_cost(self.store, plan)
            requests.append({
                "class_id": int(class_of(plan)) if class_of else 0,
                "cycles": c, "result_bits": w, "payload": plan})
        return self.pool.admit(requests, policy=policy, **kw)
