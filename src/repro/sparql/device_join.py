"""Device-resident BGP execution: scans, compaction and presorted joins on
the accelerator, with ONE device->host transfer per engine batch.

The host join pipeline (:mod:`repro.sparql.matcher`) interleaves device
kernels with host control flow: every leaf scan ends in
``np.flatnonzero(np.asarray(mask))`` — a device->host round-trip per leaf
per shard — and the joins themselves are host ``searchsorted``. This module
keeps the whole pipeline of a *device-eligible* query on the accelerator:

1. **Seed scan** through ``triple_scan`` (or the fused ``scan_probe`` when
   the next step probes a seed column — the bound-predicate star shape),
   compacted on device via ``jnp.nonzero`` with a statically-sized output.
2. **Presorted joins** through the ``probe_sorted`` Pallas kernel over
   staged shard-local ``pred_index`` views, expanded to binding rows with
   XLA ``cumsum`` / ``repeat`` / gathers — the device analogue of
   ``matcher._probe_partitions``.
3. **One bulk fetch**: every queued query's binding and edge columns leave
   the device in a single ``jax.device_get`` at the end of the batch
   (counted in ``EngineStats.host_transfers``).

**Eligibility** (:func:`device_eligible`) — everything else falls back to
the host path transparently: the seed pattern must touch a single flat
store (bound predicate on a sharded store, or any pattern on a monolithic
one) and no pattern may repeat a variable; every subsequent plan step must
be ``JoinStep.device_probe`` (a shard-local presorted join with no
equality masks). This covers the bound-predicate star/path shapes that
dominate real workloads; variable-predicate joins, cross-shard merges and
masked joins keep their host implementation.

**Honest transfer accounting.** Host *control flow* still needs O(1)
scalars off the device (a matched-row count to size the compacted output,
a fan-out total to size each expansion). These are counted separately in
``scalar_syncs`` — they move ~8 bytes, not binding tables, and are the
irreducible cost of host-driven allocation. ``host_transfers`` counts bulk
array materializations only.

Capacity semantics match the host exactly: a device join's fan-out has no
equality masks, so its raw expansion IS the surviving row count and
:class:`~repro.sparql.matcher.MatchCapacityError` is raised at the same
``max_rows`` threshold the host would hit.
"""

from __future__ import annotations

from .matcher import JoinStep, JoinStats, MatchCapacityError, MatchResult
from .query import QueryGraph, TriplePattern

import numpy as np


def _repeats_var(tp: TriplePattern) -> bool:
    vs = [t for t in (tp.s, tp.p, tp.o) if isinstance(t, str)]
    return len(vs) != len(set(vs))


def device_eligible(store, q: QueryGraph, plan: list[JoinStep]) -> bool:
    """Can ``q`` run fully device-resident under ``plan`` on ``store``?

    See the module docstring for the covered query class. The decision is
    per *canonical* query, so alpha-equivalent queries share it.
    """
    if not q.patterns or store.num_triples == 0:
        return False
    if max(store.num_entities, store.num_predicates) >= 2 ** 31:
        return False                      # ids exceed int32 kernel range
    if any(_repeats_var(tp) for tp in q.patterns):
        return False                      # device path has no repeat filters
    tp0 = q.patterns[plan[0].pattern]
    if getattr(store, "shards", None) is not None \
            and not isinstance(tp0.p, int):
        return False                      # wildcard seed fans out over shards
    return all(st.device_probe for st in plan[1:])


class DeviceBatch:
    """Accumulates device-eligible queries of one engine batch and executes
    them with a single bulk device->host transfer.

    Usage: ``add()`` each (canonical-key, canonical-query, plan) triple,
    then ``run()`` once — returns ``{ck: MatchResult}`` with canonical
    variable names, ready for the engine's result cache.
    """

    def __init__(self, backend, store) -> None:
        self._be = backend
        self._store = store
        self._jobs: list[tuple[tuple, QueryGraph, list[JoinStep]]] = []

    def add(self, ck: tuple, q: QueryGraph, plan: list[JoinStep]) -> None:
        self._jobs.append((ck, q, plan))

    def run(self, max_rows: int,
            stats: JoinStats | None = None) -> dict[tuple, MatchResult]:
        if not self._jobs:
            return {}
        pend = [(ck, len(q.patterns),
                 self._exec(q, plan, max_rows, stats))
                for ck, q, plan in self._jobs]
        # the ONE bulk transfer: every job's binding + edge columns at once
        fetched = self._be._fetch([(cols, {k: e for k, (e, _) in edges.items()})
                                   for _, _, (cols, edges) in pend])
        out: dict[tuple, MatchResult] = {}
        for (ck, E, (_, edges)), (h_cols, h_edges) in zip(pend, fetched):
            R = len(next(iter(h_edges.values())))
            if h_cols:
                bindings = np.stack(
                    [np.asarray(c, dtype=np.int64) for c in h_cols.values()],
                    axis=1)
            else:
                bindings = np.zeros((R, 0), dtype=np.int64)
            edge_ids = np.zeros((R, E), dtype=np.int64)
            for k in range(E):
                # re-lift shard-local tids by the owning shard's offset
                edge_ids[:, k] = (np.asarray(h_edges[k], dtype=np.int64)
                                  + edges[k][1])
            out[ck] = MatchResult(var_names=list(h_cols),
                                  bindings=bindings, edge_ids=edge_ids)
        return out

    # -- per-query device pipeline -------------------------------------------
    def _exec(self, q: QueryGraph, plan: list[JoinStep], max_rows: int,
              stats: JoinStats | None):
        """Build one query's device-resident column set (nothing fetched).

        Returns ``(cols, edges)``: ``cols`` maps variable name -> device
        int32 value column (host append order: s, o, p per step);
        ``edges`` maps pattern index -> (device shard-LOCAL tid column,
        global-id offset).
        """
        import jax.numpy as jnp

        from ..kernels.join_probe import probe_sorted, scan_probe
        from ..kernels.triple_scan import triple_scan

        be, store = self._be, self._store
        slots = be._store_slots(store)
        empty = jnp.zeros(0, jnp.int32)
        cols: dict[str, object] = {}
        edges: dict[int, tuple[object, int]] = {}

        # ---- seed: scan + on-device compaction -----------------------------
        tp0 = q.patterns[plan[0].pattern]
        svar0 = tp0.s if isinstance(tp0.s, str) else None
        pvar0 = tp0.p if isinstance(tp0.p, str) else None
        ovar0 = tp0.o if isinstance(tp0.o, str) else None
        if stats is not None:            # parity with the host seed expansion
            stats.joins_cartesian += 1
            stats.partitions_probed += 1
        parts = be._scan_parts(store, tp0)
        fused = None
        if not parts or parts[0][0].num_triples == 0:
            R, off0 = 0, (parts[0][1] if parts else 0)
            rows = empty
        else:
            flat0, off0 = parts[0]
            arr0 = be._triples(flat0, min_slots=slots)
            pat = jnp.asarray(be._pattern_vec(tp0))
            fuse_col = self._fuse_col(q, plan, tp0)
            if fuse_col is not None:
                col, keys = fuse_col
                mask, lo_all, hi_all = scan_probe(
                    arr0, pat, keys, col, bt=be.bt, bk=be.bt,
                    interpret=be.interpret)
            else:
                mask = triple_scan(arr0, pat, bt=be.bt,
                                   interpret=be.interpret)
            R = be._scalar(mask.sum())
            if R:
                rows = jnp.nonzero(mask, size=R)[0]
                if fuse_col is not None:
                    fused = (lo_all[rows], hi_all[rows])
            else:
                rows = empty
        for varname, c in ((svar0, 0), (ovar0, 2), (pvar0, 1)):
            if varname is not None:
                cols[varname] = (arr0[rows, c] if R else empty)
        edges[plan[0].pattern] = (rows.astype(jnp.int32), off0)

        # ---- presorted probe joins -----------------------------------------
        for si, step in enumerate(plan[1:], start=1):
            tp = q.patterns[step.pattern]
            svar = tp.s if isinstance(tp.s, str) else None
            ovar = tp.o if isinstance(tp.o, str) else None
            join_on_s = svar in cols
            newvar = ovar if join_on_s else svar
            views, offk, flatk = be._pred_views(store, tp.p)
            keys, stids = ((views[0], views[1]) if join_on_s
                           else (views[2], views[3]))
            if stats is not None:
                stats.joins_pred_index += 1   # same plan step as the host
                stats.joins_device += 1       # ... but executed on device
                stats.partitions_probed += 1
            if R == 0:
                cols[newvar] = empty
                edges[step.pattern] = (empty, offk)
                continue
            if si == 1 and fused is not None:
                lo, hi = fused
            else:
                tvals = cols[svar if join_on_s else ovar]
                lo, hi = probe_sorted(keys, _pad_probes(tvals),
                                      bk=be.bt, interpret=be.interpret)
                lo, hi = lo[:R], hi[:R]
            counts = hi - lo
            cum = jnp.cumsum(counts)
            total = be._scalar(cum[-1])
            if total > max_rows:
                raise MatchCapacityError(
                    f"join would keep more than {max_rows} rows")
            if total == 0:
                R = 0
                for v in cols:
                    cols[v] = empty
                for k in edges:
                    edges[k] = (empty, edges[k][1])
                cols[newvar] = empty
                edges[step.pattern] = (empty, offk)
                continue
            # expansion of the [lo, hi) runs — XLA cumsum/repeat/gather
            row_idx = jnp.repeat(jnp.arange(R), counts,
                                 total_repeat_length=total)
            starts = jnp.repeat(lo, counts, total_repeat_length=total)
            within = (jnp.arange(total)
                      - jnp.repeat(cum - counts, counts,
                                   total_repeat_length=total))
            sel_local = stids[starts + within]
            arrk = be._triples(flatk, min_slots=slots)
            for v in cols:
                cols[v] = cols[v][row_idx]
            for k in edges:
                edges[k] = (edges[k][0][row_idx], edges[k][1])
            cols[newvar] = arrk[sel_local, 2 if join_on_s else 0]
            edges[step.pattern] = (sel_local.astype(jnp.int32), offk)
            R = total
        return cols, edges

    def _fuse_col(self, q: QueryGraph, plan: list[JoinStep],
                  tp0: TriplePattern):
        """(triple column, device sorted keys) when step 1 probes a seed
        triple column directly — the ``scan_probe`` fusion window — else
        None (seed bound a predicate variable the join uses, or the query
        is a single pattern)."""
        if len(plan) < 2:
            return None
        tp1 = q.patterns[plan[1].pattern]
        svar1 = tp1.s if isinstance(tp1.s, str) else None
        join_on_s = svar1 is not None and svar1 in tp0.variables()
        joinvar = svar1 if join_on_s else tp1.o
        col = 0 if joinvar == tp0.s else 2 if joinvar == tp0.o else None
        if col is None:                    # join var came from seed's p
            return None
        views, _off, _flat = self._be._pred_views(self._store, tp1.p)
        return col, (views[0] if join_on_s else views[2])


def _pad_probes(v, min_size: int = 128):
    """Pad a probe vector to the next power of two (≥ ``min_size``) with
    ``-1`` so the jitted kernel retraces per size *bucket*, not per binding
    count; ``-1`` probes yield ``lo == hi == 0`` against non-negative id
    key spaces and the caller slices the pad away."""
    import jax.numpy as jnp

    P = v.shape[0]
    t = max(min_size, 1 << max(P - 1, 0).bit_length())
    return jnp.pad(v, (0, t - P), constant_values=-1) if t != P else v
