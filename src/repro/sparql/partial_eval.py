"""Collaborative partial evaluation for cross-edge queries.

The paper executes a query at an edge only when EVERY required BGP leaf's
pattern is resident there; anything else is cloud-only. Partial evaluation
(Peng et al., "Processing SPARQL Queries Over Distributed RDF Graphs")
turns that class collaborative:

1. **Plan** (:func:`plan_partial`): split each required leaf into maximal
   connected sub-BGP *fragments* whose patterns are resident at some edge
   (:func:`repro.core.pattern.leaf_residency` reports the per-leaf
   residency matrix). Non-resident fragments stay at the cloud as
   residuals. Contributing edges are picked least-loaded-first.
2. **Execute** (:func:`execute_partial_batch`): every contributing edge
   runs its fragments as ONE engine batch against its resident subgraph
   G[P]; the cloud runs the residual fragments plus any OPTIONAL leaves.
   Each edge ships a **dictionary-free binding table** — the raw
   ``[R, V]`` int64 array plus variable names, exactly the buffers the
   fork-pool IPC path already moves — whose size is the plan's egress
   (``shipped_bits``).
3. **Assemble**: fragment tables of one leaf combine with the composite-key
   ``searchsorted`` compatibility join (:func:`repro.sparql.algebra.
   _join_tables`); assembled leaves feed the ordinary algebra evaluator.

**Correctness.** An edge's store is the *induced subgraph* of the cloud
store over its resident patterns, so a fragment isomorphic to a resident
pattern finds exactly the cloud's match set (the paper's completeness
guarantee) — over the SAME global dictionary ids. And for a BGP split into
fragments T₁ ∪ T₂, the match multiset of the whole equals the compatibility
join of the fragments' match multisets on their shared variables (matches
are homomorphisms; stores are deduplicated so no multiplicities appear).
Assembly therefore reproduces the cloud-only result as a multiset; plans
whose results are row-ORDER-sensitive (LIMIT / OFFSET) are never planned
partially (:func:`plan_partial` returns None).

**Staleness.** A plan records each contributing edge's store version at
planning time; :func:`execute_partial_batch` re-verifies the versions and
transparently falls back to whole-query cloud execution when a rebalance
moved any edge in between — a stale partial table is never assembled.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.pattern import leaf_residency, pattern_of
from .algebra import (OrderSliceNode, SolutionTable, _eval, _join_tables,
                      execute_any_batch, is_algebra_plan)
from .matcher import MatchResult
from .query import QueryGraph

CLOUD = -1


@dataclass
class Fragment:
    """A connected sub-BGP of one required leaf, pinned to one server.

    ``leaf_pos`` indexes the plan's full ``bgp_leaves()`` list (or -1 when
    the query is a plain :class:`QueryGraph`). ``server_id`` is the
    contributing edge, or :data:`CLOUD` for a residual no edge holds.
    """

    query: QueryGraph
    leaf_pos: int
    server_id: int


@dataclass
class PartialPlan:
    """An executable partial-evaluation plan for one query."""

    query: object                      # plain QueryGraph or algebra plan
    fragments: list[Fragment]
    store_versions: dict[int, object] = field(default_factory=dict)

    @property
    def edge_set(self) -> list[int]:
        """Sorted contributing edge server ids."""
        return sorted({f.server_id for f in self.fragments
                       if f.server_id >= 0})

    def describe(self) -> list[str]:
        """Human-readable per-server leaf split (endpoint ``explain``)."""
        out = []
        for f in self.fragments:
            where = "cloud" if f.server_id < 0 else f"ES{f.server_id}"
            leaf = "query" if f.leaf_pos < 0 else f"leaf {f.leaf_pos}"
            pats = " . ".join(
                f"{tp.s} {tp.p} {tp.o}" for tp in f.query.patterns)
            out.append(f"{leaf} [{pats}] @ {where}")
        return out


@dataclass
class PartialExecution:
    """Outcome of one partial plan: assembled result + honest accounting."""

    result: object                     # MatchResult | SolutionTable
    servers: tuple[int, ...]           # edges that actually contributed
    shipped_bits: float                # binding-table egress, bits
    per_server_rows: dict[int, int]
    per_server_seconds: dict[int, float]
    fallback: bool = False             # stale placement -> ran at cloud
    per_server_bits: dict[int, float] = field(default_factory=dict)
    # per-phase engine wall (prescan + join seconds) per server — the
    # realized-latency input (repro.core.cost.measured_cycles): raw wall
    # above includes coordinator Python overhead that would misprice the
    # cloud assembly as from-scratch evaluation
    per_server_engine_seconds: dict[int, float] = field(
        default_factory=dict)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def _order_sensitive(root) -> bool:
    """True when the plan's result depends on row order (LIMIT/OFFSET):
    assembly reproduces the cloud result as a *multiset*, which is exactly
    what every other operator (incl. DISTINCT and bare ORDER BY) consumes."""
    stack = [root]
    while stack:
        n = stack.pop()
        if isinstance(n, OrderSliceNode) and (n.limit is not None
                                              or n.offset > 0):
            return True
        stack.extend(n.children())
    return False


def _sub_query(lq: QueryGraph, idxs: list[int]) -> QueryGraph:
    return QueryGraph(patterns=[lq.patterns[i] for i in idxs], projection=[])


def _resident_cols(sub: QueryGraph, servers: list) -> list[int]:
    p = pattern_of(sub)
    return [j for j, es in enumerate(servers)
            if es.store is not None and es.can_execute(p)]


def _split_leaf(lq: QueryGraph, servers: list,
                ) -> list[tuple[tuple[int, ...], list[int]]]:
    """Cover ``lq``'s patterns with maximal connected sub-BGPs, each with
    the server columns where its pattern is resident (empty -> residual).

    Greedy grow: seed with the lowest unplaced pattern, then repeatedly
    absorb vertex-adjacent patterns while the combined pattern stays
    resident somewhere. Deterministic for a fixed placement.
    """
    n = len(lq.patterns)
    cols = _resident_cols(lq, servers)
    if cols:
        return [(tuple(range(n)), cols)]
    verts = [{lq.patterns[i].s, lq.patterns[i].o} for i in range(n)]
    out: list[tuple[tuple[int, ...], list[int]]] = []
    remaining = list(range(n))
    while remaining:
        i = remaining.pop(0)
        frag = [i]
        cur = _resident_cols(_sub_query(lq, frag), servers)
        if cur:
            grown = True
            while grown and remaining:
                grown = False
                for j in list(remaining):
                    if not any(verts[j] & verts[k] for k in frag):
                        continue
                    cand = _resident_cols(_sub_query(lq, frag + [j]), servers)
                    if cand:
                        frag.append(j)
                        remaining.remove(j)
                        cur = cand
                        grown = True
        out.append((tuple(frag), cur))
    return out


def plan_partial(q, edge_servers: list) -> PartialPlan | None:
    """Build a partial-evaluation plan for ``q``, or None when partial
    execution is not certifiable (no contributing edge, order-sensitive
    slice, uncertifiable leaves)."""
    if is_algebra_plan(q) and _order_sensitive(q):
        return None
    servers = list(edge_servers)
    res = leaf_residency(q, servers)
    if res is None:
        return None
    fragments: list[Fragment] = []
    load: dict[int, int] = {}
    any_edge = False
    for lq, pos in zip(res.leaves, res.leaf_idx):
        for idxs, cols in _split_leaf(lq, servers):
            if cols:
                sid = min((s.server_id for j, s in enumerate(servers)
                           if j in cols),
                          key=lambda s: (load.get(s, 0), s))
                load[sid] = load.get(sid, 0) + 1
                any_edge = True
            else:
                sid = CLOUD
            fragments.append(Fragment(query=_sub_query(lq, list(idxs)),
                                      leaf_pos=pos, server_id=sid))
    if not any_edge:
        return None
    by_id = {es.server_id: es for es in servers}
    versions = {sid: by_id[sid].store.version
                for sid in {f.server_id for f in fragments if f.server_id >= 0}}
    return PartialPlan(query=q, fragments=fragments, store_versions=versions)


# ---------------------------------------------------------------------------
# execution + assembly
# ---------------------------------------------------------------------------


def _table_bits(res) -> float:
    """Dictionary-free wire size of a shipped binding table: R x V int64
    cells (variable-name header amortized away, matching ``result_bits``)."""
    r = int(res.bindings.shape[0])
    v = max(1, int(res.bindings.shape[1]))
    return float(r * v * 64)


def _as_table(res, pred_vars: frozenset, d) -> SolutionTable:
    if isinstance(res, SolutionTable):
        return res
    t = SolutionTable(list(res.var_names), res.bindings, pred_vars)
    t.dictionary = d
    return t


def _assemble_leaf(tables: list, pred_vars: frozenset, d, cap: int):
    """Compatibility-join a leaf's fragment tables (composite-key
    searchsorted equi-join). A single whole-leaf table passes through
    untouched so the one-fragment case is byte-identical to local
    evaluation."""
    if len(tables) == 1:
        return tables[0]
    acc = _as_table(tables[0], pred_vars, d)
    for t in tables[1:]:
        acc = _join_tables(acc, _as_table(t, pred_vars, d), "inner", cap)
    return acc


def execute_partial_batch(plans: list[PartialPlan], cloud_store, engine,
                          edges_by_id: dict[int, object],
                          max_rows: int | None = None,
                          ) -> list[PartialExecution]:
    """Execute a batch of partial plans with per-server fragment batching.

    All fragments bound for one edge run as ONE ``engine.execute_batch``
    against that edge's store (scan dedup / result-cache sharing apply
    per server); residual fragments and OPTIONAL leaves batch against the
    cloud store. Stale plans (an edge's store version moved since
    planning) fall back to whole-query cloud execution, marked
    ``fallback=True`` — results are always current.
    """
    cap = int(max_rows if max_rows is not None
              else getattr(engine, "max_rows", 5_000_000))
    stale = [False] * len(plans)
    for i, plan in enumerate(plans):
        for sid, ver in plan.store_versions.items():
            es = edges_by_id.get(sid)
            if es is None or es.store is None or es.store.version != ver:
                stale[i] = True
                break

    # ---- gather per-server jobs: (plan idx, slot key, query) -------------
    jobs: dict[int, list[tuple[int, tuple, QueryGraph]]] = {}
    for i, plan in enumerate(plans):
        if stale[i]:
            continue
        for fi, frag in enumerate(plan.fragments):
            jobs.setdefault(frag.server_id, []).append(
                (i, ("frag", fi), frag.query))
        if is_algebra_plan(plan.query):
            covered = {f.leaf_pos for f in plan.fragments}
            for pos, leaf in enumerate(plan.query.bgp_leaves()):
                if pos not in covered and leaf.patterns:
                    jobs.setdefault(CLOUD, []).append(
                        (i, ("leaf", pos), leaf.query))

    # ---- execute: one engine batch per server ----------------------------
    results: dict[tuple[int, tuple], object] = {}
    per_rows: dict[int, dict[int, int]] = {i: {} for i in range(len(plans))}
    per_secs: dict[int, dict[int, float]] = {i: {} for i in range(len(plans))}
    shipped: dict[int, float] = {i: 0.0 for i in range(len(plans))}
    per_bits: dict[int, dict[int, float]] = {i: {} for i in range(len(plans))}
    per_eng: dict[int, dict[int, float]] = {i: {} for i in range(len(plans))}
    stats = engine.stats
    for sid, batch in sorted(jobs.items()):
        store = cloud_store if sid == CLOUD else edges_by_id[sid].store
        e0 = stats.prescan_seconds + stats.join_seconds
        t0 = time.perf_counter()
        outs = engine.execute_batch(store, [q for (_, _, q) in batch])
        dt = time.perf_counter() - t0
        # per-phase engine wall, clamped to batch wall (the phase
        # accumulators are shared across overlapped threads); the 1ns
        # floor marks "measured (essentially free)" as distinct from
        # "not measured" for measured_cycles' fallback
        deng = max(min(stats.prescan_seconds + stats.join_seconds - e0,
                       dt), 1e-9)
        per_plan = {}
        for (i, slot, _), res in zip(batch, outs):
            results[(i, slot)] = res
            per_plan.setdefault(i, 0)
            per_plan[i] += res.num_matches
            if sid != CLOUD and slot[0] == "frag":
                b = _table_bits(res)
                shipped[i] += b
                per_bits[i][sid] = per_bits[i].get(sid, 0.0) + b
        for i, nrows in per_plan.items():
            per_rows[i][sid] = per_rows[i].get(sid, 0) + nrows
            # wall apportioned evenly across the batch's plans, matching
            # the servers' batched accounting convention
            per_secs[i][sid] = (per_secs[i].get(sid, 0.0)
                                + dt / max(1, len(per_plan)))
            per_eng[i][sid] = (per_eng[i].get(sid, 0.0)
                               + deng / max(1, len(per_plan)))

    # ---- fallback: whole-query cloud execution ---------------------------
    fb_idx = [i for i in range(len(plans)) if stale[i]]
    fb_res = (execute_any_batch(cloud_store, engine,
                                [plans[i].query for i in fb_idx], cap)
              if fb_idx else [])

    # ---- assemble --------------------------------------------------------
    out: list[PartialExecution] = []
    fb_iter = iter(fb_res)
    for i, plan in enumerate(plans):
        if stale[i]:
            out.append(PartialExecution(
                result=next(fb_iter), servers=(), shipped_bits=0.0,
                per_server_rows={}, per_server_seconds={}, fallback=True))
            continue
        root = plan.query
        d = getattr(root, "dictionary", None)
        pred_vars = getattr(root, "pred_vars", frozenset())
        by_leaf: dict[int, list] = {}
        for fi, frag in enumerate(plan.fragments):
            by_leaf.setdefault(frag.leaf_pos, []).append(
                results[(i, ("frag", fi))])
        a0 = stats.prescan_seconds + stats.join_seconds
        t_asm = time.perf_counter()
        if is_algebra_plan(root):
            leaves = root.bgp_leaves()
            leaf_results = {}
            for pos, tables in by_leaf.items():
                leaf_results[id(leaves[pos])] = _assemble_leaf(
                    tables, pred_vars, d, cap)
            for pos, leaf in enumerate(leaves):
                if pos not in by_leaf and leaf.patterns:
                    leaf_results[id(leaf)] = results[(i, ("leaf", pos))]
            final = _eval(root, leaf_results, engine, d, pred_vars, cap)
        else:
            t = _assemble_leaf(by_leaf[-1], pred_vars, d, cap)
            bindings = np.ascontiguousarray(t.bindings)
            final = MatchResult(
                var_names=list(t.var_names), bindings=bindings,
                edge_ids=np.zeros((bindings.shape[0], 0), dtype=np.int64))
        # assembly runs at the cloud: charge its wall there, so per-server
        # walls honestly cover everything the coordinator did for this plan
        asm_wall = time.perf_counter() - t_asm
        per_secs[i][CLOUD] = per_secs[i].get(CLOUD, 0.0) + asm_wall
        per_eng[i][CLOUD] = (per_eng[i].get(CLOUD, 0.0) + max(
            min(stats.prescan_seconds + stats.join_seconds - a0,
                asm_wall), 1e-9))
        used = tuple(sorted(k for k in per_rows[i] if k >= 0))
        out.append(PartialExecution(
            result=final, servers=used, shipped_bits=shipped[i],
            per_server_rows=per_rows[i], per_server_seconds=per_secs[i],
            per_server_bits=per_bits[i],
            per_server_engine_seconds=per_eng[i]))
    return out
