"""repro: cloud-edge collaborative SPARQL over large RDF graphs, in JAX.

A production-grade reproduction + extension of:
  "Efficient Cloud-edge Collaborative Approaches to SPARQL Queries over
   Large RDF graphs" (Ma, Peng, Zhou, Ozsu, Zou, Chen; CS.DB 2026)

Layers
------
- ``repro.rdf``      : dictionary-encoded triple store + synthetic generators
- ``repro.sparql``   : BGP parser + vectorized homomorphism matcher
- ``repro.core``     : pattern-induced subgraphs, DFS-code index, MINLP scheduler
- ``repro.edge``     : edge/cloud servers + end-to-end system simulator
- ``repro.models``   : LM / GNN / recsys model zoo (10 assigned architectures)
- ``repro.kernels``  : Pallas TPU kernels (validated via interpret mode on CPU)
- ``repro.runtime``  : train/serve loops, checkpointing, fault tolerance
- ``repro.workload`` : subgraph-sampling workload generator + traffic harness
- ``repro.launch``   : production mesh + multi-pod dry-run drivers

Public query API
----------------
:class:`repro.SparqlEndpoint` is the one-object entry point for running
SPARQL (SELECT/ASK with FILTER, OPTIONAL, UNION, DISTINCT, ORDER BY,
LIMIT/OFFSET) over any store — see ``repro.sparql.endpoint``. The
lower-level pieces (``parse_query`` -> ``compile_query`` -> operator tree,
``SolutionTable`` results) are re-exported here lazily. The pre-algebra
BGP path (``parse_sparql`` -> ``QueryGraph`` -> ``QueryEngine.execute``)
remains as a thin deprecation shim for Def.-2 queries.

Serving
-------
:class:`repro.SparqlHttpServer` (``repro.runtime.http``) exposes an
endpoint over HTTP (SPARQL-Protocol subset, W3C JSON results) with
:class:`repro.AdmissionQueue` micro-batch coalescing in front — concurrent
requests execute as ONE engine batch.
"""

__version__ = "1.1.0"

_LAZY = {
    "SparqlEndpoint": ("repro.sparql.endpoint", "SparqlEndpoint"),
    "SolutionTable": ("repro.sparql.algebra", "SolutionTable"),
    "compile_query": ("repro.sparql.algebra", "compile_query"),
    "parse_query": ("repro.sparql.query", "parse_query"),
    "parse_sparql": ("repro.sparql.query", "parse_sparql"),
    "AdmissionQueue": ("repro.runtime.admission", "AdmissionQueue"),
    "SparqlHttpServer": ("repro.runtime.http", "SparqlHttpServer"),
    "PatternSampler": ("repro.workload", "PatternSampler"),
    "ShapeConfig": ("repro.workload", "ShapeConfig"),
    "TrafficConfig": ("repro.workload", "TrafficConfig"),
    "build_schedule": ("repro.workload", "build_schedule"),
    "replay": ("repro.workload", "replay"),
}


def __getattr__(name):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target[0]), target[1])


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
