"""repro: cloud-edge collaborative SPARQL over large RDF graphs, in JAX.

A production-grade reproduction + extension of:
  "Efficient Cloud-edge Collaborative Approaches to SPARQL Queries over
   Large RDF graphs" (Ma, Peng, Zhou, Ozsu, Zou, Chen; CS.DB 2026)

Layers
------
- ``repro.rdf``      : dictionary-encoded triple store + synthetic generators
- ``repro.sparql``   : BGP parser + vectorized homomorphism matcher
- ``repro.core``     : pattern-induced subgraphs, DFS-code index, MINLP scheduler
- ``repro.edge``     : edge/cloud servers + end-to-end system simulator
- ``repro.models``   : LM / GNN / recsys model zoo (10 assigned architectures)
- ``repro.kernels``  : Pallas TPU kernels (validated via interpret mode on CPU)
- ``repro.runtime``  : train/serve loops, checkpointing, fault tolerance
- ``repro.launch``   : production mesh + multi-pod dry-run drivers
"""

__version__ = "1.0.0"
