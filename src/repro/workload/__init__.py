"""Subgraph-sampling workload generation + traffic replay (paper §5).

The benchmark sections of the paper evaluate the cloud-edge stack under
*workloads whose answers are known*: queries are instantiated from the
data so every reported count can be checked, and traffic is shaped
(skewed popularity, bursts, read/write mixes) to exercise the caching and
scheduling layers. This package reproduces that methodology against the
live stores:

- :mod:`~repro.workload.sampler` — :class:`PatternSampler` walks an
  :class:`~repro.rdf.graph.RDFStore` (monolithic or sharded, through the
  protocol surface only) and samples star / path / flower / snowflake
  BGPs whose constants are *witnessed* by actual triples, recording each
  query's **exact** result cardinality at sample time.
- :mod:`~repro.workload.traffic` — :func:`build_schedule` turns sampled
  templates into a deterministic, seeded open-loop schedule: Zipf
  popularity over a hot pool, Poisson or burst arrivals, a cold-template
  reserve, and an optional write mix synthesized against the same store.
- :mod:`~repro.workload.driver` — :func:`replay` pushes a schedule
  through an :class:`~repro.runtime.admission.AdmissionQueue`, reporting
  per-shape latency percentiles, cache-hit trajectories, scheduler
  decisions, and recorded-vs-observed cardinality verification.
"""

from .sampler import PatternSampler, SampledQuery, ShapeConfig
from .traffic import Schedule, ScheduledEvent, TrafficConfig, build_schedule
from .driver import ClassReport, ReplayReport, replay

__all__ = [
    "PatternSampler", "SampledQuery", "ShapeConfig",
    "Schedule", "ScheduledEvent", "TrafficConfig", "build_schedule",
    "ClassReport", "ReplayReport", "replay",
]
