"""Schedule replay through the admission front end, with verification.

:func:`replay` is the harness's measurement loop: it paces a
:class:`~repro.workload.traffic.Schedule` open-loop into an
:class:`~repro.runtime.admission.AdmissionQueue` (latencies are measured
from the *scheduled* arrival, so queueing delay under overload is part of
the number — the coordinated-omission-free convention of
``benchmarks/bench_serving.py``), polls tickets for completion, and folds
the outcome into a :class:`ReplayReport`:

- per-shape (and cold/warm) latency percentiles over completed queries;
- **cardinality verification**: each answered query's row count checked
  against the cardinality recorded at sample time (skipped automatically
  when the schedule's write style can perturb results — see
  :attr:`Schedule.verifiable`);
- the admission layer's cache-hit *trajectory* (per-batch endpoint-memo /
  engine-cache hit deltas in dispatch order — the warmup curve);
- scheduler decisions (full-edge / cloud / partial assignment counts)
  when the queue runs in ``round`` / ``pool`` mode, plus write-coalescing
  provenance when it batches updates.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClassReport:
    """Latency + verification aggregates for one event class."""

    count: int = 0
    errors: int = 0
    verified: int = 0
    mismatched: int = 0
    latencies: list = field(default_factory=list)

    def percentiles(self) -> dict:
        if not self.latencies:
            return {"p50": 0.0, "p90": 0.0, "p99": 0.0, "mean": 0.0}
        lat = np.asarray(self.latencies)
        return {"p50": float(np.percentile(lat, 50)),
                "p90": float(np.percentile(lat, 90)),
                "p99": float(np.percentile(lat, 99)),
                "mean": float(lat.mean())}

    def as_dict(self) -> dict:
        return {"count": self.count, "errors": self.errors,
                "verified": self.verified, "mismatched": self.mismatched,
                **{k: round(v, 6) for k, v in self.percentiles().items()}}


@dataclass
class ReplayReport:
    """Everything one replay run measured (see module doc)."""

    wall_s: float
    n_events: int
    completed: int
    errors: int
    per_shape: dict
    per_temperature: dict            # "cold" / "warm" ClassReports
    writes: ClassReport
    verified: int
    mismatched: int
    mismatches: list                 # (template, expected, got) samples
    cache_trajectory: list           # per-batch dicts, dispatch order
    assignment_counts: dict
    admission: dict                  # AdmissionStats.as_dict() snapshot

    @property
    def verification_ok(self) -> bool:
        return self.mismatched == 0

    def as_dict(self) -> dict:
        return {
            "wall_s": round(self.wall_s, 4),
            "n_events": self.n_events, "completed": self.completed,
            "errors": self.errors,
            "verified": self.verified, "mismatched": self.mismatched,
            "per_shape": {k: v.as_dict()
                          for k, v in sorted(self.per_shape.items())},
            "per_temperature": {k: v.as_dict() for k, v in
                                sorted(self.per_temperature.items())},
            "writes": self.writes.as_dict(),
            "cache_trajectory": self.cache_trajectory,
            "assignment_counts": {str(k): v for k, v in
                                  sorted(self.assignment_counts.items())},
            "admission": self.admission,
        }


def _observed_rows(table) -> int:
    """Observed solution count for a served query's result table."""
    return int(getattr(table, "num_matches", len(table)))


def replay(queue, schedule, *, speed: float = 1.0,
           verify: bool | None = None,
           max_mismatch_samples: int = 10) -> ReplayReport:
    """Replay ``schedule`` through ``queue`` (see module doc).

    ``speed`` compresses the schedule clock (``2.0`` replays a 1 s
    schedule in 0.5 s of wall time). ``verify=None`` auto-enables
    cardinality checking exactly when :attr:`Schedule.verifiable` holds;
    pass ``True``/``False`` to force. Submission errors (parse failures,
    queue-full rejections, deadline drops) count as ``errors`` per class
    — they never abort the replay.
    """
    if verify is None:
        verify = schedule.verifiable
    events = sorted(schedule.events, key=lambda e: e.at_s)
    # trajectory capture: an unbounded batch log when the queue offers
    # one (stats.recent is a ring trimmed to the last 64 batches, far
    # fewer than a long replay's dispatch windows); otherwise fall back
    # to seq-filtering the ring, which at least never misattributes
    # pre-replay batches
    batch_log = (queue.start_batch_log()
                 if hasattr(queue, "start_batch_log") else None)
    seq0 = max((bs.seq for bs in queue.stats.recent), default=-1)
    per_shape: dict[str, ClassReport] = {}
    per_temp = {"cold": ClassReport(), "warm": ClassReport()}
    writes = ClassReport()
    mismatches: list = []
    pending: list = []               # (event, due, ticket)

    def settle(now: float, item) -> None:
        event, due, ticket = item
        if event.kind == "update":
            report = writes
        else:
            report = per_shape.setdefault(event.shape, ClassReport())
        try:
            value = ticket.result(timeout=0)
        except Exception:
            # ticket rejection payloads are Exceptions; let
            # KeyboardInterrupt/SystemExit propagate so long replays
            # stay interruptible
            report.errors += 1
            if event.kind == "query":
                per_temp["cold" if event.cold else "warm"].errors += 1
            return
        report.count += 1
        report.latencies.append(now - due)
        if event.kind == "query":
            temp = per_temp["cold" if event.cold else "warm"]
            temp.count += 1
            temp.latencies.append(now - due)
            if verify and event.cardinality is not None:
                got = _observed_rows(value)
                if got == event.cardinality:
                    report.verified += 1
                    temp.verified += 1
                else:
                    report.mismatched += 1
                    temp.mismatched += 1
                    if len(mismatches) < max_mismatch_samples:
                        mismatches.append((event.template,
                                           event.cardinality, got))

    def drain_done(now: float) -> None:
        done = [it for it in pending if it[2].done()]
        for it in done:
            pending.remove(it)
            settle(now, it)

    start = time.monotonic()
    try:
        for event in events:
            due = start + event.at_s / speed
            while True:
                now = time.monotonic()
                if now >= due:
                    break
                drain_done(now)
                time.sleep(max(0.0, min(0.001,
                                        due - time.monotonic())))
            try:
                ticket = queue.submit(event.text)
            except Exception:
                # admission-level refusal (full queue / parse error):
                # count against the event's class, keep replaying
                report = (writes if event.kind == "update"
                          else per_shape.setdefault(event.shape,
                                                    ClassReport()))
                report.errors += 1
                if event.kind == "query":
                    per_temp["cold" if event.cold
                             else "warm"].errors += 1
                continue
            pending.append((event, due, ticket))
        while pending:
            drain_done(time.monotonic())
            if pending:
                time.sleep(0.0005)
    finally:
        if batch_log is not None:
            queue.stop_batch_log()
    wall = time.monotonic() - start

    batches = (batch_log if batch_log is not None
               else [bs for bs in queue.stats.recent if bs.seq > seq0])
    trajectory = [
        {"seq": bs.seq, "size": bs.size,
         "memo_hits": bs.memo_hits,
         "engine_cache_hits": bs.engine_cache_hits,
         "scans_deduped": bs.scans_deduped,
         "write_commits": bs.write_commits}
        for bs in batches]
    shape_totals = list(per_shape.values()) + [writes]
    return ReplayReport(
        wall_s=wall,
        n_events=len(events),
        completed=sum(r.count for r in shape_totals),
        errors=sum(r.errors for r in shape_totals),
        per_shape=per_shape,
        per_temperature=per_temp,
        writes=writes,
        verified=sum(r.verified for r in per_shape.values()),
        mismatched=sum(r.mismatched for r in per_shape.values()),
        mismatches=mismatches,
        cache_trajectory=trajectory,
        assignment_counts=dict(queue.stats.assignment_counts),
        admission=queue.stats.as_dict())
