"""Subgraph-sampling BGP templates with known exact cardinalities.

WatDiv-style benchmarks instantiate *structural templates* against the
actual data so constants are witnessed by real triples and every query is
guaranteed non-empty. :class:`PatternSampler` generalizes that recipe to
the live store: instead of a fixed schema-bound template table
(:data:`repro.rdf.generator._TEMPLATES`), it **walks the graph itself**
through the :class:`~repro.rdf.graph.RDFStore` protocol surface
(``pred_index`` sorted views + ``searchsorted``), so it works unchanged
over a monolithic :class:`~repro.rdf.graph.TripleStore` or a
:class:`~repro.rdf.sharding.ShardedTripleStore`, on any dataset.

Four shapes, each grown from a uniformly sampled seed triple:

- ``star``      — one center subject with ``size`` distinct out-predicates;
- ``path``      — a ``size``-hop subject→object random walk;
- ``flower``    — a star whose petals are extended one more hop where the
  witness object has out-edges (the paper's "flower" pattern);
- ``snowflake`` — a path with extra star edges grafted at both endpoints.

Every edge of a sampled pattern is backed by a concrete *witness* triple
discovered during the walk, so the witness assignment is one solution and
the query matches at least once. ``const_frac`` instantiates that fraction
of leaf positions with their witness constants (selectivity knob);
``decorations`` optionally wraps the BGP in witness-preserving algebra
(FILTER / OPTIONAL / UNION / VALUES / LIMIT).

The rendered SPARQL text is evaluated at sample time (parse → compile →
evaluate on a private numpy engine) and the **exact** result cardinality
is recorded on the :class:`SampledQuery` — the ground truth the traffic
driver and ``benchmarks/bench_workload.py`` verify served answers against.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

import numpy as np

from ..rdf.dictionary import Dictionary
from ..rdf.graph import RDFStore
from ..sparql.algebra import compile_query, evaluate_plan
from ..sparql.engine import QueryEngine
from ..sparql.query import parse_query

SHAPES = ("star", "path", "flower", "snowflake")

#: decorations understood by :attr:`ShapeConfig.decorations`
DECORATIONS = ("filter", "optional", "union", "values", "limit")


@dataclass(frozen=True)
class ShapeConfig:
    """Per-shape sampling knobs.

    ``size`` is the star arity / path hop count (flower and snowflake
    derive their extension counts from it). ``const_frac`` is the
    probability that each eligible leaf position is instantiated with its
    witness constant. ``decorations`` is a pool; each sampled query draws
    one uniformly (include ``None`` in the pool to mix in plain BGPs).
    """

    shape: str
    size: int = 3
    const_frac: float = 0.3
    decorations: tuple = ()

    def __post_init__(self) -> None:
        if self.shape not in SHAPES:
            raise ValueError(f"unknown shape {self.shape!r}; "
                             f"expected one of {SHAPES}")
        if self.size < 1:
            raise ValueError("size must be >= 1")
        if not 0.0 <= self.const_frac <= 1.0:
            raise ValueError("const_frac must be in [0, 1]")
        for dec in self.decorations:
            if dec is not None and dec not in DECORATIONS:
                raise ValueError(f"unknown decoration {dec!r}; "
                                 f"expected one of {DECORATIONS}")


@dataclass(frozen=True)
class SampledQuery:
    """One sampled template: SPARQL text + its ground-truth cardinality.

    ``cardinality`` is the exact solution-multiset size against the store
    the sampler walked, at ``store_version`` — any later write invalidates
    it (the churn write style of :mod:`~repro.workload.traffic` is built
    to NOT invalidate it: churn touches only an excluded predicate and
    fresh entities, so results over sampled predicates are unchanged).
    """

    name: str
    shape: str
    text: str
    cardinality: int
    n_patterns: int
    n_consts: int                   # leaf positions instantiated
    pids: tuple                     # predicate ids the pattern uses
    decoration: str | None
    store_version: object


class PatternSampler:
    """Samples witnessed BGP shapes from a live store (see module doc).

    Parameters
    ----------
    store, dictionary : the graph to walk and its term dictionary.
    seed : deterministic sampling seed (same seed ⇒ identical queries).
    engine : optional `QueryEngine` used for ground-truth evaluation;
        defaults to a private numpy engine so sampling never pollutes a
        serving engine's caches or stats.
    exclude_predicates : predicate ids (ints) or term strings never used
        in sampled patterns — reserve one for churn-style writes so the
        write mix cannot invalidate recorded cardinalities.
    max_attempts : walk retries per requested query before giving up
        (tiny/degenerate stores yield fewer queries than asked, never an
        error; an empty store yields ``[]``).
    """

    def __init__(self, store: RDFStore, dictionary: Dictionary, *,
                 seed: int = 0, engine: QueryEngine | None = None,
                 exclude_predicates=(), max_attempts: int = 32) -> None:
        self.store = store
        self.dictionary = dictionary
        self.rng = np.random.default_rng(seed)
        self.engine = engine or QueryEngine(backend="numpy")
        self.max_attempts = int(max_attempts)
        excl = set()
        for p in exclude_predicates:
            excl.add(dictionary.predicate_id(p) if isinstance(p, str)
                     else int(p))
        self.exclude = frozenset(excl)
        self._counter = 0

    # -- protocol-surface graph walking --------------------------------------
    def _live_pids(self) -> np.ndarray:
        """Predicates with at least one triple, minus the excluded set."""
        counts = np.asarray(self.store.pred_count)
        pids = np.flatnonzero(counts > 0)
        if self.exclude:
            pids = pids[~np.isin(pids, list(self.exclude))]
        return pids

    def _out_objects(self, eid: int, pid: int) -> np.ndarray:
        """Objects of out-edges ``(eid, pid, ?)`` via the sorted view."""
        idx = self.store.pred_index(pid)
        lo = np.searchsorted(idx.s_sorted, eid, "left")
        hi = np.searchsorted(idx.s_sorted, eid, "right")
        return self.store.o[idx.s_order[lo:hi]]

    def _out_pids(self, eid: int, pids: np.ndarray) -> list:
        """Subset of ``pids`` under which ``eid`` has an out-edge."""
        out = []
        for pid in pids:
            idx = self.store.pred_index(int(pid))
            lo = np.searchsorted(idx.s_sorted, eid, "left")
            if lo < len(idx.s_sorted) and idx.s_sorted[lo] == eid:
                out.append(int(pid))
        return out

    def _seed_subject(self, pids: np.ndarray) -> int | None:
        """Subject of a uniformly sampled non-excluded triple."""
        weights = np.asarray(self.store.pred_count)[pids]
        total = int(weights.sum())
        if total == 0:
            return None
        pid = int(self.rng.choice(pids, p=weights / total))
        tids = self.store.pred_tids(pid)
        return int(self.store.s[tids[self.rng.integers(len(tids))]])

    # -- shape growth (patterns over var names / witness ids) ----------------
    # Each grower returns (patterns, witness) or None to retry:
    # patterns: list of (s, pid, o) with s/o either a "?var" or an entity
    # id; witness: var -> entity id, one concrete solution by construction.

    def _grow_star(self, size: int, pids: np.ndarray):
        center = self._seed_subject(pids)
        if center is None:
            return None
        cand = self._out_pids(center, pids)
        if len(cand) < min(2, size):
            return None
        chosen = self.rng.choice(cand, size=min(size, len(cand)),
                                 replace=False)
        pats, witness = [], {"?x0": center}
        for i, pid in enumerate(chosen):
            objs = self._out_objects(center, int(pid))
            witness[f"?v{i}"] = int(objs[self.rng.integers(len(objs))])
            pats.append(("?x0", int(pid), f"?v{i}"))
        return pats, witness

    def _grow_path(self, size: int, pids: np.ndarray):
        cur = self._seed_subject(pids)
        if cur is None:
            return None
        pats, witness = [], {"?x0": cur}
        for hop in range(size):
            cand = self._out_pids(cur, pids)
            if not cand:
                break
            pid = int(self.rng.choice(cand))
            objs = self._out_objects(cur, pid)
            nxt = int(objs[self.rng.integers(len(objs))])
            pats.append((f"?x{hop}", pid, f"?x{hop + 1}"))
            witness[f"?x{hop + 1}"] = nxt
            cur = nxt
        if len(pats) < min(2, size):
            return None
        return pats, witness

    def _grow_flower(self, size: int, pids: np.ndarray):
        grown = self._grow_star(size, pids)
        if grown is None:
            return None
        pats, witness = grown
        petals = [p for p in pats]          # extend up to ceil(k/2) petals
        self.rng.shuffle(petals)
        extended = 0
        for (_, _, ovar) in petals:
            if extended >= max(1, (len(pats) + 1) // 2):
                break
            tip = witness[ovar]
            cand = self._out_pids(tip, pids)
            if not cand:
                continue
            pid = int(self.rng.choice(cand))
            objs = self._out_objects(tip, pid)
            wvar = f"?w{extended}"
            witness[wvar] = int(objs[self.rng.integers(len(objs))])
            pats.append((ovar, pid, wvar))
            extended += 1
        if extended == 0:                    # no petal extends: plain star
            return None
        return pats, witness

    def _grow_snowflake(self, size: int, pids: np.ndarray):
        grown = self._grow_path(size, pids)
        if grown is None:
            return None
        pats, witness = grown
        used = {pid for (_, pid, _) in pats}
        grafted = 0
        last = len(pats)                    # path hops before grafting
        for k, node_var in ((0, "?x0"), (1, f"?x{last}")):
            eid = witness[node_var]
            cand = [p for p in self._out_pids(eid, pids) if p not in used]
            if not cand:
                continue
            pid = int(self.rng.choice(cand))
            objs = self._out_objects(eid, pid)
            gvar = f"?g{k}"
            witness[gvar] = int(objs[self.rng.integers(len(objs))])
            pats.append((node_var, pid, gvar))
            used.add(pid)
            grafted += 1
        if grafted == 0:
            return None
        return pats, witness

    _GROWERS = {"star": _grow_star, "path": _grow_path,
                "flower": _grow_flower, "snowflake": _grow_snowflake}

    # -- rendering ------------------------------------------------------------
    def _instantiate(self, pats, witness, const_frac: float):
        """Replace leaf object variables by their witness constants with
        probability ``const_frac``. Only *leaf* positions (variables used
        in exactly one pattern, object side) are eligible — join variables
        stay variables so the shape keeps its structure."""
        uses = Counter()
        for s, _, o in pats:
            for t in (s, o):
                if isinstance(t, str):
                    uses[t] += 1
        out, n_consts = [], 0
        for s, pid, o in pats:
            if (isinstance(o, str) and uses[o] == 1
                    and self.rng.random() < const_frac):
                o = witness[o]
                n_consts += 1
            out.append((s, pid, o))
        return out, n_consts

    def _term(self, eid: int) -> str:
        return f"<{self.dictionary.entity(eid)}>"

    def _render(self, pats) -> tuple[str, list]:
        """SPARQL text + ordered variable list for a pattern list."""
        seen: dict[str, None] = {}
        body = []
        for s, pid, o in pats:
            st = s if isinstance(s, str) else self._term(s)
            ot = o if isinstance(o, str) else self._term(o)
            for t in (s, o):
                if isinstance(t, str):
                    seen.setdefault(t)
            body.append(f"{st} <{self.dictionary.predicate(pid)}> {ot}")
        variables = list(seen)
        return (f"SELECT {' '.join(variables)} WHERE {{ "
                + " . ".join(body) + " }"), variables

    def _decorate(self, pats, witness, decoration, pids: np.ndarray):
        """Render with a witness-preserving decoration applied."""
        text, variables = self._render(pats)
        if decoration is None or not variables:
            return text
        body = text[text.index("{") + 1:text.rindex("}")].strip()
        head = text[:text.index("{")]
        var = str(self.rng.choice(variables))
        if decoration == "filter":
            # exclude an entity that is NOT the witness: the witness row
            # survives, so the query stays non-empty
            avoid = witness[var]
            other = (avoid + 1) % max(1, self.dictionary.num_entities)
            if other == avoid:
                return text
            return f"{head}{{ {body} . FILTER (?{var[1:]} != " \
                   f"{self._term(other)}) }}"
        if decoration == "values":
            avoid = witness[var]
            other = (avoid + 1) % max(1, self.dictionary.num_entities)
            terms = f"{self._term(avoid)} {self._term(other)}" \
                if other != avoid else self._term(avoid)
            return f"{head}{{ {body} . VALUES {var} {{ {terms} }} }}"
        if decoration == "optional" and len(pats) >= 2:
            parts = body.split(" . ")
            return (f"{head}{{ {' . '.join(parts[:-1])} . "
                    f"OPTIONAL {{ {parts[-1]} }} }}")
        if decoration == "union" and len(pats) >= 2:
            parts = body.split(" . ")
            alt_pid = int(self.rng.choice(pids))
            s, _, o = pats[-1]
            st = s if isinstance(s, str) else self._term(s)
            ot = o if isinstance(o, str) else self._term(o)
            alt = f"{st} <{self.dictionary.predicate(alt_pid)}> {ot}"
            return (f"{head}{{ {' . '.join(parts[:-1])} . "
                    f"{{ {parts[-1]} }} UNION {{ {alt} }} }}")
        if decoration == "limit":
            return f"{text} LIMIT {int(self.rng.integers(1, 11))}"
        return text                          # decoration not applicable

    # -- public API -----------------------------------------------------------
    def sample(self, cfg: ShapeConfig, n: int) -> list:
        """Sample up to ``n`` queries of ``cfg``'s shape (see class doc)."""
        out: list[SampledQuery] = []
        if self.store.num_triples == 0 or n <= 0:
            return out
        pids = self._live_pids()
        if len(pids) == 0:
            return out
        grow = self._GROWERS[cfg.shape]
        attempts = 0
        budget = max(n, 1) * self.max_attempts
        while len(out) < n and attempts < budget:
            attempts += 1
            grown = grow(self, cfg.size, pids)
            if grown is None:
                continue
            pats, witness = grown
            pats, n_consts = self._instantiate(pats, witness,
                                               cfg.const_frac)
            decoration = (self.rng.choice(np.asarray(cfg.decorations,
                                                     dtype=object))
                          if cfg.decorations else None)
            decoration = None if decoration is None else str(decoration)
            text = self._decorate(pats, witness, decoration, pids)
            card = self._cardinality(text)
            self._counter += 1
            out.append(SampledQuery(
                name=f"{cfg.shape}{cfg.size}_{self._counter:04d}",
                shape=cfg.shape, text=text, cardinality=card,
                n_patterns=len(pats), n_consts=n_consts,
                pids=tuple(sorted({pid for (_, pid, _) in pats})),
                decoration=decoration,
                store_version=self.store.version))
        return out

    def sample_mix(self, cfgs, n_per: int) -> list:
        """Flat list over several shape configs, ``n_per`` queries each."""
        out: list[SampledQuery] = []
        for cfg in cfgs:
            out.extend(self.sample(cfg, n_per))
        return out

    def _cardinality(self, text: str) -> int:
        """Exact solution count for ``text`` against the live store."""
        root = compile_query(parse_query(text, self.dictionary),
                             self.dictionary)
        return len(evaluate_plan(root, self.store, self.engine))
