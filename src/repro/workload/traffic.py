"""Deterministic traffic modeling: templates → an open-loop schedule.

The serving experiments of §5 need *shaped* traffic, not uniform replay:
cache layers only show up under skewed popularity, the admission window
only matters under bursts, and the coalescing write path only matters
under read/write mixes. :func:`build_schedule` synthesizes all of that
from a seed — two calls with the same inputs produce byte-identical
schedules, so A/B benchmark arms replay the *same* traffic:

- **Popularity**: Zipf(``zipf_s``) over a hot template pool. A
  ``cold_fraction`` of requests instead draw the next template from a
  once-only cold reserve (carved off the template list), modeling
  compulsory cache misses; an exhausted reserve falls back to the hot
  pool.
- **Arrivals**: open-loop ``poisson`` (exponential inter-arrivals at
  ``qps``) or ``burst`` — an inhomogeneous Poisson process where a
  ``burst_fraction`` of wall time runs at ``burst_factor × qps`` in
  periodic burst windows and the off-window rate compensates so the
  overall mean stays ``qps`` (with the defaults the off-window rate is
  exactly 0: all traffic lands in the bursts).
- **Writes**: a ``write_fraction`` of events are SPARQL UPDATEs
  synthesized against the same store. Style ``"churn"`` inserts (and
  later deletes) triples on a dedicated *churn predicate* with fresh
  entity terms — it never touches sampled predicates, so recorded
  cardinalities stay exact under the write load. Style ``"touch"``
  deletes a sampled existing triple and re-inserts it on a later write
  event — real invalidation pressure, at the cost of transiently
  perturbed counts (the driver skips verification mid-flight for it).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .sampler import SampledQuery

ARRIVALS = ("poisson", "burst")
WRITE_STYLES = ("churn", "touch")


@dataclass(frozen=True)
class TrafficConfig:
    """Shape of the synthesized traffic (see module doc)."""

    duration_s: float = 1.0
    qps: float = 200.0
    arrival: str = "poisson"
    burst_factor: float = 4.0        # burst-window rate multiplier
    burst_fraction: float = 0.25     # fraction of wall time in burst
    burst_period_s: float = 0.25     # one burst per period
    zipf_s: float = 1.1              # popularity skew exponent
    cold_fraction: float = 0.0       # requests served from the cold pool
    write_fraction: float = 0.0      # events that are UPDATEs
    write_style: str = "churn"
    seed: int = 0

    def __post_init__(self) -> None:
        if self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival {self.arrival!r}; "
                             f"expected one of {ARRIVALS}")
        if self.write_style not in WRITE_STYLES:
            raise ValueError(f"unknown write_style {self.write_style!r}; "
                             f"expected one of {WRITE_STYLES}")
        for name in ("duration_s", "qps", "burst_factor",
                     "burst_period_s"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        for name in ("cold_fraction", "write_fraction", "burst_fraction"):
            if not 0.0 <= getattr(self, name) <= 1.0:
                raise ValueError(f"{name} must be in [0, 1]")


@dataclass(frozen=True)
class ScheduledEvent:
    """One arrival: a query replay or a synthesized update."""

    at_s: float                      # offset from replay start
    kind: str                        # "query" | "update"
    text: str
    template: str | None = None      # SampledQuery.name (queries only)
    shape: str | None = None
    cardinality: int | None = None   # recorded ground truth (queries only)
    cold: bool = False               # drawn from the cold reserve


@dataclass
class Schedule:
    """A fully materialized, seed-deterministic arrival sequence."""

    events: list
    config: TrafficConfig
    templates: list                  # the SampledQuery list scheduled over
    churn_predicate: str | None = None

    @property
    def n_queries(self) -> int:
        return sum(1 for e in self.events if e.kind == "query")

    @property
    def n_updates(self) -> int:
        return sum(1 for e in self.events if e.kind == "update")

    @property
    def has_writes(self) -> bool:
        return self.n_updates > 0

    @property
    def verifiable(self) -> bool:
        """Whether recorded cardinalities stay exact during replay: true
        for read-only schedules and for churn-style writes (which touch
        only the reserved predicate + fresh entities)."""
        return (not self.has_writes
                or self.config.write_style == "churn")

    def template_counts(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events:
            if e.kind == "query":
                out[e.template] = out.get(e.template, 0) + 1
        return out


def _arrival_times(cfg: TrafficConfig, rng: np.random.Generator) -> list:
    """Open-loop arrival offsets in [0, duration_s), sorted.

    ``burst`` is a piecewise-constant inhomogeneous Poisson process,
    simulated by *thinning*: sample a homogeneous process at the peak
    rate and accept each candidate with probability ``rate(t) / peak``.
    (Stepping one exponential at the rate of the current instant is NOT
    equivalent — a draw taken at a low off-window rate can overshoot
    every later burst window entirely.)
    """
    if cfg.arrival == "burst":
        burst_rate = cfg.qps * cfg.burst_factor
        window = cfg.burst_fraction * cfg.burst_period_s
        if cfg.burst_fraction >= 1.0:
            off_rate = 0.0           # degenerate: always in-burst anyway
        else:
            # chosen so burst_fraction·burst + (1-burst_fraction)·off
            # averages back to qps; clamps at 0 when the bursts alone
            # already carry the full mean load
            off_rate = cfg.qps * max(
                0.0, (1.0 - cfg.burst_factor * cfg.burst_fraction)
                / (1.0 - cfg.burst_fraction))
        peak = max(burst_rate, off_rate)
    else:
        peak = cfg.qps
    times: list[float] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / peak))
        if t >= cfg.duration_s:
            return times
        if cfg.arrival == "burst":
            rate = (burst_rate if t % cfg.burst_period_s < window
                    else off_rate)
            if rng.random() * peak >= rate:
                continue
        times.append(t)


class _ChurnWriter:
    """Synthesizes churn-style updates: fresh entities on a reserved
    predicate. Inserts until a small pool accumulates, then alternates
    insert/delete so the store does not grow without bound."""

    def __init__(self, predicate: str, rng: np.random.Generator,
                 tag: str) -> None:
        self.predicate = predicate
        self.rng = rng
        self.tag = tag
        self.live: list[tuple[str, str]] = []
        self.minted = 0

    def next_update(self) -> str:
        delete = self.live and (len(self.live) >= 8
                                or self.rng.random() < 0.4)
        if delete:
            s, o = self.live.pop(int(self.rng.integers(len(self.live))))
            return (f"DELETE DATA {{ <{s}> <{self.predicate}> <{o}> }}")
        s = f"wl:{self.tag}:e{self.minted}"
        o = f"wl:{self.tag}:e{self.minted + 1}"
        self.minted += 2
        self.live.append((s, o))
        return f"INSERT DATA {{ <{s}> <{self.predicate}> <{o}> }}"


class _TouchWriter:
    """Synthesizes touch-style updates: delete an existing triple, then
    re-insert it on a later write event (net zero at drain)."""

    def __init__(self, store, dictionary,
                 rng: np.random.Generator) -> None:
        self.store = store
        self.d = dictionary
        self.rng = rng
        self.pending: list[str] = []     # re-insert texts owed

    def next_update(self) -> str:
        if self.pending and self.rng.random() < 0.5:
            return self.pending.pop(0)
        if self.store.num_triples == 0:
            return "INSERT DATA { }"
        t = int(self.rng.integers(self.store.num_triples))
        s = self.d.entity(int(self.store.s[t]))
        p = self.d.predicate(int(self.store.p[t]))
        o = self.d.entity(int(self.store.o[t]))
        row = f"<{s}> <{p}> <{o}>"
        self.pending.append(f"INSERT DATA {{ {row} }}")
        return f"DELETE DATA {{ {row} }}"

    def drain(self) -> list:
        """Re-insert texts still owed (append these after replay to
        restore the store)."""
        out, self.pending = self.pending, []
        return out


def build_schedule(templates: list, config: TrafficConfig, *,
                   store=None, dictionary=None,
                   churn_predicate: str | None = None) -> Schedule:
    """Materialize a deterministic schedule over sampled templates.

    ``templates`` is a non-empty list of :class:`SampledQuery`.
    ``write_fraction > 0`` with style ``"churn"`` requires
    ``churn_predicate`` (a predicate term string the sampler *excluded*);
    style ``"touch"`` requires ``store`` and ``dictionary`` to sample
    existing triples from.
    """
    if not templates:
        raise ValueError("build_schedule needs at least one template")
    cfg = config
    rng = np.random.default_rng(cfg.seed)
    writer = None
    if cfg.write_fraction > 0:
        if cfg.write_style == "churn":
            if churn_predicate is None:
                raise ValueError("churn writes need churn_predicate=")
            writer = _ChurnWriter(churn_predicate, rng,
                                  tag=f"s{cfg.seed}")
        else:
            if store is None or dictionary is None:
                raise ValueError("touch writes need store= and "
                                 "dictionary=")
            writer = _TouchWriter(store, dictionary, rng)

    # hot/cold split: the cold reserve is the TAIL of the template list
    # (shuffled copy so the caller's ordering carries no popularity bias)
    order = list(templates)
    rng.shuffle(order)
    n_cold = (min(len(order) - 1, max(1, int(round(
        cfg.cold_fraction * len(order))))) if cfg.cold_fraction > 0
        and len(order) > 1 else 0)
    hot = order[:len(order) - n_cold]
    cold = order[len(order) - n_cold:]
    weights = 1.0 / np.arange(1, len(hot) + 1) ** cfg.zipf_s
    weights /= weights.sum()

    events: list[ScheduledEvent] = []
    cold_next = 0
    for t in _arrival_times(cfg, rng):
        if writer is not None and rng.random() < cfg.write_fraction:
            events.append(ScheduledEvent(at_s=t, kind="update",
                                         text=writer.next_update()))
            continue
        is_cold = (cold_next < len(cold)
                   and rng.random() < cfg.cold_fraction)
        if is_cold:
            q: SampledQuery = cold[cold_next]
            cold_next += 1
        else:
            q = hot[int(rng.choice(len(hot), p=weights))]
        events.append(ScheduledEvent(
            at_s=t, kind="query", text=q.text, template=q.name,
            shape=q.shape, cardinality=q.cardinality, cold=is_cold))
    if isinstance(writer, _TouchWriter):
        # settle owed re-inserts just after the window so replay restores
        # the store to its pre-schedule content
        eps = 1e-4
        for i, text in enumerate(writer.drain()):
            events.append(ScheduledEvent(
                at_s=cfg.duration_s + eps * (i + 1), kind="update",
                text=text))
    return Schedule(events=events, config=cfg,
                    templates=list(templates),
                    churn_predicate=(churn_predicate
                                     if cfg.write_style == "churn"
                                     and cfg.write_fraction > 0 else None))
