"""Dispatching wrappers: one API, three backends (pallas / interpret / xla).

Models call these; on TPU the Pallas kernels run compiled, on CPU they run
via interpret mode (tests) or fall back to the jnp reference (production
CPU path — interpret mode is a correctness tool, not a fast path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .embedding_bag import embedding_bag_pallas
from .flash_attention import flash_attention as _flash_pallas
from .segment_mp import segment_sum_sorted as _segmp_pallas
from .triple_scan import triple_scan as _scan_pallas


def _backend(impl: str) -> str:
    # NOTE: this picks pallas vs the jnp *reference* (a different axis than
    # repro.kernels.default_interpret, which resolves pallas_call's
    # interpret flag); the TPU-grid kernels only compile on TPU, so GPU
    # uses the XLA reference here.
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              impl: str = "auto"):
    b = _backend(impl)
    if b == "xla":
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         softcap=softcap, interpret=(b == "interpret"))


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, softcap=0.0,
                     impl: str = "auto"):
    b = _backend(impl)
    if b == "xla":
        return ref.decode_reference(q, k_cache, v_cache, lengths,
                                    window=window, softcap=softcap)
    return _decode_pallas(q, k_cache, v_cache, lengths, window=window,
                          softcap=softcap, interpret=(b == "interpret"))


def segment_sum_sorted(msg, dst, n_nodes: int, *, impl: str = "auto"):
    b = _backend(impl)
    if b == "xla":
        return ref.segment_sum_sorted_reference(msg, dst, n_nodes)
    return _segmp_pallas(msg, dst, n_nodes, interpret=(b == "interpret"))


def embedding_bag(table, ids, mask, *, combiner="mean", impl: str = "auto"):
    b = _backend(impl)
    if b == "xla":
        return ref.embedding_bag_reference(table, ids, mask,
                                           combiner=combiner)
    return embedding_bag_pallas(table, ids, mask, combiner=combiner,
                                interpret=(b == "interpret"))


def triple_scan(triples, pattern, *, impl: str = "auto"):
    b = _backend(impl)
    if b == "xla":
        return ref.triple_scan_reference(triples, int(pattern[0]),
                                         int(pattern[1]), int(pattern[2]))
    return _scan_pallas(triples, jnp.asarray(pattern),
                        interpret=(b == "interpret"))
