"""Triple-pattern scan (Pallas TPU) — the paper's candidate-scan hot spot.

BGP matching begins with a scan over dictionary-encoded triples applying the
constant components of a pattern (see ``sparql.matcher._candidates``). On
the edge servers this touches every stored triple per query; on TPU we
stream [T, 3] blocks HBM -> VMEM and evaluate the constant/wildcard mask on
the VPU, emitting an int32 match mask (compaction stays in XLA: cumsum +
take, which is already optimal there).

The pattern (s, p, o) arrives as scalar prefetch (-1 == wildcard), so ONE
compiled kernel serves every pattern — no recompilation per query, which is
what a serving system needs.

The scan is the FIRST stage of the device-resident join pipeline
(:mod:`repro.sparql.device_join`): its mask is compacted on device and fed
straight into the ``probe_sorted`` join kernel — or the scan and first
probe fuse into one launch via :func:`repro.kernels.join_probe.scan_probe`
— so eligible queries never round-trip to the host between leaf scan and
join.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(pat_ref, trip_ref, mask_ref, *, bt: int):
    s, p, o = pat_ref[0], pat_ref[1], pat_ref[2]
    t = trip_ref[...]                                  # [bt, 3] int32
    m = jnp.ones((bt,), jnp.bool_)
    m &= (t[:, 0] == s) | (s < 0)
    m &= (t[:, 1] == p) | (p < 0)
    m &= (t[:, 2] == o) | (o < 0)
    mask_ref[...] = m.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def triple_scan(triples: jnp.ndarray, pattern: jnp.ndarray, bt: int = 2048,
                interpret: bool = False) -> jnp.ndarray:
    """triples [T, 3] int32; pattern [3] int32 with -1 wildcards.

    Returns int32 match mask [T].
    """
    T = triples.shape[0]
    t_pad = ((T + bt - 1) // bt) * bt
    if t_pad != T:
        triples = jnp.pad(triples, ((0, t_pad - T), (0, 0)),
                          constant_values=-2)          # never matches
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t_pad // bt,),
        in_specs=[pl.BlockSpec((bt, 3), lambda i, pat: (i, 0))],
        out_specs=pl.BlockSpec((bt,), lambda i, pat: (i,)),
    )
    mask = pl.pallas_call(
        functools.partial(_scan_kernel, bt=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad,), jnp.int32),
        interpret=interpret,
    )(pattern.astype(jnp.int32), triples.astype(jnp.int32))
    return mask[:T]


def _scan_many_kernel(pat_ref, trip_ref, mask_ref, *, bt: int):
    qi = pl.program_id(0)
    s, p, o = pat_ref[qi, 0], pat_ref[qi, 1], pat_ref[qi, 2]
    t = trip_ref[...]                                  # [bt, 3] int32
    m = jnp.ones((bt,), jnp.bool_)
    m &= (t[:, 0] == s) | (s < 0)
    m &= (t[:, 1] == p) | (p < 0)
    m &= (t[:, 2] == o) | (o < 0)
    mask_ref[...] = m.astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def triple_scan_many(triples: jnp.ndarray, patterns: jnp.ndarray,
                     bt: int = 2048, interpret: bool = False) -> jnp.ndarray:
    """Batched scan: triples [T, 3], patterns [Q, 3] (-1 wildcards) -> [Q, T].

    Grid (Q, T/bt): every pattern streams the same triple blocks, so one
    compiled kernel evaluates *all deduplicated scans of a query batch* in a
    single launch — the batch-fusion counterpart of :func:`triple_scan` that
    ``sparql.engine``'s JAX backend uses to pre-populate its scan memo.
    """
    T = triples.shape[0]
    Q = patterns.shape[0]
    t_pad = ((T + bt - 1) // bt) * bt
    if t_pad != T:
        triples = jnp.pad(triples, ((0, t_pad - T), (0, 0)),
                          constant_values=-2)          # never matches
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, t_pad // bt),
        in_specs=[pl.BlockSpec((bt, 3), lambda qi, i, pat: (i, 0))],
        out_specs=pl.BlockSpec((1, bt), lambda qi, i, pat: (qi, i)),
    )
    mask = pl.pallas_call(
        functools.partial(_scan_many_kernel, bt=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, t_pad), jnp.int32),
        interpret=interpret,
    )(patterns.astype(jnp.int32), triples.astype(jnp.int32))
    return mask[:, :T]
