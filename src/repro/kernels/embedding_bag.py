"""EmbeddingBag (Pallas TPU): scalar-prefetched row streaming + bag reduce.

The canonical TPU embedding pattern: the (huge) table stays in HBM; the ids
are **scalar-prefetched** so each grid step's BlockSpec ``index_map`` selects
exactly the table row the step needs — the DMA engine streams only touched
rows into VMEM (no [B*F*NNZ, D] gather buffer ever exists).

Grid = (B, F, NNZ) with the bag axis innermost; a VMEM scratch accumulates
the masked bag sum, divided by the live count on the last entry (mean) —
``nn.EmbeddingBag`` semantics with multi-hot masks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, mask_ref, row_ref, o_ref, acc_scr, cnt_scr, *,
                nnz: int, combiner: str):
    b = pl.program_id(0)
    f = pl.program_id(1)
    z = pl.program_id(2)

    @pl.when(z == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    m = mask_ref[b, f, z].astype(jnp.float32)
    acc_scr[...] += row_ref[...].astype(jnp.float32) * m
    cnt_scr[...] += m

    @pl.when(z == nnz - 1)
    def _finalize():
        if combiner == "sum":
            o_ref[0] = acc_scr[...].astype(o_ref.dtype)
        else:
            denom = jnp.maximum(cnt_scr[0], 1.0)
            o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag_pallas(table: jnp.ndarray, ids: jnp.ndarray,
                         mask: jnp.ndarray, combiner: str = "mean",
                         interpret: bool = False) -> jnp.ndarray:
    """table [V, D]; ids/mask [B, F, NNZ] -> bags [B, F, D]."""
    B, F, NNZ = ids.shape
    V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # ids, mask
        grid=(B, F, NNZ),
        in_specs=[
            # stream exactly the addressed table row for this (b, f, z)
            pl.BlockSpec((1, D), lambda b, f, z, ids_ref, mask_ref:
                         (ids_ref[b, f, z], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D),
                               lambda b, f, z, ids_ref, mask_ref: (b, f, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_bag_kernel, nnz=NNZ, combiner=combiner),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, F, D), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), mask.astype(jnp.float32), table)
    return out
