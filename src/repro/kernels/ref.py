"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the mathematical definition with no blocking/tiling —
tests sweep shapes/dtypes and assert_allclose kernels against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jnp.ndarray:
    """q [B,H,S,d]; k/v [B,Hkv,S,d]. Dense softmax attention."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_reference(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray,
                     window: int = 0, softcap: float = 0.0) -> jnp.ndarray:
    """q [B,H,d]; caches [B,Hkv,S,d]; lengths [B] (valid prefix, incl. pos)."""
    B, H, d = q.shape
    Hkv = k_cache.shape[1]
    G = H // Hkv
    k = jnp.repeat(k_cache, G, axis=1)
    v = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(k.shape[2])[None, None, :]
    valid = kpos < lengths[:, None, None]
    if window > 0:
        valid &= kpos >= (lengths[:, None, None] - window)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def segment_sum_sorted_reference(msg: jnp.ndarray, dst: jnp.ndarray,
                                 n_nodes: int) -> jnp.ndarray:
    """msg [E, D], dst [E] sorted ascending. -> [n_nodes, D]."""
    return jax.ops.segment_sum(msg, dst, num_segments=n_nodes,
                               indices_are_sorted=True)


def embedding_bag_reference(table: jnp.ndarray, ids: jnp.ndarray,
                            mask: jnp.ndarray,
                            combiner: str = "mean") -> jnp.ndarray:
    """table [V, D]; ids/mask [B, F, NNZ] -> [B, F, D]."""
    emb = table[ids] * mask[..., None].astype(table.dtype)
    s = emb.sum(axis=2)
    if combiner == "sum":
        return s
    cnt = jnp.maximum(mask.sum(axis=2), 1.0)[..., None].astype(table.dtype)
    return s / cnt


def triple_scan_reference(triples: jnp.ndarray, s: int, p: int,
                          o: int) -> jnp.ndarray:
    """triples [T, 3] int32; s/p/o pattern ids, -1 == wildcard.

    Returns int32 match mask [T]."""
    m = jnp.ones(triples.shape[0], bool)
    if s >= 0:
        m &= triples[:, 0] == s
    if p >= 0:
        m &= triples[:, 1] == p
    if o >= 0:
        m &= triples[:, 2] == o
    return m.astype(jnp.int32)


def probe_sorted_reference(keys: jnp.ndarray,
                           probes: jnp.ndarray) -> tuple[jnp.ndarray,
                                                         jnp.ndarray]:
    """keys [K] sorted ascending; probes [...]. -> (lo, hi) searchsorted
    left/right bounds, the matcher's ``np.searchsorted`` probe."""
    lo = jnp.searchsorted(keys, probes, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(keys, probes, side="right").astype(jnp.int32)
    return lo, hi


def scan_probe_reference(triples: jnp.ndarray, s: int, p: int, o: int,
                         keys: jnp.ndarray,
                         col: int) -> tuple[jnp.ndarray, jnp.ndarray,
                                            jnp.ndarray]:
    """Fused scan+probe oracle: scan mask plus searchsorted bounds of
    every row's probe-column value (col 0 = subject, 2 = object)."""
    mask = triple_scan_reference(triples, s, p, o)
    lo, hi = probe_sorted_reference(keys, triples[:, col])
    return mask, lo, hi
