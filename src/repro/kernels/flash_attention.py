"""Flash attention (Pallas TPU): causal / sliding-window / softcap / GQA.

Canonical online-softmax blocking for the MXU:

  grid = (batch * q_heads, S/bq, S/bk) — the innermost kv-block axis is a
  sequential TPU grid dimension, so running max / sum / accumulator live in
  VMEM scratch that persists across kv steps for a fixed (head, q-block).

BlockSpecs stream q/k/v tiles HBM -> VMEM; fully-masked kv blocks under the
causal/window pattern are skipped with ``pl.when`` (no DMA compute waste).
Validated on CPU in interpret mode against ``ref.mha_reference``; compiled
path targets TPU v5e (bq = bk = 128 aligns with the 128x128 MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, window: int, softcap: float, bq: int,
                  bk: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)

    # skip fully-masked blocks (strictly above the diagonal / out of window)
    block_live = kj * bk <= qi * bq + bq - 1
    if window > 0:
        block_live &= (kj + 1) * bk - 1 > qi * bq - window

    @pl.when(block_live)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q [B, H, S, d]; k/v [B, Hkv, S, d] (GQA: H multiple of Hkv).

    window > 0 adds a sliding-window constraint on top of causal.
    """
    if not causal:
        raise NotImplementedError("decoder-only framework: causal attention")
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    scale = d ** -0.5

    qf = q.reshape(B * H, S, d)
    # expand kv heads to q heads (index map arithmetic keeps it view-only)
    kf = k.reshape(B * Hkv, S, d)
    vf = v.reshape(B * Hkv, S, d)

    def q_map(i, qi, kj):
        return (i, qi, 0)

    def kv_map(i, qi, kj):
        # i = b * H + h ; the kv head serving q head h is h // G
        return ((i // H) * Hkv + (i % H) // G, kj, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, window=window,
                          softcap=softcap, bq=bq, bk=bk, n_kv_blocks=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
