"""Sorted-probe join (Pallas TPU) — the device side of the matcher's join.

``sparql.matcher`` joins a new pattern into the current binding table by
probing each bound value into a *sorted* key column of the candidate set
(``np.searchsorted`` left/right -> ``[lo, hi)`` run bounds over the
``pred_index`` views built in PR 3). This module is the device analogue:

* :func:`probe_sorted` / :func:`probe_sorted_many` — binary search as a
  branchless compare-and-count streaming reduction.  For every probe value
  ``v``: ``lo = sum(keys < v)`` and ``hi = sum(keys <= v)``, accumulated
  block-by-block while the sorted key column streams HBM -> VMEM.  On the
  VPU this beats a gather-based bisection (vector gathers are the weak
  spot; dense compares are free), and the result is *bit-identical* to
  ``np.searchsorted(keys, v, "left"/"right")``.
* :func:`scan_probe` — the fused scan->join kernel for the common
  bound-predicate star shape: one launch computes the candidate-scan mask
  AND the first join's ``[lo, hi)`` bounds from the matched rows' subject
  or object column, with no intermediate materialization between scan and
  probe.

Everything takes the true (unpadded) lengths via scalar prefetch, so key /
probe padding values never affect the counts and ONE compiled kernel
serves every (pattern, key-column) pair — no recompilation per query.
Expansion of the ``[lo, hi)`` runs into binding rows stays in XLA
(cumsum + repeat + gather), mirroring how the scan kernel leaves
compaction to XLA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_PAD_KEY = jnp.iinfo(jnp.int32).max   # sorted-column padding (ignored via K)


def _probe_many_kernel(meta_ref, key_ref, probe_ref, lo_ref, hi_ref, *,
                       bk: int, bp: int):
    ki = pl.program_id(2)
    n_keys = meta_ref[0]
    keys = key_ref[...]                                # [bk] int32
    v = probe_ref[...]                                 # [1, bp] int32
    # index-mask the key padding: only positions < n_keys count
    idx = jax.lax.broadcasted_iota(jnp.int32, (bk, bp), 0) + ki * bk
    valid = idx < n_keys                               # [bk, bp]
    kv = keys[:, None]                                 # [bk, 1]
    lo_blk = ((kv < v) & valid).astype(jnp.int32).sum(axis=0)    # [bp]
    hi_blk = ((kv <= v) & valid).astype(jnp.int32).sum(axis=0)   # [bp]

    @pl.when(ki == 0)
    def _init():
        lo_ref[...] = lo_blk[None, :]
        hi_ref[...] = hi_blk[None, :]

    @pl.when(ki > 0)
    def _acc():
        lo_ref[...] += lo_blk[None, :]
        hi_ref[...] += hi_blk[None, :]


@functools.partial(jax.jit, static_argnames=("bk", "bp", "interpret"))
def probe_sorted_many(keys: jnp.ndarray, probes: jnp.ndarray,
                      bk: int = 2048, bp: int = 512,
                      interpret: bool = False) -> tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Batched sorted probe: keys [K] ascending, probes [Q, P] -> lo/hi [Q, P].

    ``lo[q, j] == np.searchsorted(keys, probes[q, j], "left")`` and
    ``hi[q, j]`` the ``"right"`` bound; ``hi - lo`` is each probe's run
    length.  Key padding is masked by true length (any probe value is
    safe); pad *probes* with ``-1`` to get ``lo == hi == 0`` runs for
    non-negative id spaces.
    """
    K = keys.shape[0]
    Q, P = probes.shape
    k_pad = max(bk, ((K + bk - 1) // bk) * bk)
    if k_pad != K:
        keys = jnp.pad(keys, (0, k_pad - K), constant_values=_PAD_KEY)
    p_pad = ((P + bp - 1) // bp) * bp
    if p_pad != P:
        probes = jnp.pad(probes, ((0, 0), (0, p_pad - P)),
                         constant_values=-1)
    meta = jnp.asarray([K], jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, p_pad // bp, k_pad // bk),
        in_specs=[pl.BlockSpec((bk,), lambda qi, pi, ki, meta: (ki,)),
                  pl.BlockSpec((1, bp), lambda qi, pi, ki, meta: (qi, pi))],
        out_specs=[pl.BlockSpec((1, bp), lambda qi, pi, ki, meta: (qi, pi)),
                   pl.BlockSpec((1, bp), lambda qi, pi, ki, meta: (qi, pi))],
    )
    lo, hi = pl.pallas_call(
        functools.partial(_probe_many_kernel, bk=bk, bp=bp),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Q, p_pad), jnp.int32),
                   jax.ShapeDtypeStruct((Q, p_pad), jnp.int32)],
        interpret=interpret,
    )(meta, keys.astype(jnp.int32), probes.astype(jnp.int32))
    return lo[:, :P], hi[:, :P]


def probe_sorted(keys: jnp.ndarray, probes: jnp.ndarray, bk: int = 2048,
                 bp: int = 512,
                 interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted probe: keys [K] ascending, probes [P] -> (lo [P], hi [P])."""
    lo, hi = probe_sorted_many(keys, probes[None, :], bk=bk, bp=bp,
                               interpret=interpret)
    return lo[0], hi[0]


def _scan_probe_kernel(meta_ref, key_ref, trip_ref, mask_ref, lo_ref, hi_ref,
                       *, bt: int, bk: int):
    ki = pl.program_id(1)
    s, p, o = meta_ref[0], meta_ref[1], meta_ref[2]
    col, n_keys = meta_ref[3], meta_ref[4]
    t = trip_ref[...]                                  # [bt, 3] int32
    keys = key_ref[...]                                # [bk] int32

    vals = jnp.where(col == 0, t[:, 0], t[:, 2])       # probe column [bt]
    idx = jax.lax.broadcasted_iota(jnp.int32, (bt, bk), 1) + ki * bk
    valid = idx < n_keys                               # [bt, bk]
    kv = keys[None, :]                                 # [1, bk]
    lo_blk = ((kv < vals[:, None]) & valid).astype(jnp.int32).sum(axis=1)
    hi_blk = ((kv <= vals[:, None]) & valid).astype(jnp.int32).sum(axis=1)

    @pl.when(ki == 0)
    def _init():
        m = jnp.ones((bt,), jnp.bool_)
        m &= (t[:, 0] == s) | (s < 0)
        m &= (t[:, 1] == p) | (p < 0)
        m &= (t[:, 2] == o) | (o < 0)
        mask_ref[...] = m.astype(jnp.int32)
        lo_ref[...] = lo_blk
        hi_ref[...] = hi_blk

    @pl.when(ki > 0)
    def _acc():
        lo_ref[...] += lo_blk
        hi_ref[...] += hi_blk


@functools.partial(jax.jit,
                   static_argnames=("col", "bt", "bk", "interpret"))
def scan_probe(triples: jnp.ndarray, pattern: jnp.ndarray,
               keys: jnp.ndarray, col: int, bt: int = 2048, bk: int = 2048,
               interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray]:
    """Fused candidate scan + first-join probe in one kernel launch.

    triples [T, 3] int32; pattern [3] int32 (-1 wildcards); keys [K] int32
    ascending sorted; ``col`` selects the probe column (0 = subject,
    2 = object).  Returns ``(mask [T], lo [T], hi [T])`` where ``mask`` is
    the scan match mask and ``lo/hi`` are searchsorted left/right bounds of
    *every* row's probe-column value (consumers take
    ``counts = where(mask, hi - lo, 0)``) — the star-shape seed scan and
    its first equi-join without materializing the matched rows in between.
    """
    if col not in (0, 2):
        raise ValueError(f"col must be 0 (subject) or 2 (object), got {col}")
    T = triples.shape[0]
    K = keys.shape[0]
    t_pad = max(bt, ((T + bt - 1) // bt) * bt)
    if t_pad != T:
        triples = jnp.pad(triples, ((0, t_pad - T), (0, 0)),
                          constant_values=-2)          # never matches
    k_pad = max(bk, ((K + bk - 1) // bk) * bk)
    if k_pad != K:
        keys = jnp.pad(keys, (0, k_pad - K), constant_values=_PAD_KEY)
    meta = jnp.concatenate([pattern.astype(jnp.int32),
                            jnp.asarray([col, K], jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t_pad // bt, k_pad // bk),
        in_specs=[pl.BlockSpec((bk,), lambda ti, ki, meta: (ki,)),
                  pl.BlockSpec((bt, 3), lambda ti, ki, meta: (ti, 0))],
        out_specs=[pl.BlockSpec((bt,), lambda ti, ki, meta: (ti,)),
                   pl.BlockSpec((bt,), lambda ti, ki, meta: (ti,)),
                   pl.BlockSpec((bt,), lambda ti, ki, meta: (ti,))],
    )
    mask, lo, hi = pl.pallas_call(
        functools.partial(_scan_probe_kernel, bt=bt, bk=bk),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((t_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((t_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((t_pad,), jnp.int32)],
        interpret=interpret,
    )(meta, keys.astype(jnp.int32), triples.astype(jnp.int32))
    return mask[:T], lo[:T], hi[:T]
