"""Flash-decode attention (Pallas TPU): one query vs a long KV cache.

Decode is memory-bound: the whole cache streams HBM -> VMEM once per step.
Grid = (batch * q_heads, S/bk) with the kv axis innermost/sequential; the
online-softmax state (m, l, acc) lives in VMEM scratch, exactly the
FlashDecoding split-K pattern collapsed onto the sequential TPU grid.
Variable per-sequence lengths arrive via scalar prefetch
(PrefetchScalarGridSpec) so masking needs no [B, S] tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   softcap: float, bk: int, n_kv_blocks: int, n_heads: int):
    i = pl.program_id(0)          # b * H + h
    kj = pl.program_id(1)
    b = i // n_heads
    length = lengths_ref[b]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo = kj * bk
    block_live = lo < length
    if window > 0:
        block_live &= (lo + bk) > (length - window)

    @pl.when(block_live)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [1, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap     # [1, bk]
        kpos = lo + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = kpos < length
        if window > 0:
            valid &= kpos >= (length - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "bk", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray,
                     window: int = 0, softcap: float = 0.0, bk: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """q [B,H,d]; caches [B,Hkv,S,d]; lengths [B] valid prefix sizes."""
    B, H, d = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    scale = d ** -0.5

    qf = q.reshape(B * H, 1, d)
    kf = k_cache.reshape(B * Hkv, S, d)
    vf = v_cache.reshape(B * Hkv, S, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, kj, L: (i, 0, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda i, kj, L: ((i // H) * Hkv + (i % H) // G,
                                           kj, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda i, kj, L: ((i // H) * Hkv + (i % H) // G,
                                           kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, kj, L: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          softcap=softcap, bk=bk, n_kv_blocks=nk,
                          n_heads=H),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, d)
