"""GNN segment-sum message passing (Pallas TPU): scatter as one-hot matmul.

The message-passing primitive ``out[n] = Σ_{e: dst[e]==n} msg[e]`` is a
scatter — hostile to the MXU as pointer chasing, friendly as a matmul:
for an edge chunk C and node block N_b,

    out[N_b] += onehot(dst_chunk - base)^T  @  msg_chunk      (MXU GEMM)

Edges arrive **sorted by destination** (the framework sorts once per graph),
so each node block touches a contiguous edge range, delivered via scalar-
prefetched CSR offsets; the grid walks (node_block, edge_chunk) with the
chunk axis innermost and an accumulator in VMEM scratch.

This is the FeatGraph/GE-SpMM gather-GEMM-scatter schedule adapted to the
TPU memory hierarchy (see DESIGN.md §Hardware-adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segmp_kernel(starts_ref, msg_ref, dst_ref, o_ref, acc_scr, *,
                  bn: int, bc: int, n_chunks: int):
    ni = pl.program_id(0)          # node block
    cj = pl.program_id(1)          # edge chunk (within this node block range)

    @pl.when(cj == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = ni * bn
    lo = starts_ref[ni]            # first edge of this node block
    hi = starts_ref[ni + 1]
    # BlockSpec streams bc-ALIGNED chunks; the block's range may start
    # mid-chunk, so mask positions outside [lo, hi) explicitly.
    aligned = ((lo + cj * bc) // bc) * bc

    @pl.when(aligned < hi)
    def _body():
        msg = msg_ref[...].astype(jnp.float32)          # [bc, D]
        dst = dst_ref[...]                              # [bc]
        epos = aligned + jax.lax.broadcasted_iota(jnp.int32, (bc,), 0)
        valid = (epos >= lo) & (epos < hi)
        local = jnp.where(valid, dst - base, bn)        # bn == dump row
        onehot = (local[:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bc, bn), 1))
        onehot = (onehot & valid[:, None]).astype(jnp.float32)
        acc_scr[...] += jax.lax.dot_general(
            onehot, msg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [bn, D]

    @pl.when(cj == n_chunks - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "bn", "bc", "interpret"))
def segment_sum_sorted(msg: jnp.ndarray, dst: jnp.ndarray, n_nodes: int,
                       bn: int = 128, bc: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """msg [E, D] edge messages; dst [E] int32 sorted ascending.

    Returns [n_nodes, D] segment sums. E and n_nodes are padded to block
    multiples internally.
    """
    E, D = msg.shape
    n_pad = ((n_nodes + bn - 1) // bn) * bn
    e_pad = ((E + bc - 1) // bc) * bc
    if e_pad != E:
        msg = jnp.pad(msg, ((0, e_pad - E), (0, 0)))
        dst = jnp.pad(dst, (0, e_pad - E), constant_values=n_pad)
    n_blocks = n_pad // bn

    # CSR-ish block offsets: first edge index whose dst >= block base
    bases = jnp.arange(n_blocks + 1, dtype=jnp.int32) * bn
    starts = jnp.searchsorted(dst, bases).astype(jnp.int32)
    # worst-case chunks a block can span (static): all edges + misalignment
    max_chunks = max(1, e_pad // bc + 1)
    last_chunk = e_pad // bc - 1

    def msg_map(ni, cj, starts_ref):
        # aligned chunk containing (block start + cj*bc); clamped — the
        # kernel's range mask kills any out-of-range iteration
        return (jnp.minimum((starts_ref[ni] + cj * bc) // bc, last_chunk), 0)

    def dst_map(ni, cj, starts_ref):
        return (jnp.minimum((starts_ref[ni] + cj * bc) // bc, last_chunk),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks, max_chunks),
        in_specs=[
            pl.BlockSpec((bc, D), msg_map),
            pl.BlockSpec((bc,), dst_map),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda ni, cj, s: (ni, 0)),
        scratch_shapes=[pltpu.VMEM((bn, D), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_segmp_kernel, bn=bn, bc=bc,
                          n_chunks=max_chunks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, D), msg.dtype),
        interpret=interpret,
    )(starts, msg, dst.astype(jnp.int32))
    return out[:n_nodes]
