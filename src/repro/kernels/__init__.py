# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from __future__ import annotations

import functools


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    Compiled on real accelerators (TPU / GPU), interpret-mode on CPU where
    Mosaic cannot lower.  Every kernel call site should route its default
    through this single helper so real hardware never silently runs the
    slow interpreter (and CPU CI never tries to compile).
    """
    import jax

    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
