"""Generic training loop: microbatching, checkpoints, straggler monitor.

Family-agnostic: anything exposing ``loss_fn(params, batch) -> (loss, aux)``
trains through here (LM, GNN, recsys — see repro/configs). The jitted step
does grad accumulation over microbatches with ``lax.scan``, AdamW update,
and returns scalar metrics only (device->host traffic stays tiny).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .fault_tolerance import StragglerMonitor, with_retries


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    microbatches: int = 1          # grad accumulation factor
    log_every: int = 10
    ckpt_every: int = 0            # 0 == disabled
    ckpt_dir: str = ""
    keep_last: int = 2
    straggler_factor: float = 5.0
    retries: int = 1


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, ``batch`` leaves must lead with that axis:
    [microbatches, per_micro, ...]; grads are averaged across microbatches.
    """

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, aux, grads

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, aux, grads = grads_of(params, batch)
        else:
            def body(acc, micro):
                loss, aux, grads = grads_of(params, micro)
                acc_loss, acc_grads = acc
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_loss + loss, acc_grads), aux
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (loss_sum, gsum), auxs = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), batch)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            aux = jax.tree.map(lambda x: x[-1], auxs)
        params, opt_state, info = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **info}
        if isinstance(aux, dict):
            metrics.update(aux)
        return params, opt_state, metrics

    return step


@dataclass
class TrainResult:
    params: object
    opt_state: object
    history: list = field(default_factory=list)
    resumed_from: int | None = None
    straggler_steps: list = field(default_factory=list)


def train(loss_fn: Callable, params, batch_iter, opt_cfg: AdamWConfig,
          loop_cfg: TrainLoopConfig, jit_kwargs: dict | None = None,
          log=print) -> TrainResult:
    """Run the loop; resumes from loop_cfg.ckpt_dir if checkpoints exist."""
    step_fn = make_train_step(loss_fn, opt_cfg, loop_cfg.microbatches)
    step_fn = jax.jit(step_fn, **(jit_kwargs or {}))
    opt_state = adamw_init(params)

    start = 0
    resumed = None
    if loop_cfg.ckpt_dir and latest_step(loop_cfg.ckpt_dir) is not None:
        start, state = restore_checkpoint(
            loop_cfg.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        resumed = start
        log(f"[train] resumed from step {start}")

    monitor = StragglerMonitor(factor=loop_cfg.straggler_factor)
    history = []
    for step in range(start, loop_cfg.total_steps):
        batch = next(batch_iter)
        t0 = time.perf_counter()
        run = with_retries(step_fn, loop_cfg.retries)
        params, opt_state, metrics = run(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if monitor.observe(step, dt):
            log(f"[train] straggler at step {step}: {dt:.3f}s")
        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            vals = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, "seconds": dt, **vals})
            log(f"[train] step {step} loss {vals['loss']:.4f} "
                f"({dt * 1e3:.1f} ms)")
        if (loop_cfg.ckpt_every and loop_cfg.ckpt_dir
                and (step + 1) % loop_cfg.ckpt_every == 0):
            save_checkpoint(loop_cfg.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            keep_last=loop_cfg.keep_last)
    return TrainResult(params=params, opt_state=opt_state, history=history,
                       resumed_from=resumed,
                       straggler_steps=list(monitor.flagged_steps))
