"""Model serving with the paper's offload scheduler as admission layer.

The MINLP scheduler is workload-agnostic: it places any task with (cycles,
result-bytes, executability mask). Here it routes *inference requests*
across a pool of "edge" replicas (each serving a subset of request classes —
the analogue of pattern residency) and a "cloud" fallback pool, then the
replicas execute their assigned requests in one batch each.

This is the paper's technique as a first-class serving feature — the same
``core.scheduler`` object schedules SPARQL queries in repro/edge and model
inference here.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..core.cost import (DEFAULT_BACKHAUL_BPS, PartialOption, QueryTasks,
                         SystemParams)
from ..core.scheduler import schedule

PARTIAL = -2   # ServedBatch.assignments sentinel: multi-replica partial plan


@dataclass
class Replica:
    """An edge replica: which request classes it serves + its capacity."""

    replica_id: int
    classes: set[int]
    cycles_per_s: float
    link_bps: float                      # replica -> client rate
    runner: Callable | None = None       # batch of requests -> responses


@dataclass
class ServedBatch:
    assignments: np.ndarray              # [N] replica idx, -1 cloud, -2 partial
    objective: float
    schedule_seconds: float
    responses: list = field(default_factory=list)
    # partial-plan accounting: requests served by a multi-replica split
    # (sub-payloads at several replicas, assembled afterwards) and their
    # total estimated inter-replica egress
    partial_queries: int = 0
    partial_bytes_shipped: int = 0


class OffloadServingPool:
    """Schedule + execute one admission batch of requests.

    Replica class sets are the serving analogue of edge pattern residency;
    :meth:`republish` swaps one replica's classes (and optionally its
    runner) atomically under the pool lock and bumps ``epoch`` — the same
    commit-at-a-barrier contract :class:`repro.edge.rebalance.
    RebalanceManager` gives the SPARQL system, so an admission batch
    snapshots ONE epoch's feasibility and never routes a request class to
    a replica mid-swap.
    """

    def __init__(self, replicas: list[Replica], cloud_runner: Callable,
                 cloud_link_bps: float = 5e6,
                 cloud_cycles_per_s: float = np.inf,
                 backhaul_bps: float = DEFAULT_BACKHAUL_BPS) -> None:
        self.replicas = replicas
        self.cloud_runner = cloud_runner
        self.cloud_link_bps = cloud_link_bps
        # generalized-Eq.-5 knobs: a finite cloud capacity prices cloud
        # compute (and partial assembly); ``backhaul_bps`` prices the
        # replica -> assembler egress of partial plans
        self.cloud_cycles_per_s = float(cloud_cycles_per_s)
        self.backhaul_bps = float(backhaul_bps)
        self._lock = threading.Lock()
        self.epoch = 0

    def republish(self, replica_id: int, classes,
                  runner: Callable | None = None) -> int:
        """Atomically update a replica's served classes (+runner); returns
        the new epoch. Concurrent ``admit`` calls see either the old or the
        new class set, never a partial one."""
        with self._lock:
            for rep in self.replicas:
                if rep.replica_id == replica_id:
                    rep.classes = set(classes)
                    if runner is not None:
                        rep.runner = runner
                    break
            else:
                raise KeyError(f"no replica {replica_id!r}")
            self.epoch += 1
            return self.epoch

    def admit(self, requests: list[dict], policy: str = "bnb",
              execute: bool = True, overlap: bool = False,
              max_workers: int | None = None, **sched_kw) -> ServedBatch:
        """requests: dicts with {class_id, cycles, result_bits, payload}.

        ``overlap=True`` runs the per-replica (and cloud-pool) batches
        through a thread pool instead of serializing them — the serving
        analogue of ``EdgeCloudSystem.run_round_batched(overlap=True)``.
        Runners must be thread-safe (``make_sparql_runner`` engines are:
        their caches are lock-guarded).

        A request may carry a ``"partial"`` spec — the serving analogue of
        cloud-edge partial evaluation — giving the scheduler a third,
        multi-replica option priced by the generalized Eq. 5::

            {"replicas": [replica_id, ...],      # contributing replicas
             "cycles": [...], "ship_bits": [...],  # per-replica estimates
             "assemble_cycles": float,           # assembler-side work
             "payloads": {replica_id: payload},  # per-replica sub-payload
             "assemble": callable | None}        # sub-results -> response

        When chosen, its row in ``assignments`` is ``PARTIAL`` (-2): each
        contributing replica runs its sub-payload, and ``assemble`` (or
        plain collection) combines the sub-results. If any contributing
        replica has no runner the whole request transparently falls back
        to the cloud pool with ``payload``.
        """
        N, K = len(requests), len(self.replicas)
        c = np.array([r["cycles"] for r in requests], dtype=np.float64)
        w = np.array([r["result_bits"] for r in requests], dtype=np.float64)
        # snapshot ONE epoch's class sets (and runners), so e_nk rows and
        # dispatch can't straddle a concurrent republish
        with self._lock:
            classes = [set(rep.classes) for rep in self.replicas]
            runners = [rep.runner for rep in self.replicas]
        idx_of = {rep.replica_id: j for j, rep in enumerate(self.replicas)}
        e = np.zeros((N, K))
        for i, r in enumerate(requests):
            for j in range(K):
                if r["class_id"] in classes[j]:
                    e[i, j] = 1.0
        partial: list | None = [None] * N
        for i, r in enumerate(requests):
            spec = r.get("partial")
            if spec is None or e[i].sum() > 0:   # full-replica dominates
                continue
            reps = np.array([idx_of[rid] for rid in spec["replicas"]],
                            dtype=np.int64)
            partial[i] = PartialOption(
                edges=reps,
                cycles=np.asarray(spec["cycles"], dtype=np.float64),
                ship_bits=np.asarray(spec["ship_bits"], dtype=np.float64),
                assemble_cycles=float(spec.get("assemble_cycles", 0.0)),
                plan=spec)
        if not any(p is not None for p in partial):
            partial = None
        params = SystemParams(
            F=np.array([rep.cycles_per_s for rep in self.replicas]),
            r_edge=np.tile(np.array([rep.link_bps
                                     for rep in self.replicas]), (N, 1)),
            r_cloud=np.full(N, self.cloud_link_bps),
            assoc=np.ones((N, K), dtype=bool),
            r_backhaul=np.full(K, self.backhaul_bps),
            F_cloud=self.cloud_cycles_per_s,
        )
        tasks = QueryTasks(c=c, w=w, e=e, partial=partial)
        t0 = time.perf_counter()
        sr = schedule(tasks, params, policy=policy, **sched_kw)
        dt = time.perf_counter() - t0
        assign = np.full(N, -1, dtype=np.int64)
        De = sr.D * e
        for i in range(N):
            if (sr.partial is not None and sr.partial[i]
                    and tasks.partial_option(i) is not None):
                assign[i] = PARTIAL
            elif De[i].sum() > 0:
                assign[i] = int(De[i].argmax())

        responses: list = [None] * N
        shipped_bits = 0.0
        if execute:
            # a replica with no runner cannot execute anything: route its
            # requests to the cloud *and say so* — assignments must report
            # the placement that actually ran, or the Eq. 5 objective and
            # the executed placement disagree (execute=False keeps the raw
            # scheduler output for simulation studies)
            for j in range(K):
                if runners[j] is None:
                    assign[assign == j] = -1
            part_rows = []
            for i in np.flatnonzero(assign == PARTIAL):
                spec = requests[i]["partial"]
                reps = [idx_of[rid] for rid in spec["replicas"]]
                if any(runners[j] is None for j in reps):
                    assign[i] = -1       # runnerless contributor: whole
                    continue             # request falls back to the cloud
                part_rows.append(int(i))
            groups = []
            for j in list(range(K)) + [-1]:
                idx = np.flatnonzero(assign == j)
                if len(idx):
                    groups.append((j, idx))

            def run_group(j: int, idx: np.ndarray):
                runner = self.cloud_runner if j < 0 else runners[j]
                return idx, runner([requests[i]["payload"] for i in idx])

            if overlap:
                from ..core.parallel import thread_map
                done = thread_map(lambda g: run_group(*g), groups,
                                  max_workers)
            else:
                done = [run_group(j, idx) for j, idx in groups]
            for idx, outs in done:
                for i, o in zip(idx, outs):
                    responses[i] = o
            for i in part_rows:
                spec = requests[i]["partial"]
                subs = [runners[idx_of[rid]]([spec["payloads"][rid]])[0]
                        for rid in spec["replicas"]]
                asm = spec.get("assemble")
                responses[i] = asm(subs) if asm is not None else subs
                shipped_bits += float(np.asarray(
                    spec["ship_bits"], dtype=np.float64).sum())
        return ServedBatch(assignments=assign, objective=sr.objective,
                           schedule_seconds=dt, responses=responses,
                           partial_queries=int((assign == PARTIAL).sum()),
                           partial_bytes_shipped=int(shipped_bits // 8))


def make_sparql_runner(store, engine) -> Callable:
    """Replica runner serving SPARQL payloads through a query engine.

    ``store`` is any :class:`repro.rdf.graph.RDFStore` — a monolithic
    :class:`~repro.rdf.graph.TripleStore` or a
    :class:`~repro.rdf.sharding.ShardedTripleStore` (whose bound-predicate
    scans prune to one shard). ``payload`` items are
    :class:`repro.sparql.query.QueryGraph`\\ s and/or compiled algebra
    plans (:mod:`repro.sparql.algebra` — FILTER/OPTIONAL/UNION/modifiers);
    the whole per-replica assignment executes as ONE engine batch (every
    algebra plan's BGP leaves included), so scan dedup, the scan LRU, and
    the result cache apply across the admission batch — the SPARQL
    instantiation of this pool's batch-execution contract. Plain payloads
    yield :class:`~repro.sparql.matcher.MatchResult`\\ s, algebra payloads
    :class:`~repro.sparql.algebra.SolutionTable`\\ s.
    """
    from ..sparql.algebra import execute_any_batch

    def runner(payloads: list) -> list:
        return execute_any_batch(store, engine, list(payloads))
    return runner
