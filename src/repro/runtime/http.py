"""SPARQL-Protocol-style HTTP front end over the admission queue.

Stdlib only (``http.server.ThreadingHTTPServer``): the constraint of this
repo is zero new dependencies, and a thread-per-connection server is
exactly right here — each handler thread blocks on its
:class:`~repro.runtime.admission.Ticket` while the admission dispatcher
coalesces all concurrently waiting requests into ONE engine batch. The
concurrency win comes from the admission layer, not the HTTP layer.

Routes (subset of the W3C SPARQL 1.1 Protocol):

- ``GET /sparql?query=...`` — also ``timeout`` (seconds) and ``user``
  (integer id, routes ``mode="round"`` scheduling) parameters.
- ``POST /sparql`` with ``application/sparql-query`` (raw query body) or
  ``application/x-www-form-urlencoded`` (``query=`` field).
- ``POST /sparql`` with ``application/sparql-update`` (raw ``INSERT DATA``
  / ``DELETE DATA`` / ``DELETE WHERE`` body) or a form ``update=`` field —
  the write rides the same admission queue, serializing against the
  micro-batch window it shares (reads first, then the write commits), and
  returns a JSON ack (``inserted``/``deleted``/``new_terms``/...).
- ``GET /stats`` — admission + engine counters as JSON.
- ``GET /healthz`` — liveness probe.

Results are W3C *SPARQL 1.1 Query Results JSON*: SELECT returns
``{"head": {"vars": [...]}, "results": {"bindings": [...]}}`` with unbound
variables omitted from their binding object (per spec); ASK returns
``{"head": {}, "boolean": ...}``. Term typing: the dictionary keeps
predicate and entity ids in disjoint spaces but records no IRI/literal
distinction, so predicate-space terms serialize as ``"type": "uri"`` and
entity-space terms as ``"type": "literal"`` — lossless for round-tripping
through this repo's own parser, approximate against full RDF.

Status mapping: 400 (:class:`~repro.sparql.query.ParseError`), 404
(unknown path), 415 (unsupported POST content type), 503 + ``Retry-After``
(:class:`~repro.runtime.admission.AdmissionFullError` — queue full), 504
(:class:`~repro.runtime.admission.DeadlineExceeded`), 500 (engine error).

>>> with SparqlHttpServer(endpoint, window_s=0.002) as srv:
...     urllib.request.urlopen(srv.url + "/sparql?query=" + quote(q))
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from ..sparql.algebra import AskNode, SolutionTable
from ..sparql.query import ParseError
from .admission import (AdmissionClosed, AdmissionFullError, AdmissionQueue,
                        DeadlineExceeded)

RESULTS_JSON = "application/sparql-results+json"


def table_to_json(table: SolutionTable) -> dict:
    """:class:`SolutionTable` -> W3C SPARQL JSON results ``dict``.

    Unbound cells are omitted from their row's binding object (the spec's
    representation of OPTIONAL/UNION non-bindings, *not* an empty-string
    binding). Predicate-space variables type as ``uri``, entity-space as
    ``literal`` (see module docstring). Variable names drop the parser's
    leading ``?`` (the spec's bare-name form).
    """
    names = [v.lstrip("?") for v in table.var_names]
    bindings = []
    for row in table.rows(decoded=True):
        b = {}
        for var, name, term in zip(table.var_names, names, row):
            if term is None:
                continue
            kind = "uri" if var in table.pred_vars else "literal"
            b[name] = {"type": kind, "value": term}
        bindings.append(b)
    return {"head": {"vars": names},
            "results": {"bindings": bindings}}


def ask_to_json(table: SolutionTable) -> dict:
    return {"head": {}, "boolean": bool(table.num_matches > 0)}


class _Handler(BaseHTTPRequestHandler):
    # one keep-alive thread per client connection (ThreadingHTTPServer)
    protocol_version = "HTTP/1.1"
    server_version = "repro-sparql/1.0"
    # buffer the whole response (status+headers+body) into ONE socket send
    # (handle_one_request flushes per request): the stdlib default writes
    # headers and body as separate small segments, and Nagle + delayed-ACK
    # turns that into a ~40ms stall per response on loopback
    wbufsize = -1
    disable_nagle_algorithm = True

    # -- plumbing ------------------------------------------------------------
    def log_message(self, fmt, *args):     # noqa: N802 - stdlib name
        pass                               # benches hammer this; stay quiet

    def _send(self, status: int, payload: dict,
              extra_headers: dict | None = None,
              ctype: str | None = None) -> None:
        body = json.dumps(payload).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", ctype or (
            RESULTS_JSON if status == 200 else "application/json"))
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True   # client went away mid-write

    def _error(self, status: int, message: str,
               extra_headers: dict | None = None) -> None:
        self._send(status, {"error": message}, extra_headers)

    # -- request handling ----------------------------------------------------
    def do_GET(self):                      # noqa: N802 - stdlib name
        url = urlsplit(self.path)
        if url.path == "/healthz":
            self._send(200, {"ok": True})
            return
        if url.path == "/stats":
            self._send(200, self.server.front.stats_dict())
            return
        if url.path != "/sparql":
            self._error(404, f"no route {url.path!r}")
            return
        params = parse_qs(url.query)
        query = params.get("query", [None])[0]
        if not query:
            self._error(400, "missing 'query' parameter")
            return
        self._serve_query(query, params)

    def do_POST(self):                     # noqa: N802 - stdlib name
        url = urlsplit(self.path)
        if url.path != "/sparql":
            self._error(404, f"no route {url.path!r}")
            return
        ctype = self.headers.get("Content-Type", "").split(";")[0].strip()
        length = int(self.headers.get("Content-Length", 0))
        body = self.rfile.read(length).decode("utf-8") if length else ""
        params = parse_qs(url.query)
        if ctype == "application/sparql-query":
            query = body
        elif ctype == "application/sparql-update":
            if not body:
                self._error(400, "missing update body")
                return
            self._serve_update(body, params)
            return
        elif ctype == "application/x-www-form-urlencoded":
            form = parse_qs(body)
            for k in ("timeout", "user"):      # form fields join URL params
                if k in form:
                    params.setdefault(k, form[k])
            update = form.get("update", [None])[0]
            if update:
                self._serve_update(update, params)
                return
            query = form.get("query", [None])[0]
        else:
            self._error(415, f"unsupported content type {ctype!r}; use "
                        "application/sparql-query, "
                        "application/sparql-update or "
                        "application/x-www-form-urlencoded")
            return
        if not query:
            self._error(400, "missing query")
            return
        self._serve_query(query, params)

    def _serve_query(self, query: str, params: dict) -> None:
        front: SparqlHttpServer = self.server.front
        try:
            timeout = params.get("timeout", [None])[0]
            timeout_s = float(timeout) if timeout is not None else None
            user = int(params.get("user", ["0"])[0])
        except ValueError:
            self._error(400, "non-numeric 'timeout' or 'user' parameter")
            return
        try:
            is_ask = isinstance(front.endpoint.parse(query), AskNode)
            table = front.queue.query(query, user=user,
                                      timeout_s=timeout_s)
        except ParseError as err:
            self._error(400, f"parse error: {err}")
            return
        except AdmissionFullError as err:
            self._error(503, str(err),
                        {"Retry-After": f"{err.retry_after_s:.3f}"})
            return
        except DeadlineExceeded as err:
            self._error(504, str(err))
            return
        except AdmissionClosed:
            self._error(503, "server shutting down")
            return
        except Exception as err:           # engine-level failure
            self._error(500, f"{type(err).__name__}: {err}")
            return
        self._send(200, ask_to_json(table) if is_ask
                   else table_to_json(table))

    def _serve_update(self, text: str, params: dict) -> None:
        """``application/sparql-update`` / form ``update=``: the write goes
        through the SAME admission queue as queries — the ticket resolves
        to the ingest ack only after every query sharing its micro-batch
        window has read the pre-write store."""
        front: SparqlHttpServer = self.server.front
        try:
            timeout = params.get("timeout", [None])[0]
            timeout_s = float(timeout) if timeout is not None else None
            user = int(params.get("user", ["0"])[0])
        except ValueError:
            self._error(400, "non-numeric 'timeout' or 'user' parameter")
            return
        try:
            ack = front.queue.query(text, user=user, timeout_s=timeout_s)
        except ParseError as err:
            self._error(400, f"parse error: {err}")
            return
        except AdmissionFullError as err:
            self._error(503, str(err),
                        {"Retry-After": f"{err.retry_after_s:.3f}"})
            return
        except DeadlineExceeded as err:
            self._error(504, str(err))
            return
        except AdmissionClosed:
            self._error(503, "server shutting down")
            return
        except Exception as err:           # ingest-level failure
            self._error(500, f"{type(err).__name__}: {err}")
            return
        self._send(200, dict(ack), ctype="application/json")


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    # socketserver's default listen backlog is 5: a burst of concurrent
    # clients overflows it and the dropped SYNs come back as 1s+ TCP
    # retransmit stalls — exactly the traffic shape this front end exists
    # to coalesce
    request_queue_size = 128
    front: "SparqlHttpServer"


class SparqlHttpServer:
    """The serving front end: HTTP listener + admission queue + endpoint.

    ``port=0`` (default) binds an ephemeral port — read :attr:`url` after
    :meth:`start`. Admission knobs (``window_s``, ``max_batch``,
    ``max_queue``, ``default_timeout_s``, ``mode``) pass straight through
    to :class:`~repro.runtime.admission.AdmissionQueue`; an existing queue
    can be supplied via ``queue=`` instead.
    """

    def __init__(self, endpoint, *, host: str = "127.0.0.1", port: int = 0,
                 queue: AdmissionQueue | None = None, **admission_kw) -> None:
        self.endpoint = endpoint
        if queue is not None and admission_kw:
            raise ValueError("pass admission knobs OR a prebuilt queue, "
                             "not both")
        self.queue = queue or AdmissionQueue(endpoint, **admission_kw)
        self._owns_queue = queue is None
        self._httpd = _Server((host, port), _Handler)
        self._httpd.front = self
        self._thread: threading.Thread | None = None

    # -- lifecycle -----------------------------------------------------------
    @property
    def address(self) -> tuple[str, int]:
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "SparqlHttpServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="sparql-http", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(5.0)
            self._thread = None
        self._httpd.server_close()
        if self._owns_queue:
            self.queue.close(drain=drain)

    def __enter__(self) -> "SparqlHttpServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- introspection -------------------------------------------------------
    def stats_dict(self) -> dict:
        q = self.queue
        es = self.endpoint.stats
        last = q.stats.recent[-1] if q.stats.recent else None
        return {
            "admission": q.stats.as_dict(),
            "queue_depth": q.depth,
            "window_s": q.window_s, "max_batch": q.max_batch,
            "mode": q.mode,
            "endpoint_memo": {"hits": self.endpoint.memo_hits,
                              "misses": self.endpoint.memo_misses},
            "engine": {"cache_hits": es.cache_hits,
                       "cache_misses": es.cache_misses,
                       "scans_executed": es.scans_executed,
                       "scans_deduped": es.scans_deduped},
            "last_batch": None if last is None else {
                "seq": last.seq, "size": last.size,
                "unique_texts": last.unique_texts,
                "expired": last.expired,
                "queue_depth": last.queue_depth,
                "window_fill": round(last.window_fill, 4),
                "wait_seconds": round(last.wait_seconds, 6),
                "exec_seconds": round(last.exec_seconds, 6),
                "memo_hits": last.memo_hits,
                "engine_cache_hits": last.engine_cache_hits,
                "scans_deduped": last.scans_deduped,
            },
        }
