"""Micro-batch admission queue — the concurrency front end of the endpoint.

The engine's whole advantage is batching: `SparqlEndpoint.query_many`
dedups repeated texts, prescans every BGP leaf of the batch together, and
lets alpha-equivalent sub-BGPs share result-cache entries. A network front
end that forwards each arriving request one at a time throws all of that
away. :class:`AdmissionQueue` restores it for concurrent traffic:

- ``submit(text)`` parses eagerly (syntax errors are rejected before they
  occupy a queue slot), enqueues a :class:`Ticket`, and wakes the
  dispatcher. The caller blocks on ``ticket.result()``.
- The dispatcher opens a **micro-batch window** at the first arrival: it
  sleeps until ``first_arrival + window_s`` (or until ``max_batch``
  tickets queued), then drains up to ``max_batch`` tickets, drops the ones
  whose deadline already passed (they fail with :class:`DeadlineExceeded`
  — a query that can't make its deadline must not occupy engine time),
  and executes the survivors as ONE engine batch.
- The queue is bounded: when ``max_queue`` tickets are waiting, ``submit``
  raises :class:`AdmissionFullError` carrying a suggested retry delay —
  the HTTP layer maps it to ``503 + Retry-After``. Backpressure beats an
  unbounded queue whose tail latency grows without limit.

``window_s=0.0, max_batch=1`` degenerates to sequential per-request
dispatch — the baseline mode of ``benchmarks/bench_serving.py``.

Execution modes (``mode=``):

- ``"endpoint"`` (default): ``endpoint.query_many`` — one engine batch.
- ``"round"``: ``endpoint.run_round(..., collect_results=True)`` — the
  batch is B&B-scheduled across the attached system's edge servers.
- ``"pool"``: ``endpoint.admit_many`` through the attached
  :class:`~repro.runtime.serving.OffloadServingPool`.

Per-batch provenance lands in :class:`BatchStats` (queue depth at close,
window fill, coalesced size, endpoint-memo and engine-cache hit deltas);
:class:`AdmissionStats` aggregates across the queue's lifetime — both feed
``bench_serving`` and the HTTP ``/stats`` route.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


class AdmissionError(Exception):
    """Base class for admission-layer failures."""


class AdmissionFullError(AdmissionError):
    """Queue at capacity — back off and retry (HTTP 503)."""

    def __init__(self, retry_after_s: float) -> None:
        super().__init__(f"admission queue full; retry after "
                         f"{retry_after_s:.3f}s")
        self.retry_after_s = retry_after_s


class DeadlineExceeded(AdmissionError):
    """Ticket deadline passed before its batch dispatched (HTTP 504)."""


class AdmissionClosed(AdmissionError):
    """Queue closed while the ticket was pending."""


class Ticket:
    """One admitted request (query or update): a thread-safe future the
    submitter blocks on. Query tickets resolve to a solution table; update
    tickets resolve to the endpoint's ack dict."""

    __slots__ = ("text", "user", "enqueued_at", "deadline",
                 "_event", "_value", "_error", "batch_seq", "is_update")

    def __init__(self, text: str, user: int,
                 enqueued_at: float, deadline: float | None,
                 is_update: bool = False) -> None:
        self.text = text
        self.user = user
        self.enqueued_at = enqueued_at
        self.deadline = deadline            # monotonic seconds, or None
        self.is_update = is_update
        self._event = threading.Event()
        self._value = None
        self._error: BaseException | None = None
        self.batch_seq: int | None = None   # which batch served it

    def _resolve(self, value) -> None:
        self._value = value
        self._event.set()

    def _reject(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """Block until served; raises the stored error on failure."""
        if not self._event.wait(timeout):
            raise TimeoutError("ticket not served within timeout")
        if self._error is not None:
            raise self._error
        return self._value


@dataclass
class BatchStats:
    """Provenance of one dispatched micro-batch."""

    seq: int                      # batch sequence number
    size: int                     # tickets executed
    unique_texts: int             # distinct query texts in the batch
    expired: int                  # tickets dropped at dispatch (deadline)
    queue_depth: int              # tickets still waiting after the drain
    window_fill: float            # size / max_batch
    wait_seconds: float           # mean enqueue -> dispatch wait
    exec_seconds: float           # engine batch wall clock
    memo_hits: int                # endpoint full-result memo hits (delta)
    engine_cache_hits: int        # engine result-cache hits (delta)
    scans_deduped: int            # engine scan dedups (delta)
    write_commits: int = 0        # store commits this window's writes took
    # scheduler provenance (mode="round"/"pool" only): per-assignment
    # counts (-1 cloud, -2 partial, k per edge/replica) and the modeled
    # scheduling objective of the window's read batch
    assignment_counts: dict | None = None
    objective: float | None = None


@dataclass
class AdmissionStats:
    """Lifetime aggregates across all batches."""

    submitted: int = 0
    completed: int = 0
    rejected: int = 0             # queue-full refusals
    expired: int = 0              # deadline drops
    failed: int = 0               # engine errors
    batches: int = 0
    max_coalesced: int = 0        # largest batch dispatched
    updates_served: int = 0       # update tickets acked
    write_commits: int = 0        # store commits those updates took
    # lifetime scheduler-decision totals (round/pool modes): assignment
    # sentinel (-1 cloud, -2 partial, k per edge) -> queries routed there
    assignment_counts: dict = field(default_factory=dict)
    recent: list = field(default_factory=list)   # last BatchStats

    @property
    def mean_batch_size(self) -> float:
        served = self.completed + self.failed
        return served / self.batches if self.batches else 0.0

    @property
    def writes_coalesced(self) -> int:
        """Commits amortized away by window-level write coalescing."""
        return self.updates_served - self.write_commits

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted, "completed": self.completed,
            "rejected": self.rejected, "expired": self.expired,
            "failed": self.failed, "batches": self.batches,
            "max_coalesced": self.max_coalesced,
            "mean_batch_size": round(self.mean_batch_size, 3),
            "updates_served": self.updates_served,
            "write_commits": self.write_commits,
            "writes_coalesced": self.writes_coalesced,
            "assignment_counts": {str(k): v for k, v in
                                  sorted(self.assignment_counts.items())},
        }


_RECENT_BATCHES = 64              # BatchStats ring kept for /stats


class AdmissionQueue:
    """Bounded micro-batch admission in front of a `SparqlEndpoint`.

    Parameters
    ----------
    endpoint : SparqlEndpoint
    window_s : float
        Micro-batch window: the dispatcher waits this long after the FIRST
        arrival before draining, so concurrently arriving queries coalesce.
        ``0.0`` dispatches immediately (with ``max_batch=1``: sequential).
    max_batch : int
        Hard cap per dispatched batch; a full window closes early.
    max_queue : int
        Bound on waiting tickets; beyond it ``submit`` raises
        :class:`AdmissionFullError` (HTTP 503 + Retry-After).
    default_timeout_s : float | None
        Per-query deadline applied when the submitter gives none; ``None``
        disables deadlines by default.
    mode : str
        ``"endpoint"`` | ``"round"`` | ``"pool"`` (see module docstring).
    mode_kw : dict | None
        Extra keyword arguments forwarded to the mode's dispatch call
        (``run_round`` / ``admit_many``) — e.g. ``{"policy": "greedy"}``
        to cap scheduling cost on large coalesced batches (B&B placement
        is exponential in batch size). Ignored by ``mode="endpoint"``.
    retry_after_s : float
        Suggested client back-off carried by :class:`AdmissionFullError`.
    coalesce_writes : bool
        Merge each window's ground updates (``INSERT DATA`` / ``DELETE
        DATA``) into ONE store commit via ``endpoint.update_many`` —
        arrival-order semantics and per-ticket failure isolation are
        preserved, but remap/edge-propagation cost is paid once per window
        instead of once per write. ``DELETE WHERE`` still commits
        individually at its arrival position.
    """

    def __init__(self, endpoint, *, window_s: float = 0.002,
                 max_batch: int = 64, max_queue: int = 1024,
                 default_timeout_s: float | None = None,
                 mode: str = "endpoint",
                 mode_kw: dict | None = None,
                 retry_after_s: float = 0.05,
                 coalesce_writes: bool = False) -> None:
        if mode not in ("endpoint", "round", "pool"):
            raise ValueError(f"unknown admission mode {mode!r}")
        if mode == "round" and endpoint.system is None:
            raise ValueError("mode='round' needs an endpoint with a "
                             "system attached")
        if mode == "pool" and endpoint.pool is None:
            raise ValueError("mode='pool' needs an endpoint with a "
                             "pool attached")
        self.endpoint = endpoint
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.default_timeout_s = default_timeout_s
        self.mode = mode
        self.mode_kw = dict(mode_kw or {})
        self.retry_after_s = float(retry_after_s)
        self.coalesce_writes = bool(coalesce_writes)
        self.stats = AdmissionStats()
        self._queue: list[Ticket] = []
        self._cond = threading.Condition()
        self._closed = False
        self._seq = 0
        self._batch_log: list | None = None
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="admission-dispatcher",
            daemon=True)
        self._dispatcher.start()

    # -- client side ---------------------------------------------------------
    def submit(self, text: str, *, user: int = 0,
               timeout_s: float | None = None) -> Ticket:
        """Admit one query; returns a :class:`Ticket` to block on.

        Parses eagerly: a syntactically invalid query raises
        :class:`~repro.sparql.query.ParseError` HERE, before the query
        occupies a queue slot (and the compiled plan is memoized, so the
        dispatcher's later parse is free).

        SPARQL UPDATE texts (``INSERT DATA`` / ``DELETE DATA`` / ``DELETE
        WHERE``) are admitted through the same queue: their ticket resolves
        to the write ack, and the write serializes against the micro-batch
        window it shares — every query in the window reads the pre-window
        store, the write commits after (see :meth:`_execute_batch`).
        """
        from ..sparql.query import is_update_text, parse_update
        is_upd = is_update_text(text)
        if is_upd:
            # eager syntax check only — compilation may mint dictionary
            # terms, which must happen at COMMIT time under the system's
            # placement lock, not at admission
            parse_update(text, self.endpoint.dictionary)
        else:
            self.endpoint.parse(text)       # raises ParseError on bad text
        now = time.monotonic()
        timeout = timeout_s if timeout_s is not None else \
            self.default_timeout_s
        deadline = (now + timeout) if timeout is not None else None
        ticket = Ticket(text, user, now, deadline, is_update=is_upd)
        with self._cond:
            if self._closed:
                raise AdmissionClosed("admission queue is closed")
            if len(self._queue) >= self.max_queue:
                self.stats.rejected += 1
                raise AdmissionFullError(self.retry_after_s)
            self._queue.append(ticket)
            self.stats.submitted += 1
            self._cond.notify_all()
        return ticket

    def query(self, text: str, *, user: int = 0,
              timeout_s: float | None = None):
        """Submit + block: the synchronous convenience wrapper."""
        return self.submit(text, user=user, timeout_s=timeout_s).result()

    @property
    def depth(self) -> int:
        with self._cond:
            return len(self._queue)

    def start_batch_log(self) -> list:
        """Capture every subsequent :class:`BatchStats` into the returned
        list until :meth:`stop_batch_log`. Unlike ``stats.recent`` (a
        ring trimmed to the last 64 batches) the log grows without bound,
        so a measurement window spanning many dispatch windows — e.g.
        ``workload.replay`` — sees its full batch trajectory. Starting a
        new log replaces any previous one."""
        log: list = []
        self._batch_log = log
        return log

    def stop_batch_log(self) -> None:
        """Stop capturing batches; the list from :meth:`start_batch_log`
        keeps whatever was captured."""
        self._batch_log = None

    def close(self, drain: bool = True, timeout: float = 10.0) -> None:
        """Stop admitting. ``drain=True`` serves already-queued tickets
        first; ``drain=False`` rejects them with :class:`AdmissionClosed`.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            if not drain:
                for t in self._queue:
                    t._reject(AdmissionClosed("queue closed"))
                self._queue.clear()
            self._cond.notify_all()
        self._dispatcher.join(timeout)

    def __enter__(self) -> "AdmissionQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatcher side -----------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            batch = self._collect_batch()
            if batch is None:
                return
            if batch:
                self._execute_batch(batch)

    def _collect_batch(self) -> list[Ticket] | None:
        """Block for the first arrival, hold the window open, drain.

        Returns ``None`` when the queue is closed and fully drained (the
        dispatcher exits), ``[]`` when every drained ticket had expired.
        """
        with self._cond:
            while not self._queue:
                if self._closed:
                    return None
                self._cond.wait()
            # window opens at the FIRST waiting arrival; closing early on
            # a full window keeps worst-case wait at window_s even under
            # burst arrival
            window_end = self._queue[0].enqueued_at + self.window_s
            while (len(self._queue) < self.max_batch
                   and not self._closed):
                remaining = window_end - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
                if not self._queue:       # spurious wake after a drain
                    return []
            batch = self._queue[:self.max_batch]
            del self._queue[:len(batch)]
            self._depth_after_drain = len(self._queue)
        # deadline enforcement AT dispatch: expired tickets never reach
        # the engine (and never pollute a batch's wall clock)
        now = time.monotonic()
        live, expired = [], []
        for t in batch:
            if t.deadline is not None and now > t.deadline:
                expired.append(t)
            else:
                live.append(t)
        for t in expired:
            t._reject(DeadlineExceeded(
                f"deadline passed {now - t.deadline:.4f}s before dispatch"))
        self.stats.expired += len(expired)
        self._expired_last = len(expired)
        return live

    def _execute_batch(self, batch: list[Ticket]) -> None:
        """Serve one micro-batch: queries first (ONE engine batch against
        the pre-window store), then updates in arrival order.

        This is the write-serialization contract: an update admitted into
        a window commits only AFTER every query of that window has read —
        so reads in the window observe one consistent store version, and
        the write's version bump (store, and dictionary for new terms)
        invalidates exactly the memos it should for the NEXT window. A
        failing update rejects only its own ticket (with
        ``coalesce_writes``, a failing *commit* rejects its whole
        coalesced group — see ``SparqlEndpoint.update_many``).

        In ``mode="round"`` / ``mode="pool"`` the scheduler's per-window
        decisions (full-edge / cloud / partial counts, modeled objective)
        are captured into :class:`BatchStats` and aggregated into
        :class:`AdmissionStats.assignment_counts`.
        """
        ep = self.endpoint
        reads = [t for t in batch if not t.is_update]
        updates = [t for t in batch if t.is_update]
        texts = [t.text for t in batch]
        seq = self._seq
        self._seq += 1
        memo0 = ep.memo_hits
        hits0 = ep.stats.cache_hits
        dedup0 = ep.stats.scans_deduped
        commits0 = ep.write_commits
        assignment_counts: dict | None = None
        objective: float | None = None
        t0 = time.monotonic()
        if reads:
            rtexts = [t.text for t in reads]
            try:
                if self.mode == "round":
                    report = ep.run_round(
                        [(t.user, t.text) for t in reads],
                        collect_results=True, **self.mode_kw)
                    tables = report.results
                    assignment_counts = dict(report.assignment_counts)
                    objective = float(report.objective)
                elif self.mode == "pool":
                    served = ep.admit_many(rtexts, **self.mode_kw)
                    tables = served.responses
                    ks, ns = _np_unique(served.assignments)
                    assignment_counts = dict(zip(ks, ns))
                    objective = float(served.objective)
                else:
                    tables = ep.query_many(rtexts)
            except Exception as err:           # engine-level failure:
                for t in reads:                # fail the window's reads
                    t._reject(err)
                self.stats.failed += len(reads)
                reads = []
            else:
                for ticket, table in zip(reads, tables):
                    ticket.batch_seq = seq
                    ticket._resolve(table)
        served_updates = 0
        if updates and self.coalesce_writes:
            try:
                outs = ep.update_many([t.text for t in updates])
            except Exception as err:
                # an exception escaping the coalesced commit must not
                # strand the window's tickets unresolved (clients poll
                # ticket.done() forever) — reject them all, mirroring
                # the read path
                for t in updates:
                    t._reject(err)
                self.stats.failed += len(updates)
            else:
                for t, out in zip(updates, outs):
                    if isinstance(out, BaseException):
                        t._reject(out)
                        self.stats.failed += 1
                    else:
                        t.batch_seq = seq
                        t._resolve(out)
                        served_updates += 1
        else:
            for t in updates:
                try:
                    ack = ep.update(t.text)
                except Exception as err:
                    t._reject(err)
                    self.stats.failed += 1
                else:
                    t.batch_seq = seq
                    t._resolve(ack)
                    served_updates += 1
        dt = time.monotonic() - t0
        n_ok = len(reads) + served_updates
        self.stats.completed += n_ok
        self.stats.batches += 1
        self.stats.max_coalesced = max(self.stats.max_coalesced,
                                       len(batch))
        self.stats.updates_served += served_updates
        self.stats.write_commits += ep.write_commits - commits0
        if assignment_counts:
            for k, n in assignment_counts.items():
                self.stats.assignment_counts[int(k)] = \
                    self.stats.assignment_counts.get(int(k), 0) + int(n)
        bs = BatchStats(
            seq=seq, size=len(batch), unique_texts=len(set(texts)),
            expired=getattr(self, "_expired_last", 0),
            queue_depth=getattr(self, "_depth_after_drain", 0),
            window_fill=len(batch) / self.max_batch,
            wait_seconds=(t0 - sum(t.enqueued_at for t in batch)
                          / len(batch)),
            exec_seconds=dt,
            memo_hits=ep.memo_hits - memo0,
            engine_cache_hits=ep.stats.cache_hits - hits0,
            scans_deduped=ep.stats.scans_deduped - dedup0,
            write_commits=ep.write_commits - commits0,
            assignment_counts=assignment_counts,
            objective=objective)
        self.stats.recent.append(bs)
        del self.stats.recent[:-_RECENT_BATCHES]
        log = self._batch_log
        if log is not None:
            log.append(bs)


def _np_unique(assignments):
    import numpy as np
    ks, ns = np.unique(np.asarray(assignments, dtype=np.int64),
                       return_counts=True)
    return [int(k) for k in ks], [int(n) for n in ns]
