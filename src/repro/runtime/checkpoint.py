"""Atomic, reshardable checkpointing.

Layout per checkpoint:  <dir>/step_<n>/
    manifest.json   — step, flattened key list, shapes/dtypes, version
    arrays.npz      — one entry per pytree leaf (path-encoded keys)

Writes go to ``<dir>/.tmp_step_<n>`` then ``os.replace`` (atomic on POSIX) —
a crash mid-write never corrupts the latest checkpoint. Restore can target a
*different* mesh than the one that saved (elastic scaling): leaves are loaded
on host and ``jax.device_put`` with the new NamedShardings.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    import ml_dtypes
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:   # npz has no bf16: store bits
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str, step: int, state: dict,
                    keep_last: int = 3) -> str:
    """state: arbitrary pytree (params, opt_state, rng, ...)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "version": 1,
        "step": step,
        "keys": sorted(arrays),
        "treedef": jax.tree_util.tree_structure(state).__repr__(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore_checkpoint(ckpt_dir: str, like: dict, step: int | None = None,
                       shardings=None) -> tuple[int, dict]:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding matching ``like`` — when
    given, each leaf is device_put with its sharding (works across mesh
    shapes: elastic restart path).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves, treedef = flat_like
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), sh in zip(leaves, flat_sh):
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        import ml_dtypes
        if (arr.dtype == np.uint16
                and np.dtype(leaf.dtype) == ml_dtypes.bfloat16):
            arr = arr.view(ml_dtypes.bfloat16)    # stored as raw bf16 bits
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    state = jax.tree_util.tree_structure(like).unflatten(out)
    return step, state
