"""Fault tolerance: stragglers, retries, elastic re-meshing.

At thousands of nodes, three failure classes dominate; each has a handler:

1. **Transient step failure** (preempted host, flaky interconnect):
   ``with_retries`` re-executes the step function; training state is
   functional (params, opt_state), so a retry is side-effect-free.
2. **Stragglers**: ``StragglerMonitor`` keeps an EWMA of step time; a step
   exceeding ``factor``x the EWMA (or an absolute deadline) is flagged.
   The driver's response is configurable — log, re-dispatch the step, or
   (on real fleets) trigger hot-spare swap. On this CPU container the
   monitor's detection logic is what we can exercise (tests inject delays).
3. **Node loss -> elastic re-mesh**: ``plan_mesh`` picks the largest
   (data, model) grid for the surviving device count with the model axis
   preserved; the driver then restores the latest checkpoint with the new
   mesh's shardings (see checkpoint.restore_checkpoint) and resumes.
   Resharding is free because checkpoints are mesh-agnostic host arrays.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np


def with_retries(fn, n_retries: int = 2, backoff_s: float = 0.0,
                 on_error=None):
    """Run fn(); on exception retry up to n_retries times."""
    def wrapped(*a, **kw):
        err = None
        for attempt in range(n_retries + 1):
            try:
                return fn(*a, **kw)
            except Exception as e:  # noqa: BLE001 — deliberate catch-all
                err = e
                if on_error is not None:
                    on_error(attempt, e)
                if backoff_s:
                    time.sleep(backoff_s * (2 ** attempt))
        raise err
    return wrapped


@dataclass
class StragglerMonitor:
    """EWMA-based straggler detection over step durations."""

    factor: float = 3.0
    deadline_s: float | None = None
    alpha: float = 0.2
    ewma: float | None = None
    flagged_steps: list[int] = field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if self.deadline_s is not None and duration_s > self.deadline_s:
            is_straggler = True
        if self.ewma is not None and duration_s > self.factor * self.ewma:
            is_straggler = True
        # stragglers don't poison the EWMA
        if not is_straggler:
            self.ewma = (duration_s if self.ewma is None
                         else self.alpha * duration_s
                         + (1 - self.alpha) * self.ewma)
        if is_straggler:
            self.flagged_steps.append(step)
        return is_straggler


def plan_mesh(n_devices: int, model_axis: int,
              pod_axis: int = 1) -> tuple[int, ...]:
    """Largest (pod, data, model) grid for the surviving device count.

    Keeps the model (TP) axis intact — params stay shardable — and shrinks
    data parallelism. Drops stray devices that don't fill a full data row
    (they become hot spares).
    """
    per_pod = n_devices // pod_axis
    data = per_pod // model_axis
    if data < 1:
        raise ValueError(
            f"{n_devices} devices cannot sustain model axis {model_axis}")
    if pod_axis > 1:
        return (pod_axis, data, model_axis)
    return (data, model_axis)


def simulate_failure(devices: list, n_lost: int) -> list:
    """Drop the last n_lost devices (deterministic for tests)."""
    if n_lost >= len(devices):
        raise ValueError("cannot lose every device")
    return devices[: len(devices) - n_lost]
