"""Branch-and-bound for the query-assignment decision (paper Alg. 1).

Search tree: level i decides one EU's placement among {cloud} ∪ {feasible
edges}. Exactness only requires that every node's lower bound is certified;
two bounding modes are provided:

- ``bound="rqad"`` (paper-faithful): the convex R-QAD relaxation solved in
  JAX with a Frank-Wolfe duality-gap certificate (see ``qad.py``); children
  of one expansion are bounded in a single vmapped call.
- ``bound="marginal"`` (beyond-paper, default): a congestion-free completion
  bound. With prefix loads S_k = Σ_{fixed n∈N_k} √c_n, a free user's true
  marginal cost on edge k is ≥ (2·S_k·√c_n + c_n)/F_k + w_n/r^{n,k} because
  additional free users only increase S_k; taking each free user's cheapest
  option therefore lower-bounds every completion:
      LB = cost(prefix) + Σ_{free n} min(w_n/r^{n,c}, min_k marginal_{n,k}).
  It is O(N·K) NumPy per node — no accelerator round-trip — and *tighter*
  than the LP-style relaxation deep in the tree.

Upper bounds come from greedy completion of the prefix (and, in rqad mode,
additionally from Eq. 17 rounding), evaluated exactly through the CRA closed
form. Both modes return certified-optimal solutions unless ``max_nodes`` is
hit (then ``optimal=False`` and the incumbent is returned — anytime mode).

Further beyond-paper optimizations (measured in bench_sched_overhead.py):
- users are branched in descending *impact* order (max feasible saving);
- single-choice users are collapsed instead of branched;
- greedy warm start for the incumbent (paper uses cloud-only; configurable).
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass

import numpy as np

from .cost import QueryTasks, SystemParams, assignment_cost
from .cra import allocate_closed_form


@dataclass
class BnBResult:
    D: np.ndarray                 # [N, K] binary assignment
    f: np.ndarray                 # [N, K] allocated cycles/s
    objective: float              # total cost (Eq. 5, with optimal CRA)
    nodes_explored: int
    nodes_pruned: int
    solve_seconds: float
    optimal: bool                 # False if the node cap was hit


class _Instance:
    """Preprocessed arrays shared across the search."""

    def __init__(self, tasks: QueryTasks, params: SystemParams,
                 order: str) -> None:
        self.N, self.K = tasks.N, params.K
        self.e = (tasks.e * params.assoc).astype(np.float64)
        self.c = tasks.c.astype(np.float64)
        self.w = tasks.w.astype(np.float64)
        self.sq = np.sqrt(np.maximum(self.c, 0.0))
        self.F = params.F.astype(np.float64)
        with np.errstate(divide="ignore"):
            self.tx_edge = np.where(
                self.e > 0, self.w[:, None] / np.maximum(params.r_edge, 1e-30),
                np.inf)
        self.tx_cloud = self.w / params.r_cloud
        # alone-on-the-edge saving per user: branching impact
        alone = self.c[:, None] / self.F[None, :] + self.tx_edge
        saving = self.tx_cloud[:, None] - alone
        saving = np.where(self.e > 0, saving, -np.inf)
        impact = saving.max(axis=1)
        if order == "impact":
            self.perm = np.argsort(-impact, kind="stable")
        else:
            self.perm = np.arange(self.N)
        self.inv = np.argsort(self.perm)
        # permuted views
        for name in ("e", "c", "w", "sq", "tx_edge", "tx_cloud"):
            setattr(self, name, getattr(self, name)[self.perm])
        self.choices = [
            [-1] + list(np.flatnonzero(self.e[n] > 0))
            for n in range(self.N)]

    # ---- exact cost of a complete decision vector -------------------------
    def exact_cost(self, decisions: np.ndarray) -> float:
        S = np.zeros(self.K)
        tx = 0.0
        for n, ch in enumerate(decisions):
            if ch >= 0:
                S[ch] += self.sq[n]
                tx += self.tx_edge[n, ch]
            else:
                tx += self.tx_cloud[n]
        return float((S ** 2 / self.F).sum() + tx)

    # ---- prefix state -------------------------------------------------------
    def prefix_state(self, decisions: list[int]) -> tuple[np.ndarray, float]:
        S = np.zeros(self.K)
        tx = 0.0
        for n, ch in enumerate(decisions):
            if ch >= 0:
                S[ch] += self.sq[n]
                tx += self.tx_edge[n, ch]
            else:
                tx += self.tx_cloud[n]
        return S, tx

    # ---- certified congestion-free lower bound -----------------------------
    def marginal_lb(self, S: np.ndarray, tx: float, depth: int) -> float:
        base = float((S ** 2 / self.F).sum() + tx)
        if depth >= self.N:
            return base
        sq = self.sq[depth:, None]
        c = self.c[depth:, None]
        marg = (2.0 * S[None, :] * sq + c) / self.F[None, :] \
            + self.tx_edge[depth:]
        best = np.minimum(marg.min(axis=1), self.tx_cloud[depth:])
        return base + float(best.sum())

    # ---- greedy completion (upper bound + incumbent) ------------------------
    def greedy_complete(self, decisions: list[int]) -> np.ndarray:
        S, _ = self.prefix_state(decisions)
        out = np.asarray(decisions + [-1] * (self.N - len(decisions)),
                         dtype=np.int64)
        for n in range(len(decisions), self.N):
            feas = self.choices[n][1:]
            if not feas:
                continue
            feas = np.asarray(feas)
            delta = ((S[feas] + self.sq[n]) ** 2 - S[feas] ** 2) / self.F[feas]
            delta += self.tx_edge[n, feas] - self.tx_cloud[n]
            j = int(np.argmin(delta))
            if delta[j] < 0.0:
                out[n] = feas[j]
                S[feas[j]] += self.sq[n]
        return out

    def to_D(self, decisions: np.ndarray) -> np.ndarray:
        D = np.zeros((self.N, self.K))
        for n, ch in enumerate(decisions):
            if ch >= 0:
                D[n, ch] = 1.0
        return D[self.inv]          # undo the impact permutation


def branch_and_bound(tasks: QueryTasks, params: SystemParams,
                     strategy: str = "depth_first",
                     bound: str = "marginal",
                     order: str = "impact",
                     warm_start: str = "greedy",
                     solver_iters: int = 200,
                     rqad_max_depth: int | None = None,
                     max_nodes: int = 200_000,
                     max_seconds: float | None = None,
                     prune_tol: float = 1e-9) -> BnBResult:
    """Alg. 1 (modified): exact minimizer of Eq. (15).

    ``bound="rqad"`` reproduces the paper's relaxation bounding;
    ``bound="marginal"`` is the fast default (identical optima, certified).
    ``max_nodes`` / ``max_seconds`` turn the solver into an anytime method:
    the greedy-completion incumbent is returned with ``optimal=False`` when
    a budget is hit (at paper scale K=4, N=20 optimality is proven in ms).
    """
    t0 = time.perf_counter()
    inst = _Instance(tasks, params, order)
    N, K = inst.N, inst.K

    use_rqad = bound == "rqad"
    if use_rqad:
        from .qad import build_qad_arrays, solve_rqad_batch
        A, b, const = build_qad_arrays(
            inst.c, inst.w, inst.e,
            np.where(inst.e > 0, inst.w[:, None] / np.maximum(inst.tx_edge,
                                                              1e-300), 1e-30),
            inst.w / inst.tx_cloud)
        # NOTE: r_edge reconstructed from tx_edge to honor the permutation.

    # incumbent
    if warm_start == "greedy":
        best_dec = inst.greedy_complete([])
    else:
        best_dec = np.full(N, -1, dtype=np.int64)
    best_cost = inst.exact_cost(best_dec)

    counter = itertools.count()
    heap: list[tuple] = []

    def priority(depth: int, lb: float) -> tuple:
        if strategy == "depth_first":
            return (-depth, lb)
        return (lb, -depth)

    S0, tx0 = inst.prefix_state([])
    root_lb = inst.marginal_lb(S0, tx0, 0)
    heapq.heappush(heap, (priority(0, root_lb), next(counter), [], root_lb,
                          S0, tx0))
    explored = pruned = 0
    optimal = True

    while heap:
        if explored >= max_nodes or (max_seconds is not None
                                     and time.perf_counter() - t0
                                     > max_seconds):
            optimal = False
            break
        _, _, decisions, node_lb, S_node, tx_node = heapq.heappop(heap)
        if node_lb > best_cost + prune_tol:
            pruned += 1
            continue
        depth = len(decisions)
        if depth == N:
            cost = inst.exact_cost(np.asarray(decisions))
            if cost < best_cost:
                best_cost, best_dec = cost, np.asarray(decisions)
            continue
        explored += 1
        # expand children, carrying (S, tx) incrementally
        prefixes = [decisions + [ch] for ch in inst.choices[depth]]
        while len(prefixes) == 1 and len(prefixes[0]) < N:
            d2 = len(prefixes[0])
            prefixes = [prefixes[0] + [ch] for ch in inst.choices[d2]]
        child_depth = len(prefixes[0])

        lbs = np.empty(len(prefixes))
        states = []
        for ci, dec in enumerate(prefixes):
            S, tx = S_node.copy(), tx_node
            for nd in range(depth, child_depth):
                ch = dec[nd]
                if ch >= 0:
                    S[ch] += inst.sq[nd]
                    tx += inst.tx_edge[nd, ch]
                else:
                    tx += inst.tx_cloud[nd]
            states.append((S, tx))
            lbs[ci] = inst.marginal_lb(S, tx, child_depth)

        if use_rqad and (rqad_max_depth is None
                         or child_depth <= rqad_max_depth):
            fixed_mask = np.zeros(N)
            fixed_mask[:child_depth] = 1.0
            fixed_Ds = np.stack([_decisions_to_D(dec, N, K)
                                 for dec in prefixes])
            D_rel, f_vals, rq_lbs = solve_rqad_batch(
                A, b, inst.F, inst.e, fixed_mask, fixed_Ds, solver_iters)
            rq_lbs = np.asarray(rq_lbs) + const
            lbs = np.maximum(lbs, rq_lbs)

        for ci, dec in enumerate(prefixes):
            if lbs[ci] > best_cost + prune_tol:
                pruned += 1
                continue
            # greedy completion: exact upper bound + candidate incumbent
            full = inst.greedy_complete(dec)
            ub = inst.exact_cost(full)
            if ub < best_cost:
                best_cost, best_dec = ub, full
            if child_depth == N:
                cost = inst.exact_cost(np.asarray(dec))
                if cost < best_cost:
                    best_cost, best_dec = cost, np.asarray(dec)
                continue
            S_c, tx_c = states[ci]
            heapq.heappush(heap, (priority(child_depth, float(lbs[ci])),
                                  next(counter), dec, float(lbs[ci]),
                                  S_c, tx_c))

    D = inst.to_D(best_dec)
    e_full = (tasks.e * params.assoc).astype(np.float64)
    f = allocate_closed_form(D * e_full, tasks.c, params.F)
    obj = assignment_cost(D, tasks, params)
    return BnBResult(D=D, f=f, objective=float(obj),
                     nodes_explored=explored, nodes_pruned=pruned,
                     solve_seconds=time.perf_counter() - t0, optimal=optimal)


def _decisions_to_D(decisions: list[int], N: int, K: int) -> np.ndarray:
    D = np.zeros((N, K))
    for n, ch in enumerate(decisions):
        if ch >= 0:
            D[n, ch] = 1.0
    return D


def brute_force(tasks: QueryTasks, params: SystemParams) -> BnBResult:
    """Exhaustive minimizer (tests / tiny instances only)."""
    t0 = time.perf_counter()
    N, K = tasks.N, params.K
    e = (tasks.e * params.assoc).astype(np.float64)
    choices = [[-1] + list(np.flatnonzero(e[n] > 0)) for n in range(N)]
    best_cost, best_D = np.inf, np.zeros((N, K))
    n_nodes = 0
    for combo in itertools.product(*choices):
        n_nodes += 1
        D = _decisions_to_D(list(combo), N, K)
        cost = assignment_cost(D, tasks, params)
        if cost < best_cost:
            best_cost, best_D = cost, D
    f = allocate_closed_form(best_D * e, tasks.c, params.F)
    return BnBResult(D=best_D, f=f, objective=float(best_cost),
                     nodes_explored=n_nodes, nodes_pruned=0,
                     solve_seconds=time.perf_counter() - t0, optimal=True)
