"""Branch-and-bound for the query-assignment decision (paper Alg. 1).

Search tree: level i decides one EU's placement among {cloud} ∪ {feasible
edges} ∪ {partial} (the partial-evaluation option, when the query carries
one — see :class:`repro.core.cost.PartialOption`). Exactness only requires
that every node's lower bound is certified; two bounding modes are
provided:

- ``bound="rqad"`` (paper-faithful): the convex R-QAD relaxation solved in
  JAX with a Frank-Wolfe duality-gap certificate (see ``qad.py``); children
  of one expansion are bounded in a single vmapped call. The relaxation
  does not model the partial option, so its bound is corrected by a
  certified slack (:func:`repro.core.qad.partial_lb_slack`): a row taking
  its partial option costs at least its congestion-free partial cost, so
  subtracting ``max(0, cloud_n - partial_free_n)`` per partial-capable row
  keeps the bound a true lower bound for every completion.
- ``bound="marginal"`` (beyond-paper, default): a congestion-free completion
  bound. With prefix loads S_k = Σ_{fixed n∈N_k} √c_n, a free user's true
  marginal cost on edge k is ≥ (2·S_k·√c_n + c_n)/F_k + w_n/r^{n,k} because
  additional free users only increase S_k; the same telescoping argument
  prices a free user's partial option at
  ≥ Σ_k (2·S_k·P_sq_{n,k} + P_c_{n,k})/F_k + fixed_n. Taking each free
  user's cheapest option therefore lower-bounds every completion. The
  partial option adds one more column — greedy and bounding stay O(N·K).

Upper bounds come from greedy completion of the prefix (and, in rqad mode,
additionally from Eq. 17 rounding), evaluated exactly through the CRA closed
form. Both modes return certified-optimal solutions unless ``max_nodes`` is
hit (then ``optimal=False`` and the incumbent is returned — anytime mode).

Further beyond-paper optimizations (measured in bench_sched_overhead.py):
- users are branched in descending *impact* order (max feasible saving);
- single-choice users are collapsed instead of branched;
- greedy warm start for the incumbent (paper uses cloud-only; configurable).

Decision encoding: -1 cloud, 0..K-1 edge, K partial. In the returned
``D`` matrix a partial row is all-zero (legacy consumers read it as cloud,
which is also the execution fallback direction); the ``partial`` boolean
mask on :class:`BnBResult` is authoritative.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass

import numpy as np

from .cost import (QueryTasks, SystemParams, assignment_cost,
                   cloud_unit_cost, decisions_cost, partial_fixed_cost)
from .cra import allocate_closed_form


@dataclass
class BnBResult:
    D: np.ndarray                 # [N, K] binary assignment
    f: np.ndarray                 # [N, K] allocated cycles/s
    objective: float              # total cost (Eq. 5 gen., optimal CRA)
    nodes_explored: int
    nodes_pruned: int
    solve_seconds: float
    optimal: bool                 # False if the node cap was hit
    partial: np.ndarray | None = None   # [N] bool: row takes its partial plan


class _Instance:
    """Preprocessed arrays shared across the search."""

    def __init__(self, tasks: QueryTasks, params: SystemParams,
                 order: str) -> None:
        self.N, self.K = tasks.N, params.K
        self.e = (tasks.e * params.assoc).astype(np.float64)
        self.c = tasks.c.astype(np.float64)
        self.w = tasks.w.astype(np.float64)
        self.sq = np.sqrt(np.maximum(self.c, 0.0))
        self.F = params.F.astype(np.float64)
        with np.errstate(divide="ignore"):
            self.tx_edge = np.where(
                self.e > 0, self.w[:, None] / np.maximum(params.r_edge, 1e-30),
                np.inf)
        # cloud path: delivery + (generalized) cloud compute
        self.cloud = cloud_unit_cost(tasks, params).astype(np.float64)
        # partial option arrays (zero / inf when a row has none)
        self.has_partial = np.zeros(self.N, dtype=bool)
        self.P_sq = np.zeros((self.N, self.K))
        self.P_c = np.zeros((self.N, self.K))
        self.part_fixed = np.full(self.N, np.inf)
        if tasks.partial is not None:
            for n, opt in enumerate(tasks.partial):
                if opt is None:
                    continue
                eids = np.asarray(opt.edges, dtype=np.int64)
                cyc = np.maximum(np.asarray(opt.cycles, dtype=np.float64), 0.0)
                self.has_partial[n] = True
                self.P_c[n, eids] = cyc
                self.P_sq[n, eids] = np.sqrt(cyc)
                self.part_fixed[n] = partial_fixed_cost(
                    opt, float(self.w[n]), params, n)
        # alone-on-the-edge saving per user: branching impact
        alone = self.c[:, None] / self.F[None, :] + self.tx_edge
        saving = self.cloud[:, None] - alone
        saving = np.where(self.e > 0, saving, -np.inf)
        impact = saving.max(axis=1)
        part_alone = (self.P_c / self.F[None, :]).sum(axis=1) + self.part_fixed
        impact = np.where(self.has_partial,
                          np.maximum(impact, self.cloud - part_alone), impact)
        if order == "impact":
            self.perm = np.argsort(-impact, kind="stable")
        else:
            self.perm = np.arange(self.N)
        self.inv = np.argsort(self.perm)
        # permuted views
        for name in ("e", "c", "w", "sq", "tx_edge", "cloud",
                     "has_partial", "P_sq", "P_c", "part_fixed"):
            setattr(self, name, getattr(self, name)[self.perm])
        self.choices = [
            [-1] + list(np.flatnonzero(self.e[n] > 0))
            + ([self.K] if self.has_partial[n] else [])
            for n in range(self.N)]

    # ---- exact cost of a complete decision vector -------------------------
    def exact_cost(self, decisions: np.ndarray) -> float:
        S = np.zeros(self.K)
        tx = 0.0
        for n, ch in enumerate(decisions):
            if ch == self.K:
                S += self.P_sq[n]
                tx += self.part_fixed[n]
            elif ch >= 0:
                S[ch] += self.sq[n]
                tx += self.tx_edge[n, ch]
            else:
                tx += self.cloud[n]
        return float((S ** 2 / self.F).sum() + tx)

    # ---- prefix state -------------------------------------------------------
    def prefix_state(self, decisions: list[int]) -> tuple[np.ndarray, float]:
        S = np.zeros(self.K)
        tx = 0.0
        for n, ch in enumerate(decisions):
            if ch == self.K:
                S += self.P_sq[n]
                tx += self.part_fixed[n]
            elif ch >= 0:
                S[ch] += self.sq[n]
                tx += self.tx_edge[n, ch]
            else:
                tx += self.cloud[n]
        return S, tx

    # ---- certified congestion-free lower bound -----------------------------
    def marginal_lb(self, S: np.ndarray, tx: float, depth: int) -> float:
        base = float((S ** 2 / self.F).sum() + tx)
        if depth >= self.N:
            return base
        sq = self.sq[depth:, None]
        c = self.c[depth:, None]
        marg = (2.0 * S[None, :] * sq + c) / self.F[None, :] \
            + self.tx_edge[depth:]
        best = np.minimum(marg.min(axis=1), self.cloud[depth:])
        # partial marginal: P_sq/P_c are zero and part_fixed inf for rows
        # without the option, so pm is inf there and never selected
        pm = ((2.0 * S[None, :] * self.P_sq[depth:] + self.P_c[depth:])
              / self.F[None, :]).sum(axis=1) + self.part_fixed[depth:]
        best = np.minimum(best, pm)
        return base + float(best.sum())

    # ---- greedy completion (upper bound + incumbent) ------------------------
    def greedy_complete(self, decisions: list[int]) -> np.ndarray:
        S, _ = self.prefix_state(decisions)
        out = np.asarray(decisions + [-1] * (self.N - len(decisions)),
                         dtype=np.int64)
        for n in range(len(decisions), self.N):
            best_ch, best_delta = -1, 0.0
            feas = [ch for ch in self.choices[n][1:] if ch != self.K]
            if feas:
                feas = np.asarray(feas)
                delta = ((S[feas] + self.sq[n]) ** 2 - S[feas] ** 2) \
                    / self.F[feas]
                delta += self.tx_edge[n, feas] - self.cloud[n]
                j = int(np.argmin(delta))
                if delta[j] < best_delta:
                    best_ch, best_delta = int(feas[j]), float(delta[j])
            if self.has_partial[n]:
                pd = float((((S + self.P_sq[n]) ** 2 - S ** 2)
                            / self.F).sum()
                           + self.part_fixed[n] - self.cloud[n])
                if pd < best_delta:
                    best_ch, best_delta = self.K, pd
            if best_ch != -1:
                out[n] = best_ch
                if best_ch == self.K:
                    S = S + self.P_sq[n]
                else:
                    S[best_ch] += self.sq[n]
        return out

    def to_D(self, decisions: np.ndarray) -> np.ndarray:
        D = np.zeros((self.N, self.K))
        for n, ch in enumerate(decisions):
            if 0 <= ch < self.K:
                D[n, ch] = 1.0
        return D[self.inv]          # undo the impact permutation

    def to_partial_mask(self, decisions: np.ndarray) -> np.ndarray:
        return (np.asarray(decisions) == self.K)[self.inv]


def branch_and_bound(tasks: QueryTasks, params: SystemParams,
                     strategy: str = "depth_first",
                     bound: str = "marginal",
                     order: str = "impact",
                     warm_start: str = "greedy",
                     solver_iters: int = 200,
                     rqad_max_depth: int | None = None,
                     max_nodes: int = 200_000,
                     max_seconds: float | None = None,
                     prune_tol: float = 1e-9) -> BnBResult:
    """Alg. 1 (modified): exact minimizer of Eq. (15), three-way plan space.

    ``bound="rqad"`` reproduces the paper's relaxation bounding (with the
    partial-slack correction when partial options exist);
    ``bound="marginal"`` is the fast default (identical optima, certified).
    ``max_nodes`` / ``max_seconds`` turn the solver into an anytime method:
    the greedy-completion incumbent is returned with ``optimal=False`` when
    a budget is hit (at paper scale K=4, N=20 optimality is proven in ms).
    """
    t0 = time.perf_counter()
    inst = _Instance(tasks, params, order)
    N, K = inst.N, inst.K

    use_rqad = bound == "rqad"
    if use_rqad:
        from .qad import build_qad_arrays, partial_lb_slack, solve_rqad_batch
        A, b, const = build_qad_arrays(
            inst.c, inst.w, inst.e,
            np.where(inst.e > 0, inst.w[:, None] / np.maximum(inst.tx_edge,
                                                              1e-300), 1e-30),
            inst.w / np.maximum(inst.cloud - inst.c / params.F_cloud, 1e-300),
            cloud_compute=inst.c / params.F_cloud)
        # NOTE: r_edge / r_cloud reconstructed from the permuted cost
        # arrays so the relaxation sees the same branching order.
        part_free = (inst.P_c / inst.F[None, :]).sum(axis=1) + inst.part_fixed
        rqad_slack = partial_lb_slack(inst.cloud, part_free)

    # incumbent
    if warm_start == "greedy":
        best_dec = inst.greedy_complete([])
    else:
        best_dec = np.full(N, -1, dtype=np.int64)
    best_cost = inst.exact_cost(best_dec)

    counter = itertools.count()
    heap: list[tuple] = []

    def priority(depth: int, lb: float) -> tuple:
        if strategy == "depth_first":
            return (-depth, lb)
        return (lb, -depth)

    S0, tx0 = inst.prefix_state([])
    root_lb = inst.marginal_lb(S0, tx0, 0)
    heapq.heappush(heap, (priority(0, root_lb), next(counter), [], root_lb,
                          S0, tx0))
    explored = pruned = 0
    optimal = True

    while heap:
        if explored >= max_nodes or (max_seconds is not None
                                     and time.perf_counter() - t0
                                     > max_seconds):
            optimal = False
            break
        _, _, decisions, node_lb, S_node, tx_node = heapq.heappop(heap)
        if node_lb > best_cost + prune_tol:
            pruned += 1
            continue
        depth = len(decisions)
        if depth == N:
            cost = inst.exact_cost(np.asarray(decisions))
            if cost < best_cost:
                best_cost, best_dec = cost, np.asarray(decisions)
            continue
        explored += 1
        # expand children, carrying (S, tx) incrementally
        prefixes = [decisions + [ch] for ch in inst.choices[depth]]
        while len(prefixes) == 1 and len(prefixes[0]) < N:
            d2 = len(prefixes[0])
            prefixes = [prefixes[0] + [ch] for ch in inst.choices[d2]]
        child_depth = len(prefixes[0])

        lbs = np.empty(len(prefixes))
        states = []
        for ci, dec in enumerate(prefixes):
            S, tx = S_node.copy(), tx_node
            for nd in range(depth, child_depth):
                ch = dec[nd]
                if ch == K:
                    S += inst.P_sq[nd]
                    tx += inst.part_fixed[nd]
                elif ch >= 0:
                    S[ch] += inst.sq[nd]
                    tx += inst.tx_edge[nd, ch]
                else:
                    tx += inst.cloud[nd]
            states.append((S, tx))
            lbs[ci] = inst.marginal_lb(S, tx, child_depth)

        if use_rqad and (rqad_max_depth is None
                         or child_depth <= rqad_max_depth):
            fixed_mask = np.zeros(N)
            fixed_mask[:child_depth] = 1.0
            fixed_Ds = np.stack([_decisions_to_D(dec, N, K)
                                 for dec in prefixes])
            D_rel, f_vals, rq_lbs = solve_rqad_batch(
                A, b, inst.F, inst.e, fixed_mask, fixed_Ds, solver_iters)
            rq_lbs = np.asarray(rq_lbs) + const - rqad_slack
            lbs = np.maximum(lbs, rq_lbs)

        for ci, dec in enumerate(prefixes):
            if lbs[ci] > best_cost + prune_tol:
                pruned += 1
                continue
            # greedy completion: exact upper bound + candidate incumbent
            full = inst.greedy_complete(dec)
            ub = inst.exact_cost(full)
            if ub < best_cost:
                best_cost, best_dec = ub, full
            if child_depth == N:
                cost = inst.exact_cost(np.asarray(dec))
                if cost < best_cost:
                    best_cost, best_dec = cost, np.asarray(dec)
                continue
            S_c, tx_c = states[ci]
            heapq.heappush(heap, (priority(child_depth, float(lbs[ci])),
                                  next(counter), dec, float(lbs[ci]),
                                  S_c, tx_c))

    D = inst.to_D(best_dec)
    part = inst.to_partial_mask(best_dec)
    e_full = (tasks.e * params.assoc).astype(np.float64)
    f = allocate_closed_form(D * e_full, tasks.c, params.F)
    if part.any():
        obj = decisions_cost(np.asarray(best_dec)[inst.inv], tasks, params)
    else:
        obj = assignment_cost(D, tasks, params)
    return BnBResult(D=D, f=f, objective=float(obj),
                     nodes_explored=explored, nodes_pruned=pruned,
                     solve_seconds=time.perf_counter() - t0, optimal=optimal,
                     partial=part)


def _decisions_to_D(decisions: list[int], N: int, K: int) -> np.ndarray:
    # a partial decision (ch == K) maps to an all-zero row: the relaxation
    # prices it as cloud, which the partial slack correction accounts for
    D = np.zeros((N, K))
    for n, ch in enumerate(decisions):
        if 0 <= ch < K:
            D[n, ch] = 1.0
    return D


def brute_force(tasks: QueryTasks, params: SystemParams) -> BnBResult:
    """Exhaustive minimizer (tests / tiny instances only)."""
    t0 = time.perf_counter()
    N, K = tasks.N, params.K
    e = (tasks.e * params.assoc).astype(np.float64)
    choices = [[-1] + list(np.flatnonzero(e[n] > 0))
               + ([K] if tasks.partial_option(n) is not None else [])
               for n in range(N)]
    best_cost, best_combo = np.inf, tuple([-1] * N)
    n_nodes = 0
    for combo in itertools.product(*choices):
        n_nodes += 1
        cost = decisions_cost(np.asarray(combo, dtype=np.int64),
                              tasks, params)
        if cost < best_cost:
            best_cost, best_combo = cost, combo
    best_D = _decisions_to_D(list(best_combo), N, K)
    part = np.asarray(best_combo, dtype=np.int64) == K
    f = allocate_closed_form(best_D * e, tasks.c, params.F)
    return BnBResult(D=best_D, f=f, objective=float(best_cost),
                     nodes_explored=n_nodes, nodes_pruned=0,
                     solve_seconds=time.perf_counter() - t0, optimal=True,
                     partial=part)
