"""Pattern-induced subgraphs (paper Def. 5).

``G[P] = ( ∪_{p∈P} ∪_{µ∈MS(p)} V(µ),  ∪_{p∈P} ∪_{µ∈MS(p)} E(µ) )`` —
the union of vertices/edges participating in at least one homomorphic match
of any pattern in P. Construction uses **homomorphism** (completeness);
routing uses **isomorphism** (soundness) — see paper Fig. 3 discussion.

Two construction paths:

- ``induced_edge_ids`` (paper-faithful, exact): enumerate MS(p) with the
  vectorized matcher and union the matched edge ids.
- ``induced_edge_ids_semijoin`` (beyond-paper optimization): a full-reducer
  semijoin program that computes, per pattern edge, the triples that survive
  iterated semijoin filtering. For acyclic patterns this equals the exact
  edge set without ever materializing the (possibly exponential) match set;
  for cyclic patterns it yields a superset — still *sound and complete* for
  query answering (any G' with G[P] ⊆ G' ⊆ G preserves all matches of
  queries isomorphic to p, and cannot invent matches since G' ⊆ G).
"""

from __future__ import annotations

import threading

import numpy as np

from ..rdf.graph import RDFStore
from ..sparql.matcher import match_bgp
from ..sparql.query import QueryGraph, TriplePattern
from .pattern import VAR_PRED_LABEL, Pattern


def pattern_to_query(p: Pattern) -> QueryGraph:
    """Lift a pattern back to an all-variable query graph for matching."""
    pats = []
    for i, (u, v, l) in enumerate(p.edges):
        pats.append(TriplePattern(
            f"?v{u}", f"?p{i}" if l == VAR_PRED_LABEL else int(l), f"?v{v}"))
    return QueryGraph(patterns=pats, projection=[])


def induced_edge_ids(store: RDFStore, patterns: list[Pattern],
                     max_rows: int = 20_000_000) -> np.ndarray:
    """Exact Def. 5 edge set: union of matched edge ids over all patterns."""
    parts: list[np.ndarray] = []
    for p in patterns:
        res = match_bgp(store, pattern_to_query(p), max_rows=max_rows)
        if res.edge_ids.size:
            parts.append(np.unique(res.edge_ids))
    if not parts:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(parts))


class InducedIndex:
    """Memoized per-pattern induced-edge-id computation.

    Entries are keyed ``(store.version, pattern.key)`` — version-granular,
    because stores may now mutate in place through the delta protocol
    (:mod:`repro.rdf.deltas`) and a memo keyed on pattern alone would go
    stale the moment the cloud graph changes. For an unchanged cloud store,
    repeated rebalances cost **zero** matcher calls for patterns already
    measured (the regression test in ``tests/test_rebalance.py`` asserts
    exactly that); only genuinely new ``(version, pattern)`` combinations
    run the matcher. One index is shared across all edge servers of an
    :class:`repro.edge.system.EdgeCloudSystem` — the same pattern measured
    by two servers is matched once.
    """

    def __init__(self, method: str = "exact") -> None:
        if method not in ("exact", "semijoin"):
            raise ValueError(f"unknown method {method!r}")
        self.method = method
        # per-store-version working sets: {version: {pattern key: eids}}.
        # Superseded versions are dropped as soon as a newer one is seen
        # (under live cloud ingest every apply_delta shifts the id space,
        # so old-version entries can never be served again) — bounding the
        # memo at O(live versions x patterns) instead of growing forever.
        self._memo: dict[object, dict[tuple, np.ndarray]] = {}
        # in-flight computations, keyed (version, pattern key): concurrent
        # callers (the parallel rebalance compute phase fans out over
        # edges that often share patterns) wait on the owner instead of
        # duplicating matcher work — "unchanged patterns cost zero matcher
        # calls" holds per pattern even under concurrency
        self._pending: dict[tuple, threading.Event] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def edge_ids(self, store: RDFStore, p: Pattern) -> np.ndarray:
        """Cloud-global edge ids of ``G[{p}]`` (cached, read-only)."""
        key = (store.version, p.key)
        while True:
            with self._lock:
                per_ver = self._memo.get(store.version)
                eids = None if per_ver is None else per_ver.get(p.key)
                if eids is not None:
                    self.hits += 1
                    return eids
                event = self._pending.get(key)
                if event is None:
                    self._pending[key] = event = threading.Event()
                    self.misses += 1
                    break                # this caller computes
            event.wait()                 # another caller is computing;
            #                              loop re-reads (or takes over on
            #                              the owner's failure)
        try:
            fn = (induced_edge_ids if self.method == "exact"
                  else induced_edge_ids_semijoin)
            eids = fn(store, [p])       # matcher runs outside the lock
            with self._lock:
                if store.version not in self._memo:
                    # a NEW version supersedes any other version's entries
                    self._memo = {store.version: {}}
                self._memo[store.version][p.key] = eids
            return eids
        finally:
            with self._lock:
                self._pending.pop(key, None)
            event.set()

    def union_edge_ids(self, store: RDFStore,
                       patterns: list[Pattern]) -> np.ndarray:
        """Union of per-pattern edge ids (each memoized independently, so
        residency changes re-match only the patterns that are new)."""
        parts = [e for p in patterns
                 if len(e := self.edge_ids(store, p))]
        if not parts:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate(parts))

    def install(self, version, entries: dict[tuple, np.ndarray]) -> None:
        """Seed the working set for ``version`` with precomputed entries,
        superseding every other version.

        This is the live-ingest carry-forward: when a delta touches only
        some predicates, :meth:`repro.edge.system.EdgeCloudSystem.
        apply_update` proves which patterns are untouched, remaps their old
        matched-edge ids to the new global id space, and installs them here
        — so the post-ingest rebalance/propagation pays matcher calls only
        for genuinely invalidated patterns. Entries land as memo *hits*.
        """
        with self._lock:
            self._memo = {version: dict(entries)}

    def entries_for(self, version) -> dict[tuple, np.ndarray]:
        """Snapshot of the memo entries for ``version`` (empty if gone)."""
        with self._lock:
            return dict(self._memo.get(version, {}))

    def clear(self) -> None:
        with self._lock:
            self._memo.clear()


def reship_bytes(store: RDFStore, patterns: list[Pattern],
                 index: "InducedIndex | None" = None) -> int:
    """Bytes to make a query edge-feasible the all-or-nothing way: ship the
    ENTIRE induced subgraph ``G[P]`` of its required-leaf patterns to one
    edge (three int64 columns per triple — the delta wire format). This is
    the baseline that partial evaluation's ``partial_bytes_shipped`` is
    gated against (``bench_engine --partial``)."""
    if index is not None:
        eids = index.union_edge_ids(store, patterns)
    else:
        eids = induced_edge_ids(store, patterns)
    return int(len(eids) * 3 * np.dtype(np.int64).itemsize)


def induced_subgraph(store: RDFStore, patterns: list[Pattern],
                     method: str = "exact") -> RDFStore:
    if method == "exact":
        eids = induced_edge_ids(store, patterns)
    elif method == "semijoin":
        eids = induced_edge_ids_semijoin(store, patterns)
    else:
        raise ValueError(f"unknown method {method!r}")
    return store.subgraph(eids)


# ---------------------------------------------------------------------------
# semijoin full reducer (beyond-paper fast path)
# ---------------------------------------------------------------------------

def _semijoin_reduce_one(store: RDFStore, p: Pattern,
                         n_rounds: int | None = None) -> np.ndarray:
    """Edge ids surviving iterated semijoins for one pattern.

    Candidate triple sets per pattern edge are filtered until fixpoint: a
    triple survives for pattern edge (u,v,l) only if, for every other pattern
    edge incident to u (resp. v), some surviving triple agrees on the shared
    vertex. For acyclic patterns this is the exact participating-edge set
    (Yannakakis); for cyclic ones a superset.
    """
    E = len(p.edges)
    cand: list[np.ndarray] = []       # triple ids per pattern edge
    for (u, v, l) in p.edges:
        if l == VAR_PRED_LABEL:
            tids = np.arange(store.num_triples, dtype=np.int64)
        else:
            tids = store.pred_tids(int(l))
        if u == v:
            tids = tids[store.s[tids] == store.o[tids]]
        cand.append(tids)

    # adjacency between pattern edges through shared vertices:
    # for pattern edge a, its endpoint x (0 -> u, 1 -> v) must agree with
    # pattern edge b's endpoint y
    links: list[list[tuple[int, int, int]]] = [[] for _ in range(E)]
    for a in range(E):
        ua, va, _ = p.edges[a]
        for b in range(E):
            if a == b:
                continue
            ub, vb, _ = p.edges[b]
            for (ea, sa) in ((ua, 0), (va, 1)):
                for (eb, sb) in ((ub, 0), (vb, 1)):
                    if ea == eb:
                        links[a].append((b, sa, sb))

    def endpoint(tids: np.ndarray, side: int) -> np.ndarray:
        return store.s[tids] if side == 0 else store.o[tids]

    rounds = n_rounds if n_rounds is not None else 2 * E
    for _ in range(rounds):
        changed = False
        for a in range(E):
            keep = np.ones(len(cand[a]), dtype=bool)
            for (b, sa, sb) in links[a]:
                vals_b = np.unique(endpoint(cand[b], sb))
                keep &= np.isin(endpoint(cand[a], sa), vals_b)
            if not keep.all():
                cand[a] = cand[a][keep]
                changed = True
        if not changed:
            break
    if any(len(c) == 0 for c in cand):
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(cand))


def induced_edge_ids_semijoin(store: RDFStore,
                              patterns: list[Pattern]) -> np.ndarray:
    parts = [_semijoin_reduce_one(store, p) for p in patterns]
    parts = [x for x in parts if len(x)]
    if not parts:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(parts))
