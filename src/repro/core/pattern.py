"""Patterns, minimum DFS codes, and the edge-server pattern index.

Paper §3.2 (Data Model):

- A *pattern* generalizes a query: every constant at subject/object position
  becomes a variable (Def. 4). Predicate labels are kept; identical constants
  keep their join structure (they were one query-graph vertex already).
- Query executability at an edge server is decided by **graph isomorphism**
  between the query's pattern and a stored pattern, via canonical *minimum
  DFS codes* (gSpan [Yan/Yu/Han, SIGMOD'04]) hashed into a table.

The minimum DFS code here extends gSpan to *directed, edge-labeled
multigraphs with unlabeled vertices* (exactly the shape of SPARQL patterns):
each code entry covers ``(i, j, direction, label)`` over DFS discovery
indices; ``direction`` records the RDF edge orientation relative to the
traversal. The canonical form is the lexicographic minimum over all valid
rightmost-path DFS traversals; two patterns share a code iff isomorphic.

Limitation (documented in DESIGN.md): predicate *variables* are all encoded
with one sentinel label; patterns whose only difference is predicate-variable
sharing across edges are treated as non-indexable and routed to the cloud
(``Pattern.indexable``). Our workloads use constant predicates throughout.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

from ..sparql.query import QueryGraph

VAR_PRED_LABEL = -2


@dataclass(frozen=True)
class Pattern:
    """A query shape: directed edge-labeled multigraph over anonymous vertices.

    ``edges``: tuple of (u, v, label) with u, v in [0, n_vertices).
    Identical duplicate edges are collapsed (they add no constraint under
    homomorphism semantics).
    """

    edges: tuple[tuple[int, int, int], ...]
    n_vertices: int
    indexable: bool = True

    @cached_property
    def code(self) -> tuple:
        return min_dfs_code(self.edges, self.n_vertices)

    @cached_property
    def key(self) -> tuple:
        """Hashable canonical key (what the paper's hash table indexes)."""
        return (self.n_vertices, self.code)

    def isomorphic_to(self, other: "Pattern") -> bool:
        return self.key == other.key

    @property
    def n_edges(self) -> int:
        return len(self.edges)


def pattern_of(q: QueryGraph) -> Pattern:
    """Def. 4: replace constants at subject/object positions by variables.

    Vertex identity (join structure) is preserved; predicate constants stay.
    """
    verts = q.vertices()
    vmap = {v: i for i, v in enumerate(verts)}
    edges = set()
    pred_vars: dict[str, int] = {}
    for tp in q.patterns:
        if isinstance(tp.p, str):
            label = VAR_PRED_LABEL
            pred_vars[tp.p] = pred_vars.get(tp.p, 0) + 1
        else:
            label = tp.p
        edges.add((vmap[tp.s], vmap[tp.o], label))
    # a predicate variable shared across edges encodes a label-join the DFS
    # code cannot express -> non-indexable (cloud-routed), stays sound
    indexable = all(c == 1 for c in pred_vars.values())
    return Pattern(edges=tuple(sorted(edges)), n_vertices=len(verts),
                   indexable=indexable)


# ---------------------------------------------------------------------------
# algebra-plan feasibility (per-BGP-leaf patterns)
# ---------------------------------------------------------------------------

def feasibility_patterns(q) -> list[Pattern] | None:
    """Patterns whose residency certifies edge executability of ``q``.

    ``q`` is a plain :class:`~repro.sparql.query.QueryGraph` (one pattern —
    the pre-algebra behavior) or a compiled algebra plan
    (:class:`repro.sparql.algebra.Node`). For a plan, edge execution is
    sound iff the union of its **required** BGP leaves is covered by the
    edge's pattern-induced residency: every required leaf isomorphic to a
    resident pattern finds its complete match set over G[P] (the paper's
    completeness guarantee), and FILTER / DISTINCT / ORDER / slice
    operators only ever combine or drop those rows. OPTIONAL right sides
    are *excluded from the requirement* — they can only extend solutions,
    and an edge lacking them under-binds optional columns (the documented
    relaxation; deploy their patterns too for exact cloud parity).

    Returns ``None`` when edge execution cannot be certified at all: a
    required leaf is disconnected (no DFS code exists) or the plan has no
    required leaf with patterns (nothing to anchor residency on).
    """
    leaves = getattr(q, "bgp_leaves", None)
    if leaves is None:
        return [pattern_of(q)]
    pats: list[Pattern] = []
    for leaf in q.bgp_leaves(required_only=True):
        if not leaf.query.patterns:
            continue
        if not leaf.query.is_weakly_connected():
            return None
        pats.append(pattern_of(leaf.query))
    return pats or None


@dataclass
class LeafResidency:
    """Per-required-leaf residency report — the refactor of the
    all-or-nothing edge-executable boolean into *which* leaves live where.

    leaves:   required leaf :class:`QueryGraph`\\ s (``bgp_leaves`` order)
    leaf_idx: index of each into the plan's full ``bgp_leaves()`` list;
              ``[-1]`` for a plain :class:`QueryGraph` (the query itself)
    resident: [L, K'] bool — ``leaves[i]``'s whole-leaf pattern is
              isomorphic to a pattern resident at ``servers[j]``
    servers:  the server ids the columns of ``resident`` refer to
    """

    leaves: list
    leaf_idx: list[int]
    resident: np.ndarray
    servers: list[int]

    def covered_servers(self) -> list[int]:
        """Servers holding EVERY required leaf (the legacy e[n,k] == 1)."""
        full = self.resident.all(axis=0)
        return [s for s, ok in zip(self.servers, full) if ok]


def leaf_residency(q, edge_servers) -> LeafResidency | None:
    """Report which required leaves of ``q`` are resident per edge server.

    Same certification rules as :func:`feasibility_patterns` (whole-leaf
    pattern isomorphism against each server's index; OPTIONAL right sides
    excluded), but instead of collapsing to one boolean per edge it keeps
    the [leaf x server] matrix — the input the partial-evaluation planner
    (:mod:`repro.sparql.partial_eval`) needs to split a query across a set
    of contributing edges. ``edge_servers`` only need ``server_id`` and
    ``can_execute(pattern)``. Returns ``None`` when residency cannot be
    certified at all (disconnected required leaf / nothing required).
    """
    leaves = getattr(q, "bgp_leaves", None)
    if leaves is None:
        if not q.patterns or not q.is_weakly_connected():
            return None
        qs, idxs = [q], [-1]
    else:
        required = {id(leaf) for leaf in q.bgp_leaves(required_only=True)}
        qs, idxs = [], []
        for i, leaf in enumerate(q.bgp_leaves()):
            if id(leaf) not in required or not leaf.query.patterns:
                continue
            if not leaf.query.is_weakly_connected():
                return None
            qs.append(leaf.query)
            idxs.append(i)
        if not qs:
            return None
    resident = np.zeros((len(qs), len(edge_servers)), dtype=bool)
    for i, lq in enumerate(qs):
        p = pattern_of(lq)
        for j, es in enumerate(edge_servers):
            resident[i, j] = bool(es.can_execute(p))
    return LeafResidency(leaves=qs, leaf_idx=idxs, resident=resident,
                         servers=[es.server_id for es in edge_servers])


def observed_patterns(q) -> list[Pattern]:
    """Patterns the placement policy should learn from ``q`` — ALL its BGP
    leaves (OPTIONAL sides included, so dynamic placement can make optional
    parts resident and restore exact edge/cloud parity), skipping
    disconnected or empty leaves."""
    leaves = getattr(q, "bgp_leaves", None)
    if leaves is None:
        return [pattern_of(q)]
    return [pattern_of(leaf.query) for leaf in q.bgp_leaves()
            if leaf.query.patterns and leaf.query.is_weakly_connected()]


# ---------------------------------------------------------------------------
# minimum DFS code
# ---------------------------------------------------------------------------

def _entry_key(i: int, j: int, d: int, l: int) -> tuple:
    """Total order on code entries realizing gSpan's edge order.

    backward (j <= i): (i, 1, j, d, l) — forward (j > i): (j, 0, -i, d, l).
    This places, for a shared prefix, backward edges of the rightmost vertex
    before forward extensions, and deeper forward extensions last, matching
    gSpan's <_e; direction flag and label break structural ties.
    """
    if j > i:
        return (j, 0, -i, d, l)
    return (i, 1, j, d, l)


def min_dfs_code(edges: tuple[tuple[int, int, int], ...],
                 n_vertices: int) -> tuple:
    """Lexicographically minimal DFS code over all valid traversals.

    Exhaustive rightmost-path extension with lexicographic prefix pruning —
    patterns are small (the paper notes <10 triples), so this is
    microseconds-to-milliseconds in practice.
    """
    if not edges:
        return ()
    E = len(edges)
    # undirected incidence: vertex -> list of (edge_idx, other, direction);
    # direction 0 when the stored edge leaves this endpoint (u == vertex)
    inc: list[list[tuple[int, int, int]]] = [[] for _ in range(n_vertices)]
    for ei, (u, v, l) in enumerate(edges):
        inc[u].append((ei, v, 0))
        if u != v:
            inc[v].append((ei, u, 1))

    best: list[tuple] | None = None

    def search(order: tuple[int, ...], vmap: dict[int, int],
               rpath: tuple[int, ...], used: int, code: list[tuple]) -> None:
        nonlocal best
        if len(code) == E:
            if best is None or code < best:
                best = list(code)
            return
        pos = len(code)
        cands: list[tuple[tuple, int, int, int]] = []  # (key, edge, newv, src)
        rm = rpath[-1]
        on_rpath = set(rpath)
        # backward (incl. self-loop) edges from the rightmost vertex
        for (ei, other, d) in inc[order[rm]]:
            if used >> ei & 1:
                continue
            jo = vmap.get(other)
            if jo is not None and jo in on_rpath:
                cands.append((_entry_key(rm, jo, d, edges[ei][2]), ei, -1, -1))
        # forward edges from any rightmost-path vertex to a new vertex
        for ridx in rpath:
            for (ei, other, d) in inc[order[ridx]]:
                if used >> ei & 1:
                    continue
                if other not in vmap:
                    cands.append((_entry_key(ridx, len(order), d,
                                             edges[ei][2]), ei, other, ridx))
        if not cands:
            return  # dead end: remaining edges unreachable under the rule
        cands.sort(key=lambda c: c[0])
        for (k, ei, newv, src) in cands:
            if best is not None:
                code.append(k)
                worse = code > best[:pos + 1]
                code.pop()
                if worse:
                    break  # candidates are sorted: the rest are worse too
            code.append(k)
            if newv >= 0:
                nvmap = dict(vmap)
                nvmap[newv] = len(order)
                cut = rpath.index(src) + 1
                search(order + (newv,), nvmap,
                       rpath[:cut] + (len(order),), used | (1 << ei), code)
            else:
                search(order, vmap, rpath, used | (1 << ei), code)
            code.pop()

    for v0 in range(n_vertices):
        if inc[v0]:
            search((v0,), {v0: 0}, (0,), 0, [])
    if best is None:
        raise ValueError("pattern is not weakly connected")
    return tuple(best)


# ---------------------------------------------------------------------------
# pattern index (paper: canonical DFS codes hashed into a table)
# ---------------------------------------------------------------------------

class PatternIndex:
    """Hash index: canonical code -> payloads (e.g. which ES stores it).

    This is the paper's "lightweight indexing mechanism": the executable
    vector E is built by O(1) lookups instead of subgraph-matching at
    scheduling time.
    """

    def __init__(self) -> None:
        self._table: dict[tuple, list] = {}

    def add(self, p: Pattern, payload) -> None:
        if not p.indexable:
            raise ValueError("non-indexable pattern (shared predicate vars)")
        self._table.setdefault(p.key, []).append(payload)

    def lookup(self, p: Pattern) -> list:
        if not p.indexable:
            return []
        return self._table.get(p.key, [])

    def lookup_query(self, q: QueryGraph) -> list:
        return self.lookup(pattern_of(q))

    def __contains__(self, p: Pattern) -> bool:
        return bool(self.lookup(p))

    def __len__(self) -> int:
        return len(self._table)
