"""System model (paper §3.2): communication, computation, query costs.

Notation (Table 1): N end users, K edge servers; query task Q_n = (c_n, w_n)
with c_n CPU cycles and w_n result bits; downlink rates r^{n,k} (edge->user,
OFDMA model Eq. 4) and r^{n,c} (cloud->user); edge compute capacity F_k.

Costs:  edge  O_e^{n,k} = c_n / f_{n,k} + w_n / r^{n,k}
        cloud O_c^{n}   = w_n / r^{n,c}           (cloud compute ~ free)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..rdf.graph import RDFStore
from ..sparql.matcher import estimate_pattern_cardinality
from ..sparql.query import QueryGraph


def ofdma_rate(bandwidth_hz: np.ndarray | float,
               tx_power: np.ndarray | float,
               channel_gain: np.ndarray | float,
               noise_power: float = 1e-9) -> np.ndarray:
    """Eq. (4): r = B log2(1 + tp * h / sigma^2)."""
    return np.asarray(bandwidth_hz) * np.log2(
        1.0 + np.asarray(tx_power) * np.asarray(channel_gain) / noise_power)


@dataclass
class SystemParams:
    """Static system-side parameters.

    F:        [K] edge compute capacity, cycles/s
    r_edge:   [N, K] downlink rate ES_k -> EU_n, bits/s
    r_cloud:  [N] downlink rate cloud -> EU_n, bits/s
    assoc:    [N, K] bool, EU_n physically associated with ES_k
    """

    F: np.ndarray
    r_edge: np.ndarray
    r_cloud: np.ndarray
    assoc: np.ndarray

    @property
    def N(self) -> int:
        return len(self.r_cloud)

    @property
    def K(self) -> int:
        return len(self.F)

    @classmethod
    def synthetic(cls, n_users: int, n_edges: int, seed: int = 0,
                  edge_mbps: float = 75.0, cloud_mbps: float = 5.0,
                  f_ghz: float = 0.2, multi_assoc_frac: float = 0.8,
                  ) -> "SystemParams":
        """Paper §5.1 defaults: edge link ~70-80 Mbps, cloud ~5 Mbps,
        0.2 GHz edge CPUs; ~20% of users see one ES, the rest several."""
        rng = np.random.default_rng(seed)
        F = np.full(n_edges, f_ghz * 1e9)
        # association: every user gets >=1 ES; multi-assoc users get 2-3
        assoc = np.zeros((n_users, n_edges), dtype=bool)
        for n in range(n_users):
            k0 = int(rng.integers(n_edges))
            assoc[n, k0] = True
            if rng.random() < multi_assoc_frac and n_edges > 1:
                extra = int(rng.integers(1, min(3, n_edges)))
                others = rng.choice([k for k in range(n_edges) if k != k0],
                                    size=min(extra, n_edges - 1),
                                    replace=False)
                assoc[n, others] = True
        # rates: jitter around nominal (OFDMA model collapses to this for
        # fixed bandwidth/power/gain; Eq. 4 provided for physical configs)
        r_edge = (edge_mbps * 1e6) * rng.uniform(0.9, 1.1, (n_users, n_edges))
        r_edge = np.where(assoc, r_edge, 0.0)
        r_cloud = (cloud_mbps * 1e6) * rng.uniform(0.9, 1.1, n_users)
        return cls(F=F, r_edge=r_edge, r_cloud=r_cloud, assoc=assoc)


@dataclass
class QueryTasks:
    """Per-query parameters + executability matrix E (Eq. 2)."""

    c: np.ndarray          # [N] cycles
    w: np.ndarray          # [N] bits
    e: np.ndarray          # [N, K] {0,1}

    @property
    def N(self) -> int:
        return len(self.c)


# ---------------------------------------------------------------------------
# cost evaluation (Eq. 5 / Eq. 10)
# ---------------------------------------------------------------------------

def total_cost(D: np.ndarray, f: np.ndarray, tasks: QueryTasks,
               params: SystemParams) -> float:
    """Eq. (5) evaluated for explicit (D, F). D, f: [N, K]."""
    De = D * tasks.e
    on_edge = De.sum(axis=1)  # 0 or 1 per user
    edge_comp = np.where(De > 0, tasks.c[:, None] / np.maximum(f, 1e-30), 0.0)
    with np.errstate(divide="ignore"):
        edge_tx = np.where(De > 0,
                           tasks.w[:, None] / np.maximum(params.r_edge, 1e-30),
                           0.0)
    cloud = (1.0 - on_edge) * tasks.w / params.r_cloud
    return float((De * (edge_comp + edge_tx)).sum() + cloud.sum())


def assignment_cost(D: np.ndarray, tasks: QueryTasks,
                    params: SystemParams) -> float:
    """Eq. (14): exact cost of an integral assignment with optimal CRA."""
    from .cra import allocate_closed_form, o_total_calc
    De = (D * tasks.e).astype(np.float64)
    o_calc = o_total_calc(De, tasks.c, params.F)
    with np.errstate(divide="ignore"):
        edge_tx = np.where(De > 0,
                           tasks.w[:, None] / np.maximum(params.r_edge, 1e-30),
                           0.0).sum()
    cloud = ((1.0 - De.sum(axis=1)) * tasks.w / params.r_cloud).sum()
    return float(o_calc + edge_tx + cloud)


# ---------------------------------------------------------------------------
# query cost estimation (paper adopts selectivity estimators [29, 41])
# ---------------------------------------------------------------------------

CYCLES_PER_ROW = 220.0       # calibration constant: join work per binding row
CYCLES_BASE = 5e4            # fixed per-query overhead (parse, plan)
BITS_PER_CELL = 64.0
BITS_PER_BYTE = 8


def result_bits(res, projection: list[str]) -> float:
    """w_n in *bits* from a :class:`~repro.sparql.matcher.MatchResult`.

    The single source of the bytes->bits unit conversion for result-size
    accounting — every ``ExecutionRecord.result_bits`` and measured ``w_n``
    goes through here (Eq. 5 divides w_n by link rates in bits/s).
    """
    return float(res.result_bytes(projection) * BITS_PER_BYTE)


def estimate_query_cost(store: RDFStore, q,
                        ) -> tuple[float, float]:
    """(c_n cycles, w_n bits) via join-order cardinality simulation.

    Follows Stocker et al. [WWW'08]-style selectivity composition: walk the
    greedy join order, multiplying in per-pattern selectivities; c_n sums the
    estimated intermediate sizes (work), w_n is the final estimate (result).

    ``q`` is a plain :class:`QueryGraph` or a compiled algebra plan
    (:class:`repro.sparql.algebra.Node`): a plan costs the sum of its BGP
    leaves' work c_n (every leaf executes) and estimates w_n structurally
    — UNION **sums** its branches (concatenation grows the result), while
    join/filter/modifier operators take the largest input (they only
    combine or drop rows of their inputs).
    """
    leaves = getattr(q, "bgp_leaves", None)
    if leaves is not None:
        from ..sparql.algebra import BGPNode, UnionNode
        work = 0.0

        def est_w(node) -> float:
            nonlocal work
            if isinstance(node, BGPNode):
                if not node.query.patterns:
                    return float(BITS_PER_CELL)
                c_i, w_i = estimate_query_cost(store, node.query)
                work += c_i - CYCLES_BASE
                return w_i
            kids = [est_w(c) for c in node.children()]
            if not kids:
                return float(BITS_PER_CELL)
            return float(sum(kids) if isinstance(node, UnionNode)
                         else max(kids))
        w = est_w(q)
        return float(CYCLES_BASE + work), max(w, float(BITS_PER_CELL))
    from ..sparql.matcher import _order_patterns  # same plan as execution
    order = _order_patterns(store, q)
    bound: set[str] = set()
    rows = 1.0
    work = 0.0
    for i in order:
        tp = q.patterns[i]
        card = max(estimate_pattern_cardinality(store, tp), 1e-3)
        # classic independent-join estimate: each shared variable divides by
        # the distinct-value count of the position it occupies in tp
        denom = 1.0
        if isinstance(tp.p, int):
            ds = max(1.0, float(store.pred_distinct_s[tp.p]))
            do = max(1.0, float(store.pred_distinct_o[tp.p]))
        else:
            ds = do = max(1.0, float(store.num_entities) ** 0.5)
        if isinstance(tp.s, str) and tp.s in bound:
            denom *= ds
        if isinstance(tp.o, str) and tp.o in bound:
            denom *= do
        if isinstance(tp.p, str) and tp.p in bound:
            denom *= max(1.0, float(store.num_predicates))
        rows = rows * card / denom
        rows = max(rows, 0.0)
        work += rows
        bound.update(tp.variables())
    n_proj = max(1, len(q.projection) if q.projection else len(q.variables))
    c = CYCLES_BASE + CYCLES_PER_ROW * work
    w = max(BITS_PER_CELL, rows * n_proj * BITS_PER_CELL)
    return float(c), float(w)


def measured_query_cost(store: RDFStore, q: QueryGraph,
                        engine=None) -> tuple[float, float, int]:
    """(c_n cycles-equivalent, w_n bits, n_matches) by actually executing.

    ``engine``: optional :class:`repro.sparql.engine.QueryEngine` — routes
    execution through its backend and result cache, so repeated measurement
    of a hot query (re-costing between scheduling rounds) is a cache hit.
    ``q`` may be a plain :class:`QueryGraph` or a compiled algebra plan
    (the latter requires an engine).
    """
    if engine is not None:
        from ..sparql.algebra import execute_any_batch
        res = execute_any_batch(store, engine, [q])[0]
    else:
        from ..sparql.algebra import is_algebra_plan
        if is_algebra_plan(q):
            raise ValueError("measuring an algebra plan needs an engine")
        from ..sparql.matcher import match_bgp
        res = match_bgp(store, q)
    n_rows = res.num_matches
    c = CYCLES_BASE + CYCLES_PER_ROW * max(n_rows, 1)
    # unit check: 64-bit binding cells == 8 bytes/cell; w_n must be bits
    assert BITS_PER_CELL == BITS_PER_BYTE * np.dtype(np.int64).itemsize
    w = result_bits(res, q.projection)
    return float(c), w, n_rows


def measured_query_cost_batch(store: RDFStore, queries: list[QueryGraph],
                              engine) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """Vectorized measured costs ([N] c, [N] w, [N] n_matches) for a batch.

    One ``engine.execute_batch`` call: identical candidate scans across the
    batch run once and alpha-equivalent queries share cached results, which
    is what makes measured (rather than estimated) costs affordable as a
    scheduler input at serving scale. Mixed BGP/algebra batches are
    supported — every algebra plan's BGP leaves join the same batch.
    """
    from ..sparql.algebra import execute_any_batch
    results = execute_any_batch(store, engine, queries)
    n = np.array([r.num_matches for r in results], dtype=np.int64)
    c = CYCLES_BASE + CYCLES_PER_ROW * np.maximum(n, 1).astype(np.float64)
    w = np.array([result_bits(r, q.projection)
                  for q, r in zip(queries, results)], dtype=np.float64)
    return c, w, n
