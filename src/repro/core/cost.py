"""System model (paper §3.2): communication, computation, query costs.

Notation (Table 1): N end users, K edge servers; query task Q_n = (c_n, w_n)
with c_n CPU cycles and w_n result bits; downlink rates r^{n,k} (edge->user,
OFDMA model Eq. 4) and r^{n,c} (cloud->user); edge compute capacity F_k.

Costs:  edge  O_e^{n,k} = c_n / f_{n,k} + w_n / r^{n,k}
        cloud O_c^{n}   = w_n / r^{n,c} + c_n / F_cloud
        partial O_p^{n} = Σ_e (c_e/f_e + b_e/r_bh^e) + a_n/F_cloud
                          + w_n / r^{n,c}

The paper's Eq. 5 treats cloud compute as free (F_cloud = inf, the
default). The generalized model adds two optional knobs: ``F_cloud``
(finite = congested / metered cloud CPU) and per-edge backhaul rates
``r_backhaul`` (edge -> assembler uplink), which together price the
*partial-evaluation* plan: each contributing edge e computes its
resident-leaf fragment (c_e cycles, joining edge e's CRA pool), ships a
dictionary-free binding table of b_e bits over the backhaul, and the
cloud assembles (a_n cycles) and delivers the final w_n bits over the
user's cloud link.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..rdf.graph import RDFStore
from ..sparql.matcher import estimate_pattern_cardinality
from ..sparql.query import QueryGraph


def ofdma_rate(bandwidth_hz: np.ndarray | float,
               tx_power: np.ndarray | float,
               channel_gain: np.ndarray | float,
               noise_power: float = 1e-9) -> np.ndarray:
    """Eq. (4): r = B log2(1 + tp * h / sigma^2)."""
    return np.asarray(bandwidth_hz) * np.log2(
        1.0 + np.asarray(tx_power) * np.asarray(channel_gain) / noise_power)


@dataclass
class SystemParams:
    """Static system-side parameters.

    F:        [K] edge compute capacity, cycles/s
    r_edge:   [N, K] downlink rate ES_k -> EU_n, bits/s
    r_cloud:  [N] downlink rate cloud -> EU_n, bits/s
    assoc:    [N, K] bool, EU_n physically associated with ES_k

    Generalized-Eq.-5 extensions (both default to the paper's model):

    r_backhaul: [K] uplink rate ES_k -> cloud assembler, bits/s, or None
                (None -> DEFAULT_BACKHAUL_BPS for every edge)
    F_cloud:    cloud compute capacity in cycles/s; np.inf == the paper's
                free-cloud-compute assumption (legacy behaviour)
    """

    F: np.ndarray
    r_edge: np.ndarray
    r_cloud: np.ndarray
    assoc: np.ndarray
    r_backhaul: np.ndarray | None = None
    F_cloud: float = np.inf

    @property
    def N(self) -> int:
        return len(self.r_cloud)

    @property
    def K(self) -> int:
        return len(self.F)

    @property
    def backhaul(self) -> np.ndarray:
        """[K] effective edge->assembler uplink rates, bits/s."""
        if self.r_backhaul is None:
            return np.full(self.K, DEFAULT_BACKHAUL_BPS)
        return np.asarray(self.r_backhaul, dtype=np.float64)

    @classmethod
    def synthetic(cls, n_users: int, n_edges: int, seed: int = 0,
                  edge_mbps: float = 75.0, cloud_mbps: float = 5.0,
                  f_ghz: float = 0.2, multi_assoc_frac: float = 0.8,
                  backhaul_mbps: float = 150.0,
                  cloud_ghz: float | None = None,
                  ) -> "SystemParams":
        """Paper §5.1 defaults: edge link ~70-80 Mbps, cloud ~5 Mbps,
        0.2 GHz edge CPUs; ~20% of users see one ES, the rest several.
        ``cloud_ghz=None`` keeps the paper's free cloud compute;
        ``backhaul_mbps`` prices partial binding-table egress."""
        rng = np.random.default_rng(seed)
        F = np.full(n_edges, f_ghz * 1e9)
        # association: every user gets >=1 ES; multi-assoc users get 2-3
        assoc = np.zeros((n_users, n_edges), dtype=bool)
        for n in range(n_users):
            k0 = int(rng.integers(n_edges))
            assoc[n, k0] = True
            if rng.random() < multi_assoc_frac and n_edges > 1:
                extra = int(rng.integers(1, min(3, n_edges)))
                others = rng.choice([k for k in range(n_edges) if k != k0],
                                    size=min(extra, n_edges - 1),
                                    replace=False)
                assoc[n, others] = True
        # rates: jitter around nominal (OFDMA model collapses to this for
        # fixed bandwidth/power/gain; Eq. 4 provided for physical configs)
        r_edge = (edge_mbps * 1e6) * rng.uniform(0.9, 1.1, (n_users, n_edges))
        r_edge = np.where(assoc, r_edge, 0.0)
        r_cloud = (cloud_mbps * 1e6) * rng.uniform(0.9, 1.1, n_users)
        r_bh = (backhaul_mbps * 1e6) * rng.uniform(0.9, 1.1, n_edges)
        return cls(F=F, r_edge=r_edge, r_cloud=r_cloud, assoc=assoc,
                   r_backhaul=r_bh,
                   F_cloud=np.inf if cloud_ghz is None else cloud_ghz * 1e9)


@dataclass
class PartialOption:
    """A candidate partial-evaluation plan for one query (Eq. 5 gen.).

    edges:           [m] int edge server ids contributing fragments
    cycles:          [m] estimated fragment cycles per contributing edge
                     (joins that edge's CRA pool when the option is taken)
    ship_bits:       [m] estimated binding-table egress bits per edge
    assemble_cycles: cloud-side work: residual (non-resident) fragments +
                     the compatibility joins over the shipped tables
    plan:            opaque executable plan (sparql.partial_eval.PartialPlan)
    """

    edges: np.ndarray
    cycles: np.ndarray
    ship_bits: np.ndarray
    assemble_cycles: float
    plan: object | None = None


@dataclass
class QueryTasks:
    """Per-query parameters + executability matrix E (Eq. 2).

    ``partial``: optional [N] list of :class:`PartialOption` or None per
    query — the three-way plan space {full-edge, cloud, partial}. When the
    whole list is None (default) scheduling is the paper's binary model.
    """

    c: np.ndarray          # [N] cycles
    w: np.ndarray          # [N] bits
    e: np.ndarray          # [N, K] {0,1}
    partial: list | None = None

    @property
    def N(self) -> int:
        return len(self.c)

    def partial_option(self, n: int) -> PartialOption | None:
        return self.partial[n] if self.partial is not None else None


# ---------------------------------------------------------------------------
# cost evaluation (Eq. 5 / Eq. 10, generalized to multi-server plans)
# ---------------------------------------------------------------------------

DEFAULT_BACKHAUL_BPS = 150e6   # edge -> cloud assembler uplink default


def cloud_unit_cost(tasks: QueryTasks, params: SystemParams) -> np.ndarray:
    """[N] per-query cloud-path cost: delivery + (optional) cloud compute.

    With the paper's ``F_cloud = inf`` this is exactly ``w / r_cloud``."""
    return tasks.w / params.r_cloud + tasks.c / params.F_cloud


def partial_fixed_cost(opt: PartialOption, w_n: float,
                       params: SystemParams, row: int) -> float:
    """Congestion-independent terms of a partial plan for user-row ``row``:
    backhaul egress + cloud assembly + final delivery over the cloud link.
    The per-edge compute term is congestion-dependent (CRA pool) and is
    accounted where the assignment is known (see :func:`decisions_cost`)."""
    bh = params.backhaul[np.asarray(opt.edges, dtype=np.int64)]
    return float((np.asarray(opt.ship_bits, dtype=np.float64) / bh).sum()
                 + opt.assemble_cycles / params.F_cloud
                 + w_n / params.r_cloud[row])


def partial_free_cost(opt: PartialOption, w_n: float,
                      params: SystemParams, row: int) -> float:
    """Congestion-FREE total partial cost (each fragment alone on its edge:
    c_e / F_e). Lower-bounds the realized partial cost; used for modeled
    latency and for the R-QAD slack correction in :mod:`repro.core.bnb`."""
    F = params.F[np.asarray(opt.edges, dtype=np.int64)]
    return float((np.asarray(opt.cycles, dtype=np.float64) / F).sum()
                 + partial_fixed_cost(opt, w_n, params, row))


def total_cost(D: np.ndarray, f: np.ndarray, tasks: QueryTasks,
               params: SystemParams) -> float:
    """Eq. (5) evaluated for explicit (D, F). D, f: [N, K]."""
    De = D * tasks.e
    on_edge = De.sum(axis=1)  # 0 or 1 per user
    edge_comp = np.where(De > 0, tasks.c[:, None] / np.maximum(f, 1e-30), 0.0)
    with np.errstate(divide="ignore"):
        edge_tx = np.where(De > 0,
                           tasks.w[:, None] / np.maximum(params.r_edge, 1e-30),
                           0.0)
    cloud = (1.0 - on_edge) * cloud_unit_cost(tasks, params)
    return float((De * (edge_comp + edge_tx)).sum() + cloud.sum())


def assignment_cost(D: np.ndarray, tasks: QueryTasks,
                    params: SystemParams) -> float:
    """Eq. (14): exact cost of an integral assignment with optimal CRA."""
    from .cra import allocate_closed_form, o_total_calc
    De = (D * tasks.e).astype(np.float64)
    o_calc = o_total_calc(De, tasks.c, params.F)
    with np.errstate(divide="ignore"):
        edge_tx = np.where(De > 0,
                           tasks.w[:, None] / np.maximum(params.r_edge, 1e-30),
                           0.0).sum()
    cloud = ((1.0 - De.sum(axis=1)) * cloud_unit_cost(tasks, params)).sum()
    return float(o_calc + edge_tx + cloud)


def decisions_cost(decisions: np.ndarray, tasks: QueryTasks,
                   params: SystemParams) -> float:
    """Exact generalized-Eq.-5 cost of a per-query decision vector.

    ``decisions``: [N] ints — edge id in [0, K), -1 for cloud, or K for the
    query's partial option (requires ``tasks.partial[n]``). Edge-assigned
    queries AND partial fragments share each edge's CRA pool (Eq. 13):
    the pool's sqrt-cycles sum S_k prices compute as Σ_k S_k²/F_k.
    """
    K = params.K
    S = np.zeros(K)
    tx = 0.0
    sq = np.sqrt(np.maximum(tasks.c, 0.0))
    cloud = cloud_unit_cost(tasks, params)
    for n, ch in enumerate(np.asarray(decisions, dtype=np.int64)):
        if ch == K:
            opt = tasks.partial_option(int(n))
            if opt is None:
                raise ValueError(f"row {n}: partial decision without option")
            eids = np.asarray(opt.edges, dtype=np.int64)
            S[eids] += np.sqrt(np.maximum(
                np.asarray(opt.cycles, dtype=np.float64), 0.0))
            tx += partial_fixed_cost(opt, float(tasks.w[n]), params, int(n))
        elif ch >= 0:
            S[ch] += sq[n]
            tx += float(tasks.w[n] / params.r_edge[n, ch])
        else:
            tx += float(cloud[n])
    return float((S ** 2 / params.F).sum() + tx)


# ---------------------------------------------------------------------------
# query cost estimation (paper adopts selectivity estimators [29, 41])
# ---------------------------------------------------------------------------

CYCLES_PER_ROW = 220.0       # calibration constant: join work per binding row
CYCLES_BASE = 5e4            # fixed per-query overhead (parse, plan)
BITS_PER_CELL = 64.0
BITS_PER_BYTE = 8
# realized-latency calibration: measured engine wall (prescan + join phases)
# -> cost-model cycles. The reference machine the row-count calibration
# above was fit on runs ~1e9 model-cycles of matcher work per wall second,
# so a measured second of engine time prices the same as ~4.5M result rows.
CYCLES_PER_ENGINE_SECOND = 1.0e9


def measured_cycles(n_rows: int, engine_seconds: float = 0.0) -> float:
    """Realized c_n: cost-model cycles from MEASURED execution evidence.

    When per-phase engine wall is available (``ExecutionRecord.
    engine_seconds`` / ``PartialExecution.per_server_seconds`` — the
    prescan+join seconds the engine actually spent on this work), cycles
    derive from it directly, floored only at the fixed per-query overhead.
    Final row counts alone misprice compute in both directions: they
    undercount intermediate join work (a selective query over a huge graph
    can burn seconds and return 3 rows) and overcharge work that never
    re-ran (a partial plan's cloud ASSEMBLY joins two shipped binding
    tables, yet the final row count prices it like a from-scratch
    evaluation) — the ROADMAP partial-eval follow-on (c) fidelity gap. The
    row-count calibration remains the fallback for records with no phase
    measurement (``engine_seconds == 0``).
    """
    if engine_seconds > 0.0:
        return float(max(CYCLES_BASE,
                         engine_seconds * CYCLES_PER_ENGINE_SECOND))
    return float(CYCLES_BASE + CYCLES_PER_ROW * max(n_rows, 1))


def result_bits(res, projection: list[str]) -> float:
    """w_n in *bits* from a :class:`~repro.sparql.matcher.MatchResult`.

    The single source of the bytes->bits unit conversion for result-size
    accounting — every ``ExecutionRecord.result_bits`` and measured ``w_n``
    goes through here (Eq. 5 divides w_n by link rates in bits/s).
    """
    return float(res.result_bytes(projection) * BITS_PER_BYTE)


def estimate_query_cost(store: RDFStore, q,
                        ) -> tuple[float, float]:
    """(c_n cycles, w_n bits) via join-order cardinality simulation.

    Follows Stocker et al. [WWW'08]-style selectivity composition: walk the
    greedy join order, multiplying in per-pattern selectivities; c_n sums the
    estimated intermediate sizes (work), w_n is the final estimate (result).

    ``q`` is a plain :class:`QueryGraph` or a compiled algebra plan
    (:class:`repro.sparql.algebra.Node`): a plan costs the sum of its BGP
    leaves' work c_n (every leaf executes) and estimates w_n structurally
    — UNION **sums** its branches (concatenation grows the result), while
    join/filter/modifier operators take the largest input (they only
    combine or drop rows of their inputs).
    """
    leaves = getattr(q, "bgp_leaves", None)
    if leaves is not None:
        from ..sparql.algebra import BGPNode, UnionNode
        work = 0.0

        def est_w(node) -> float:
            nonlocal work
            if isinstance(node, BGPNode):
                if not node.query.patterns:
                    return float(BITS_PER_CELL)
                c_i, w_i = estimate_query_cost(store, node.query)
                work += c_i - CYCLES_BASE
                return w_i
            kids = [est_w(c) for c in node.children()]
            if not kids:
                return float(BITS_PER_CELL)
            return float(sum(kids) if isinstance(node, UnionNode)
                         else max(kids))
        w = est_w(q)
        return float(CYCLES_BASE + work), max(w, float(BITS_PER_CELL))
    from ..sparql.matcher import _order_patterns  # same plan as execution
    order = _order_patterns(store, q)
    bound: set[str] = set()
    rows = 1.0
    work = 0.0
    for i in order:
        tp = q.patterns[i]
        card = max(estimate_pattern_cardinality(store, tp), 1e-3)
        # classic independent-join estimate: each shared variable divides by
        # the distinct-value count of the position it occupies in tp
        denom = 1.0
        if isinstance(tp.p, int):
            ds = max(1.0, float(store.pred_distinct_s[tp.p]))
            do = max(1.0, float(store.pred_distinct_o[tp.p]))
        else:
            ds = do = max(1.0, float(store.num_entities) ** 0.5)
        if isinstance(tp.s, str) and tp.s in bound:
            denom *= ds
        if isinstance(tp.o, str) and tp.o in bound:
            denom *= do
        if isinstance(tp.p, str) and tp.p in bound:
            denom *= max(1.0, float(store.num_predicates))
        rows = rows * card / denom
        rows = max(rows, 0.0)
        work += rows
        bound.update(tp.variables())
    n_proj = max(1, len(q.projection) if q.projection else len(q.variables))
    c = CYCLES_BASE + CYCLES_PER_ROW * work
    w = max(BITS_PER_CELL, rows * n_proj * BITS_PER_CELL)
    return float(c), float(w)


def measured_query_cost(store: RDFStore, q: QueryGraph,
                        engine=None) -> tuple[float, float, int]:
    """(c_n cycles-equivalent, w_n bits, n_matches) by actually executing.

    ``engine``: optional :class:`repro.sparql.engine.QueryEngine` — routes
    execution through its backend and result cache, so repeated measurement
    of a hot query (re-costing between scheduling rounds) is a cache hit.
    ``q`` may be a plain :class:`QueryGraph` or a compiled algebra plan
    (the latter requires an engine).
    """
    if engine is not None:
        from ..sparql.algebra import execute_any_batch
        res = execute_any_batch(store, engine, [q])[0]
    else:
        from ..sparql.algebra import is_algebra_plan
        if is_algebra_plan(q):
            raise ValueError("measuring an algebra plan needs an engine")
        from ..sparql.matcher import match_bgp
        res = match_bgp(store, q)
    n_rows = res.num_matches
    c = CYCLES_BASE + CYCLES_PER_ROW * max(n_rows, 1)
    # unit check: 64-bit binding cells == 8 bytes/cell; w_n must be bits
    assert BITS_PER_CELL == BITS_PER_BYTE * np.dtype(np.int64).itemsize
    w = result_bits(res, q.projection)
    return float(c), w, n_rows


def measured_query_cost_batch(store: RDFStore, queries: list[QueryGraph],
                              engine) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """Vectorized measured costs ([N] c, [N] w, [N] n_matches) for a batch.

    One ``engine.execute_batch`` call: identical candidate scans across the
    batch run once and alpha-equivalent queries share cached results, which
    is what makes measured (rather than estimated) costs affordable as a
    scheduler input at serving scale. Mixed BGP/algebra batches are
    supported — every algebra plan's BGP leaves join the same batch.
    """
    from ..sparql.algebra import execute_any_batch
    results = execute_any_batch(store, engine, queries)
    n = np.array([r.num_matches for r in results], dtype=np.int64)
    c = CYCLES_BASE + CYCLES_PER_ROW * np.maximum(n, 1).astype(np.float64)
    w = np.array([result_bits(r, q.projection)
                  for q, r in zip(queries, results)], dtype=np.float64)
    return c, w, n
