"""Shared dispatch helper for overlapped batch execution.

Used by :meth:`repro.edge.system.EdgeCloudSystem.run_round_batched`
(thread mode) and :meth:`repro.runtime.serving.OffloadServingPool.admit`
so the worker-count heuristic lives in one place.
"""

from __future__ import annotations

from typing import Callable, Iterable


def thread_map(fn: Callable, items: Iterable,
               max_workers: int | None = None) -> list:
    """``[fn(it) for it in items]`` through a thread pool.

    Single-item (or empty) inputs run inline. Worker count defaults to
    ``min(len(items), cpu_count + 1)`` — oversubscribing cores serializes
    on the GIL instead of overlapping, while one extra worker packs uneven
    loads best.
    """
    items = list(items)
    if len(items) <= 1:
        return [fn(it) for it in items]
    import os
    from concurrent.futures import ThreadPoolExecutor

    workers = max_workers or min(len(items), (os.cpu_count() or 2) + 1)
    with ThreadPoolExecutor(max_workers=workers) as pool:
        return list(pool.map(fn, items))
