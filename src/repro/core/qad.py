"""R-QAD: convex relaxation of the query-assignment problem, in JAX.

Paper §4.4 relaxes D ∈ {0,1} to [0,1] (Eq. 16) and solves the resulting
convex program with Gurobi. Here the solver is accelerator-native:

- projected gradient with Nesterov acceleration, fully ``jit``-compiled;
- the feasible set  {d ∈ [0,1]^K : Σ_{k: e_nk=1} d_k ≤ 1}  is handled by an
  exact per-row projection (bisection on the simplex dual variable),
  vectorized over all rows;
- a **certified lower bound** is returned via the Frank-Wolfe duality gap:
  for convex f and any feasible x,  min f ≥ f(x) + min_{y∈C} ∇f(x)·(y−x),
  and the linear minimum over C is available in closed form (per row: either
  0 or the single most negative gradient coordinate). B&B pruning therefore
  never relies on the iterative solver having fully converged.
- ``solve_rqad_batch`` evaluates a whole branch-and-bound frontier in one
  vmapped call (beyond-paper optimization; see EXPERIMENTS.md §Perf-sched).

Objective (constant cloud term excluded; callers add it):

    f(D) = Σ_k (Σ_n D_nk A_nk)² / F_k + Σ_nk D_nk b_nk
    A_nk = e_nk √c_n ,   b_nk = e_nk (w_n/r^{n,k} − w_n/r^{n,c})
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def build_qad_arrays(c: np.ndarray, w: np.ndarray, e: np.ndarray,
                     r_edge: np.ndarray, r_cloud: np.ndarray,
                     cloud_compute: np.ndarray | None = None,
                     ) -> tuple[np.ndarray, np.ndarray, float]:
    """(A, b, const) for the objective above. Arrays are [N, K].

    ``cloud_compute``: optional [N] per-query cloud compute cost c_n/F_cloud
    (the generalized Eq. 5); it joins the per-row cloud cost both in the
    relative edge gains ``b`` and in the constant term."""
    cloud = w / r_cloud
    if cloud_compute is not None:
        cloud = cloud + np.asarray(cloud_compute, dtype=np.float64)
    A = e * np.sqrt(np.maximum(c, 0.0))[:, None]
    with np.errstate(divide="ignore"):
        edge_tx = np.where(e > 0, w[:, None] / np.maximum(r_edge, 1e-30), 0.0)
    b = e * (edge_tx - cloud[:, None])
    const = float(cloud.sum())
    return A.astype(np.float64), b.astype(np.float64), const


def partial_lb_slack(cloud_cost: np.ndarray,
                     partial_free_cost: np.ndarray) -> float:
    """Certified correction keeping the R-QAD lower bound sound when rows
    carry a partial-evaluation option the relaxation cannot represent.

    The relaxation prices a non-edge row at its cloud cost; a row actually
    taking its partial plan pays at least its congestion-free partial cost
    (edge compute alone-on-the-edge + fixed backhaul/assembly/delivery,
    since (S² − (S−s)²)/F ≥ s²/F = c/F). Subtracting
    ``Σ_n max(0, cloud_n − partial_free_n)`` therefore lower-bounds every
    completion that swaps any subset of rows from cloud to partial. Rows
    without the option carry ``partial_free_cost = inf`` and contribute 0.
    """
    return float(np.maximum(0.0, cloud_cost - partial_free_cost).sum())


def _project_rows(x: jnp.ndarray, e: jnp.ndarray,
                  n_bisect: int = 40) -> jnp.ndarray:
    """Project rows of x onto {d ∈ [0,1]^K : Σ_{k:e=1} d_k ≤ 1}."""
    x = jnp.where(e > 0, x, 0.0)
    y = jnp.clip(x, 0.0, 1.0)
    s = y.sum(axis=-1)
    lo = jnp.zeros(x.shape[:-1], x.dtype)
    hi = jnp.maximum(jnp.max(x, axis=-1), 0.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        val = jnp.clip(x - mid[..., None], 0.0, 1.0).sum(axis=-1)
        gt = val > 1.0
        return jnp.where(gt, mid, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    z = jnp.clip(x - hi[..., None], 0.0, 1.0)
    return jnp.where((s <= 1.0)[..., None], y, z)


def _objective(D_eff: jnp.ndarray, A: jnp.ndarray, b: jnp.ndarray,
               F: jnp.ndarray) -> jnp.ndarray:
    S = (D_eff * A).sum(axis=0)
    return (S * S / F).sum() + (D_eff * b).sum()


@partial(jax.jit, static_argnames=("iters",))
def solve_rqad(A: jnp.ndarray, b: jnp.ndarray, F: jnp.ndarray,
               e: jnp.ndarray, fixed_mask: jnp.ndarray,
               fixed_D: jnp.ndarray, iters: int = 300,
               ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Minimize f over free rows; fixed rows are pinned to ``fixed_D``.

    Returns (D_relaxed [N,K], objective value, certified lower bound) —
    both values EXCLUDE the constant cloud term.
    """
    free = (1.0 - fixed_mask)[:, None] * e          # [N,K] optimizable coords

    def eff(x):
        return jnp.where(fixed_mask[:, None] > 0, fixed_D, x * free)

    def grad(x):
        D_eff = eff(x)
        S = (D_eff * A).sum(axis=0)
        return (2.0 * A * (S / F)[None, :] + b) * free

    # Lipschitz bound for the quadratic part over the free subspace
    L = 2.0 * jnp.max((A * A).sum(axis=0) / F) + 1e-12
    step = 1.0 / L

    x0 = _project_rows(jnp.full_like(A, 0.5) * free, e) * free

    def body(t, carry):
        x, x_prev = carry
        beta = t / (t + 3.0)
        y = x + beta * (x - x_prev)
        x_new = _project_rows(y - step * grad(y), e) * free
        return x_new, x

    x, _ = jax.lax.fori_loop(0, iters, body, (x0, x0))
    x = _project_rows(x, e) * free
    D_eff = eff(x)
    f_val = _objective(D_eff, A, b, F)

    # Frank-Wolfe certificate: f* >= f(x) + min_{y in C} g·(y - x)
    g = grad(x)
    g_masked = jnp.where(free > 0, g, jnp.inf)
    row_min = jnp.min(g_masked, axis=1)             # best single coordinate
    row_lin_min = jnp.minimum(row_min, 0.0)         # or the origin
    row_lin_min = jnp.where(jnp.isfinite(row_lin_min), row_lin_min, 0.0)
    gap = (row_lin_min - (g * x).sum(axis=1)) * (1.0 - fixed_mask)
    lb = f_val + gap.sum()
    return D_eff, f_val, lb


# One relaxation per child node of a B&B branching step, in a single call.
solve_rqad_batch = jax.jit(
    jax.vmap(solve_rqad, in_axes=(None, None, None, None, None, 0, None)),
    static_argnames=("iters",))


def round_relaxed(D_relaxed: np.ndarray, e: np.ndarray) -> np.ndarray:
    """Eq. (17) rounding, kept feasible: at most one 1 per row (argmax wins
    when several coordinates tie at >= 0.5, which the simplex constraint
    otherwise forbids only strictly)."""
    D = np.asarray(D_relaxed)
    out = np.zeros_like(D)
    best = D.argmax(axis=1)
    take = D[np.arange(D.shape[0]), best] >= 0.5
    rows = np.arange(D.shape[0])[take]
    out[rows, best[take]] = 1.0
    return out * (e > 0)
