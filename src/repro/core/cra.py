"""Computational Resource Allocation — closed-form KKT optimum (paper §4.2).

For a fixed feasible assignment D, minimizing the total compute term
``Σ_k Σ_{n∈N_k} c_n / f_{n,k}`` subject to C3/C4 is convex; stationarity of
the Lagrangian gives the water-filling-like solution

    f*_{n,k} = F_k · sqrt(c_n) / Σ_{m∈N_k} sqrt(c_m)            (Eq. 12)
    O*_calc  = Σ_k ( Σ_{n∈N_k} sqrt(c_n) )² / F_k               (Eq. 13)
"""

from __future__ import annotations

import numpy as np


def allocate_closed_form(De: np.ndarray, c: np.ndarray,
                         F: np.ndarray) -> np.ndarray:
    """Eq. (12). ``De``: [N, K] effective assignment (D*e), c: [N], F: [K].

    Returns f: [N, K] with zeros where De == 0.
    """
    sq = np.sqrt(np.maximum(c, 0.0))[:, None] * (De > 0)
    col = sq.sum(axis=0)                      # Σ_{m∈N_k} sqrt(c_m)
    with np.errstate(invalid="ignore", divide="ignore"):
        f = np.where(col[None, :] > 0, F[None, :] * sq / col[None, :], 0.0)
    return f


def o_total_calc(De: np.ndarray, c: np.ndarray, F: np.ndarray) -> float:
    """Eq. (13): optimal total compute cost for assignment De."""
    sq = np.sqrt(np.maximum(c, 0.0))[:, None] * (De > 0)
    col = sq.sum(axis=0)
    return float((col ** 2 / F).sum())
