"""Unified scheduler facade: one entry point for all assignment policies.

``schedule(tasks, params, policy=...)`` returns (D, f, objective, info).
Policies: "bnb" (the paper's method), plus the four §5.1 baselines.

This facade is also what the model-serving runtime uses to place inference
requests across replica pools (see repro.runtime.serving) — the paper's
scheduler as a first-class framework feature.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from .baselines import BASELINES
from .bnb import BnBResult, branch_and_bound
from .cost import QueryTasks, SystemParams, assignment_cost
from .cra import allocate_closed_form


@dataclass
class ScheduleResult:
    D: np.ndarray
    f: np.ndarray
    objective: float
    policy: str
    info: dict[str, Any]
    partial: np.ndarray | None = None   # [N] bool: row takes its partial plan


def schedule(tasks: QueryTasks, params: SystemParams, policy: str = "bnb",
             **kw) -> ScheduleResult:
    if policy == "bnb":
        r: BnBResult = branch_and_bound(tasks, params, **kw)
        return ScheduleResult(D=r.D, f=r.f, objective=r.objective,
                              policy=policy,
                              info={"nodes_explored": r.nodes_explored,
                                    "nodes_pruned": r.nodes_pruned,
                                    "solve_seconds": r.solve_seconds,
                                    "optimal": r.optimal},
                              partial=r.partial)
    if policy in BASELINES:
        D = BASELINES[policy](tasks, params, **kw)
        De = D * tasks.e * params.assoc
        f = allocate_closed_form(De, tasks.c, params.F)
        return ScheduleResult(D=D, f=f,
                              objective=assignment_cost(D, tasks, params),
                              policy=policy, info={})
    raise ValueError(f"unknown policy {policy!r}; options: bnb, "
                     + ", ".join(BASELINES))
