"""Pattern selection + dynamic placement for edge servers (paper §3.2).

Storage-aware selection: choosing which pattern-induced subgraphs an edge
server hosts is a knapsack (benefit = access frequency, cost = subgraph
bytes); the paper uses a lightweight greedy heuristic — benefit/cost ratio
with a frequency tiebreak.

Dynamic update: the system tracks per-pattern access frequencies; patterns
hot in the cloud but absent at an edge are added, cold ones evicted, as an
asynchronous background task (here: an explicit ``rebalance()`` the driver
calls between scheduling rounds, keeping query latency unaffected).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .pattern import Pattern


@dataclass
class PatternProfile:
    pattern: Pattern
    frequency: float          # accesses (decayed)
    size_bytes: int           # |G[{p}]| storage cost


def greedy_knapsack(profiles: list[PatternProfile],
                    budget_bytes: int) -> list[int]:
    """Indices of selected patterns under the budget (benefit/cost greedy)."""
    order = sorted(
        range(len(profiles)),
        key=lambda i: (-(profiles[i].frequency
                         / max(1, profiles[i].size_bytes)),
                       -profiles[i].frequency, i))
    chosen: list[int] = []
    used = 0
    for i in order:
        sz = profiles[i].size_bytes
        if used + sz <= budget_bytes:
            chosen.append(i)
            used += sz
    return sorted(chosen)


@dataclass
class DynamicPlacement:
    """Frequency-tracking placement policy for one edge server."""

    budget_bytes: int
    decay: float = 0.9                  # per-round exponential decay
    freq: dict[tuple, float] = field(default_factory=dict)
    sizes: dict[tuple, int] = field(default_factory=dict)
    patterns: dict[tuple, Pattern] = field(default_factory=dict)
    resident: set[tuple] = field(default_factory=set)

    def observe(self, p: Pattern, count: float = 1.0) -> None:
        """Record accesses for a pattern (edge- or cloud-served)."""
        if not p.indexable:
            return
        k = p.key
        self.freq[k] = self.freq.get(k, 0.0) + count
        self.patterns.setdefault(k, p)

    def set_size(self, p: Pattern, size_bytes: int) -> None:
        self.sizes[p.key] = int(size_bytes)

    def decay_round(self) -> None:
        for k in list(self.freq):
            self.freq[k] *= self.decay

    def rebalance(self) -> tuple[list[Pattern], list[Pattern]]:
        """Recompute residency; returns (added, evicted) patterns.

        Patterns without a measured size are skipped (size is measured by the
        server when it first materializes G[{p}]).
        """
        known = [k for k in self.freq if k in self.sizes]
        profiles = [PatternProfile(self.patterns[k], self.freq[k],
                                   self.sizes[k]) for k in known]
        chosen = set(known[i] for i in greedy_knapsack(
            profiles, self.budget_bytes))
        added = [self.patterns[k] for k in chosen - self.resident]
        evicted = [self.patterns[k] for k in self.resident - chosen]
        self.resident = chosen
        return added, evicted

    def used_bytes(self) -> int:
        return sum(self.sizes.get(k, 0) for k in self.resident)
