"""Pattern selection + dynamic placement for edge servers (paper §3.2).

Storage-aware selection: choosing which pattern-induced subgraphs an edge
server hosts is a knapsack (benefit = access frequency, cost = subgraph
bytes); the paper uses a lightweight greedy heuristic — benefit/cost ratio
with a frequency tiebreak.

**Per-shard budgets.** On a sharded deployment the binding constraint is
often a single shard's device buffer, not the server total: a pattern whose
induced triples all hash to one shard can blow that shard's capacity while
the server as a whole has room. :func:`greedy_knapsack` therefore accepts
an optional per-shard budget vector next to the total; a
:class:`PatternProfile` carries its per-shard byte split
(``shard_bytes``), and a candidate is admitted only if it fits the total
AND every shard it touches. Per-shard footprints are additive
approximations (overlapping patterns share triples), matching the existing
total-bytes accounting.

Dynamic update: the system tracks per-pattern access frequencies; patterns
hot in the cloud but absent at an edge are added, cold ones evicted.
:meth:`DynamicPlacement.plan` computes the new residency WITHOUT mutating
state — the asynchronous rebalance pipeline
(:class:`repro.edge.rebalance.RebalanceManager`) plans and computes deltas
off the query path, then commits residency atomically at an epoch barrier.
``rebalance()`` (plan + commit in one step) remains for synchronous
callers. ``hysteresis`` damps add/evict flapping: a currently-resident
pattern's frequency is scored with a ``(1 + hysteresis)`` bonus, so a
challenger must beat the incumbent by a margin before triggering an
eviction/re-ship cycle.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .pattern import Pattern


@dataclass
class PatternProfile:
    pattern: Pattern
    frequency: float          # accesses (decayed)
    size_bytes: int           # |G[{p}]| storage cost
    shard_bytes: dict[int, int] | None = None  # per-shard byte split


def greedy_knapsack(profiles: list[PatternProfile], budget_bytes: int,
                    shard_budgets=None) -> list[int]:
    """Indices of selected patterns under the budget (benefit/cost greedy).

    ``shard_budgets`` (optional) is indexable by shard id (array or dict);
    when given, a profile with ``shard_bytes`` is admitted only if every
    shard it touches stays within its budget. Profiles without a per-shard
    split are checked against the total only.
    """
    order = sorted(
        range(len(profiles)),
        key=lambda i: (-(profiles[i].frequency
                         / max(1, profiles[i].size_bytes)),
                       -profiles[i].frequency, i))
    chosen: list[int] = []
    used = 0
    used_shard: dict[int, int] = {}
    for i in order:
        sz = profiles[i].size_bytes
        if used + sz > budget_bytes:
            continue
        sb = profiles[i].shard_bytes
        if shard_budgets is not None and sb:
            if any(used_shard.get(k, 0) + b > shard_budgets[k]
                   for k, b in sb.items()):
                continue
            for k, b in sb.items():
                used_shard[k] = used_shard.get(k, 0) + b
        chosen.append(i)
        used += sz
    return sorted(chosen)


@dataclass
class DynamicPlacement:
    """Frequency-tracking placement policy for one edge server."""

    budget_bytes: int
    decay: float = 0.9                  # per-round exponential decay
    hysteresis: float = 0.0             # resident-pattern score bonus
    shard_budgets: np.ndarray | None = None   # per-shard byte budgets
    freq: dict[tuple, float] = field(default_factory=dict)
    sizes: dict[tuple, int] = field(default_factory=dict)
    shard_sizes: dict[tuple, dict[int, int]] = field(default_factory=dict)
    patterns: dict[tuple, Pattern] = field(default_factory=dict)
    resident: set[tuple] = field(default_factory=set)

    def observe(self, p: Pattern, count: float = 1.0) -> None:
        """Record accesses for a pattern (edge- or cloud-served)."""
        if not p.indexable:
            return
        k = p.key
        self.freq[k] = self.freq.get(k, 0.0) + count
        self.patterns.setdefault(k, p)

    def set_size(self, p: Pattern, size_bytes: int,
                 shard_bytes: dict[int, int] | None = None) -> None:
        self.sizes[p.key] = int(size_bytes)
        if shard_bytes is not None:
            self.shard_sizes[p.key] = {int(k): int(v)
                                       for k, v in shard_bytes.items()}

    def decay_round(self) -> None:
        for k in list(self.freq):
            self.freq[k] *= self.decay

    def plan(self) -> tuple[set[tuple], set[tuple], set[tuple]]:
        """Compute the target residency WITHOUT mutating state.

        Returns ``(chosen, added, evicted)`` key sets. Patterns without a
        measured size are skipped (size is measured by the server when it
        first materializes G[{p}]). Currently-resident patterns score with
        the ``hysteresis`` bonus (see module docstring).
        """
        # snapshot first: plan() may run on the rebalance thread while a
        # concurrent round observes new patterns (freq inserts are benign —
        # they surface next epoch — but iteration must not race them)
        snap = list(self.freq.items())
        known = [k for k, _ in snap if k in self.sizes]
        freq = dict(snap)
        boost = 1.0 + max(0.0, self.hysteresis)
        profiles = [PatternProfile(
            self.patterns[k],
            freq[k] * (boost if k in self.resident else 1.0),
            self.sizes[k], self.shard_sizes.get(k)) for k in known]
        chosen = set(known[i] for i in greedy_knapsack(
            profiles, self.budget_bytes, self.shard_budgets))
        return chosen, chosen - self.resident, self.resident - chosen

    def rebalance(self) -> tuple[list[Pattern], list[Pattern]]:
        """Plan + commit residency; returns (added, evicted) patterns."""
        chosen, add, ev = self.plan()
        self.resident = chosen
        return ([self.patterns[k] for k in add],
                [self.patterns[k] for k in ev])

    def used_bytes(self) -> int:
        return sum(self.sizes.get(k, 0) for k in self.resident)

    def used_shard_bytes(self) -> dict[int, int]:
        """Additive per-shard usage of the current residency."""
        out: dict[int, int] = {}
        for k in self.resident:
            for sid, b in self.shard_sizes.get(k, {}).items():
                out[sid] = out.get(sid, 0) + b
        return out
