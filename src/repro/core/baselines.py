"""Scheduling baselines (paper §5.1).

- Cloud-Only : every query to the cloud.
- Random     : uniform choice among {cloud} ∪ feasible edges.
- Edge-First : any feasible edge wins (fastest link picked); no resource
               allocation awareness.
- Greedy     : sequentially place each query where its *marginal* cost
               (with CRA-optimal reallocation) is lowest.
"""

from __future__ import annotations

import numpy as np

from .cost import QueryTasks, SystemParams


def cloud_only(tasks: QueryTasks, params: SystemParams) -> np.ndarray:
    return np.zeros((tasks.N, params.K))


def random_assign(tasks: QueryTasks, params: SystemParams,
                  seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    e = tasks.e * params.assoc
    D = np.zeros((tasks.N, params.K))
    for n in range(tasks.N):
        feas = np.flatnonzero(e[n] > 0)
        pick = int(rng.integers(len(feas) + 1))  # 0 == cloud
        if pick > 0:
            D[n, feas[pick - 1]] = 1.0
    return D


def edge_first(tasks: QueryTasks, params: SystemParams) -> np.ndarray:
    e = tasks.e * params.assoc
    D = np.zeros((tasks.N, params.K))
    for n in range(tasks.N):
        feas = np.flatnonzero(e[n] > 0)
        if len(feas):
            D[n, feas[np.argmax(params.r_edge[n, feas])]] = 1.0
    return D


def greedy_assign(tasks: QueryTasks, params: SystemParams) -> np.ndarray:
    """Marginal-cost greedy with incremental Eq. (13) updates, O(N·K).

    Placing user n on edge k changes the objective by
        Δ = ((S_k + √c_n)² − S_k²)/F_k + w_n/r^{n,k} − w_n/r^{n,c}
    where S_k is the current √c load of edge k; Δ_cloud = 0.
    """
    e = tasks.e * params.assoc
    D = np.zeros((tasks.N, params.K))
    S = np.zeros(params.K)
    sq = np.sqrt(np.maximum(tasks.c, 0.0))
    for n in range(tasks.N):
        feas = np.flatnonzero(e[n] > 0)
        if not len(feas):
            continue
        delta = ((S[feas] + sq[n]) ** 2 - S[feas] ** 2) / params.F[feas]
        delta += tasks.w[n] / params.r_edge[n, feas]
        delta -= tasks.w[n] / params.r_cloud[n]
        j = int(np.argmin(delta))
        if delta[j] < 0.0:
            k = feas[j]
            D[n, k] = 1.0
            S[k] += sq[n]
    return D


BASELINES = {
    "cloud_only": cloud_only,
    "random": random_assign,
    "edge_first": edge_first,
    "greedy": greedy_assign,
}
