"""Edge and cloud servers.

An edge server hosts pattern-induced subgraphs for a resident pattern set
(selected under its storage budget — total bytes plus optional per-shard
budgets on sharded deployments) plus the hash-code pattern index used for
O(1) executability checks. The cloud hosts the full graph.

Both execute queries with the same vectorized matcher — the paper's
completeness guarantee (matches over G[P] == matches over G for queries
isomorphic to a resident pattern) is what makes edge execution correct, and
is asserted in tests/test_edge_system.py.

Residency is tracked in **cloud-global edge ids** (``resident_eids``), the
id-stable coordinate system across placement changes: per-pattern induced
edge ids come from a shared, memoized
:class:`repro.core.induced.InducedIndex` (keyed ``(cloud version, pattern
key)``, so unchanged patterns cost zero matcher calls), and a residency
change is committed either as a :class:`repro.rdf.deltas.TripleDelta`
applied to the edge store *in place* (shipping only the diff) or as a full
``subgraph`` rebuild. :meth:`EdgeServer.commit_residency` updates the store
and republishes the pattern index together — callers serialize commits
against query rounds (the epoch barrier in
:class:`repro.edge.system.EdgeCloudSystem`), so the scheduler's
feasibility matrix can never observe a half-applied placement.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.cost import result_bits
from ..core.induced import InducedIndex
from ..core.pattern import Pattern, PatternIndex
from ..core.placement import DynamicPlacement
from ..rdf.deltas import (ADD_WIRE_BYTES, TripleDelta, delta_between,
                          rows_at)
from ..rdf.graph import RDFStore, triples_size_bytes
from ..sparql.algebra import execute_any_batch
from ..sparql.engine import QueryEngine
from ..sparql.matcher import MatchResult


@dataclass
class ExecutionRecord:
    n_matches: int
    wall_seconds: float
    result_bits: float
    # per-phase engine wall (prescan + join seconds) attributable to this
    # query — the realized-latency cost model derives measured cloud cycles
    # from it instead of final row counts alone (see
    # :func:`repro.core.cost.measured_cycles`); 0.0 when unavailable
    engine_seconds: float = 0.0

    @classmethod
    def of(cls, res: MatchResult, projection: list[str],
           wall_seconds: float,
           engine_seconds: float = 0.0) -> "ExecutionRecord":
        """Build from a match result; ``result_bits`` goes through the
        single-sourced :func:`repro.core.cost.result_bits` conversion."""
        return cls(n_matches=res.num_matches, wall_seconds=wall_seconds,
                   result_bits=result_bits(res, projection),
                   engine_seconds=engine_seconds)


def _execute_batch(store: RDFStore, engine: QueryEngine,
                   queries: list,
                   ) -> list[tuple[MatchResult, ExecutionRecord]]:
    """Run one server's batch through the engine; wall time is apportioned
    evenly across the batch (scans/cache are shared, so per-query isolation
    is not measurable — Eq. 5 accounting only needs the total).

    ``queries`` may mix plain :class:`QueryGraph`\\ s and compiled algebra
    plans (:mod:`repro.sparql.algebra`): all BGP leaves share ONE engine
    batch, and an algebra result is a
    :class:`~repro.sparql.algebra.SolutionTable` (same cost-accounting
    surface as :class:`MatchResult`)."""
    s = engine.stats
    e0 = s.prescan_seconds + s.join_seconds
    t0 = time.perf_counter()
    results = execute_any_batch(store, engine, queries)
    wall = time.perf_counter() - t0
    # per-phase engine seconds this batch spent scanning + joining. The
    # stats object is shared across overlapped server batches, so the delta
    # is clamped to this batch's own wall before apportioning — a
    # concurrent thread's phase time can inflate the counter but never
    # charge more than the time that actually elapsed here.
    # the 1ns floor marks "measured (served from cache, essentially
    # free)" as distinct from "not measured" for measured_cycles
    eng = max(min(s.prescan_seconds + s.join_seconds - e0, wall), 1e-9)
    per_q = wall / max(1, len(queries))
    per_e = eng / max(1, len(queries))
    return [(res, ExecutionRecord.of(res, list(q.projection), per_q, per_e))
            for q, res in zip(queries, results)]


class CloudServer:
    """Holds the complete RDF graph G — monolithic or sharded
    (any :class:`RDFStore`)."""

    def __init__(self, store: RDFStore,
                 engine: QueryEngine | None = None) -> None:
        self.store = store
        self.engine = engine or QueryEngine()

    def execute(self, q) -> tuple[MatchResult, ExecutionRecord]:
        return _execute_batch(self.store, self.engine, [q])[0]

    def execute_batch(self, queries: list,
                      ) -> list[tuple[MatchResult, ExecutionRecord]]:
        return _execute_batch(self.store, self.engine, queries)


class EdgeServer:
    """Stores pattern-induced subgraphs G[P] + the pattern index."""

    def __init__(self, server_id: int, storage_budget_bytes: int,
                 compute_cycles_per_s: float,
                 engine: QueryEngine | None = None,
                 shard_budgets=None,
                 induced: InducedIndex | None = None) -> None:
        self.server_id = server_id
        self.budget = int(storage_budget_bytes)
        self.F = float(compute_cycles_per_s)
        self.engine = engine or QueryEngine()
        self.placement = DynamicPlacement(budget_bytes=self.budget,
                                          shard_budgets=shard_budgets)
        self.induced = induced if induced is not None else InducedIndex()
        self.index = PatternIndex()
        self.store: RDFStore | None = None
        self._resident: dict[tuple, Pattern] = {}
        # cloud-global edge ids backing ``store``, plus the cloud version
        # they were derived against: edge ids are only id-stable while the
        # cloud holds that version (the cloud itself may move through
        # apply_delta — live ingest), so both are needed to decide whether
        # residency is current and whether the cheap id-space diff is sound
        self.resident_eids: np.ndarray = np.zeros(0, dtype=np.int64)
        self.resident_cloud_version = None

    # -- deployment ---------------------------------------------------------
    def measure_pattern(self, cloud_store: RDFStore, p: Pattern) -> int:
        """Compute |G[{p}]| bytes (memoized via the shared induced index);
        records total and per-shard sizes with the placement policy."""
        eids = self.induced.edge_ids(cloud_store, p)
        nbytes = triples_size_bytes(len(eids))
        self.placement.set_size(p, nbytes,
                                self._shard_split(cloud_store, eids))
        return nbytes

    @staticmethod
    def _shard_split(cloud_store: RDFStore,
                     eids: np.ndarray) -> dict[int, int] | None:
        """Per-shard byte footprint of an induced edge set (sharded cloud
        only). Edge stores inherit the cloud's shard count and predicate
        hash through ``subgraph``/deltas, so the cloud-side split IS the
        edge-side placement footprint."""
        shards = getattr(cloud_store, "shards", None)
        if shards is None or not len(eids):
            return None
        from ..rdf.sharding import shard_of_pred
        owner = shard_of_pred(cloud_store.p[eids],
                              cloud_store.num_shards).astype(np.int64)
        counts = np.bincount(owner, minlength=cloud_store.num_shards)
        return {k: triples_size_bytes(int(c))
                for k, c in enumerate(counts) if c}

    def deploy(self, cloud_store: RDFStore,
               patterns: list[Pattern]) -> None:
        """Materialize G[P] for the given resident set (full rebuild).

        Built through the :class:`RDFStore` protocol: ``subgraph`` preserves
        the cloud store's kind, so a sharded cloud yields sharded
        pattern-induced edge stores (possibly with empty shards)."""
        resident = {p.key: p for p in patterns if p.indexable}
        eids = self.induced.union_edge_ids(cloud_store,
                                           list(resident.values()))
        self._publish(resident, eids, cloud_store.version,
                      store=cloud_store.subgraph(eids))

    def _publish(self, resident: dict[tuple, Pattern], eids: np.ndarray,
                 cloud_version, store: RDFStore | None = None) -> None:
        """Republish residency state: store (if given), pattern index, and
        placement bookkeeping — together, so executability lookups and the
        data they promise can never disagree."""
        self._resident = resident
        if store is not None:
            self.store = store
        self.resident_eids = eids
        self.resident_cloud_version = cloud_version
        self.index = PatternIndex()
        for p in resident.values():
            self.index.add(p, self.server_id)
        self.placement.resident = set(resident)

    def commit_residency(self, cloud_store: RDFStore,
                         chosen: set[tuple], target_eids: np.ndarray,
                         delta: TripleDelta | None = None) -> str:
        """Commit a planned residency (see :mod:`repro.edge.rebalance`).

        Applies ``delta`` to the live store in place when it still matches
        the store's version; otherwise falls back to a full ``subgraph``
        rebuild (first deployment, or the store moved since the delta was
        computed). Returns ``"delta"``, ``"full"``, or ``"noop"``.
        """
        resident = {k: self.placement.patterns[k] for k in chosen}
        if (delta is not None and self.store is not None
                and delta.base_version == self.store.version):
            if not delta.is_noop:
                self.store.apply_delta(delta)
            self._publish(resident, target_eids, cloud_store.version)
            return "delta" if not delta.is_noop else "noop"
        self._publish(resident, target_eids, cloud_store.version,
                      store=cloud_store.subgraph(target_eids))
        return "full"

    def plan_rebalance(self, cloud_store: RDFStore, use_delta: bool = True,
                       ) -> tuple[set, set, set, np.ndarray,
                                  TripleDelta | None, bool]:
        """Measure + plan a residency update WITHOUT committing it.

        Returns ``(chosen, added, evicted, target_eids, delta,
        needs_commit)``; the expensive parts (matching new patterns,
        diffing content) happen here, off the commit path, against a cloud
        store that is immutable while this runs (one rebalance at a time).

        ``needs_commit`` is true when the resident pattern set changed OR
        the data behind an unchanged pattern set moved: the cloud store
        itself may advance through ``apply_delta`` (live ingest), which
        both shifts the cloud id space and changes induced edge sets — so
        staleness is judged against ``resident_cloud_version`` and the
        freshly computed ``target_eids``, never against pattern add/evict
        counts alone. The cheap id-space diff is sound only while the
        cloud still holds the version residency was derived against;
        after a cloud move the content-based :func:`~repro.rdf.deltas.
        delta_between` diff is used instead (ids are not comparable
        across cloud versions, triple content always is).
        """
        for k, p in list(self.placement.patterns.items()):
            if k not in self.placement.sizes:
                self.measure_pattern(cloud_store, p)
        chosen, added, evicted = self.placement.plan()
        target_eids = self.induced.union_edge_ids(
            cloud_store, [self.placement.patterns[k] for k in chosen])
        ids_stable = cloud_store.version == self.resident_cloud_version
        needs_commit = bool(
            added or evicted or self.store is None or not ids_stable
            or not np.array_equal(target_eids, self.resident_eids))
        delta = None
        if use_delta and self.store is not None and needs_commit:
            if ids_stable:
                # id-stable diff: residency ids and target ids live in the
                # SAME cloud version's id space, and the cloud store is
                # deduplicated, so id set-difference IS row set-difference
                # — far cheaper than row-wise set algebra
                delta = TripleDelta(
                    base_version=self.store.version,
                    add=rows_at(cloud_store,
                                np.setdiff1d(target_eids,
                                             self.resident_eids)),
                    evict=rows_at(cloud_store,
                                  np.setdiff1d(self.resident_eids,
                                               target_eids)))
            else:
                delta = delta_between(self.store,
                                      rows_at(cloud_store, target_eids))
            if delta.shipped_bytes >= len(target_eids) * ADD_WIRE_BYTES:
                # near-total churn: the diff costs more on the wire than
                # re-shipping the (smaller) target outright — let the
                # commit fall back to a full rebuild
                delta = None
        return chosen, added, evicted, target_eids, delta, needs_commit

    def rebalance(self, cloud_store: RDFStore,
                  use_delta: bool = True) -> tuple[int, int]:
        """Synchronous single-server dynamic update (paper §3.2).

        Plan + commit in one step; returns (n_added, n_evicted) pattern
        counts. The system-level path (:meth:`repro.edge.system.
        EdgeCloudSystem.rebalance_all` / ``rebalance_async``) goes through
        :class:`repro.edge.rebalance.RebalanceManager` instead, which
        separates this into an overlap-safe compute phase and an epoch-
        barrier commit.
        """
        chosen, added, evicted, eids, delta, needs_commit = \
            self.plan_rebalance(cloud_store, use_delta)
        if needs_commit:
            self.commit_residency(cloud_store, chosen, eids, delta)
        return len(added), len(evicted)

    # -- query path ----------------------------------------------------------
    def can_execute(self, q_pattern: Pattern) -> bool:
        return bool(self.index.lookup(q_pattern))

    def execute(self, q) -> tuple[MatchResult, ExecutionRecord]:
        assert self.store is not None, "edge server has no deployed data"
        return _execute_batch(self.store, self.engine, [q])[0]

    def execute_batch(self, queries: list,
                      ) -> list[tuple[MatchResult, ExecutionRecord]]:
        assert self.store is not None, "edge server has no deployed data"
        return _execute_batch(self.store, self.engine, queries)

    def used_bytes(self) -> int:
        return self.store.size_bytes() if self.store is not None else 0
