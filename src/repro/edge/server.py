"""Edge and cloud servers.

An edge server hosts pattern-induced subgraphs for a resident pattern set
(selected under its storage budget) plus the hash-code pattern index used for
O(1) executability checks. The cloud hosts the full graph.

Both execute queries with the same vectorized matcher — the paper's
completeness guarantee (matches over G[P] == matches over G for queries
isomorphic to a resident pattern) is what makes edge execution correct, and
is asserted in tests/test_edge_system.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cost import result_bits
from ..core.induced import induced_edge_ids
from ..core.pattern import Pattern, PatternIndex, pattern_of
from ..core.placement import DynamicPlacement
from ..rdf.graph import RDFStore, triples_size_bytes
from ..sparql.engine import QueryEngine
from ..sparql.matcher import MatchResult
from ..sparql.query import QueryGraph


@dataclass
class ExecutionRecord:
    n_matches: int
    wall_seconds: float
    result_bits: float

    @classmethod
    def of(cls, res: MatchResult, projection: list[str],
           wall_seconds: float) -> "ExecutionRecord":
        """Build from a match result; ``result_bits`` goes through the
        single-sourced :func:`repro.core.cost.result_bits` conversion."""
        return cls(n_matches=res.num_matches, wall_seconds=wall_seconds,
                   result_bits=result_bits(res, projection))


def _execute_batch(store: RDFStore, engine: QueryEngine,
                   queries: list[QueryGraph],
                   ) -> list[tuple[MatchResult, ExecutionRecord]]:
    """Run one server's batch through the engine; wall time is apportioned
    evenly across the batch (scans/cache are shared, so per-query isolation
    is not measurable — Eq. 5 accounting only needs the total)."""
    t0 = time.perf_counter()
    results = engine.execute_batch(store, queries)
    per_q = (time.perf_counter() - t0) / max(1, len(queries))
    return [(res, ExecutionRecord.of(res, q.projection, per_q))
            for q, res in zip(queries, results)]


class CloudServer:
    """Holds the complete RDF graph G — monolithic or sharded
    (any :class:`RDFStore`)."""

    def __init__(self, store: RDFStore,
                 engine: QueryEngine | None = None) -> None:
        self.store = store
        self.engine = engine or QueryEngine()

    def execute(self, q: QueryGraph) -> tuple[MatchResult, ExecutionRecord]:
        t0 = time.perf_counter()
        res = self.engine.execute(self.store, q)
        dt = time.perf_counter() - t0
        return res, ExecutionRecord.of(res, q.projection, dt)

    def execute_batch(self, queries: list[QueryGraph],
                      ) -> list[tuple[MatchResult, ExecutionRecord]]:
        return _execute_batch(self.store, self.engine, queries)


class EdgeServer:
    """Stores pattern-induced subgraphs G[P] + the pattern index."""

    def __init__(self, server_id: int, storage_budget_bytes: int,
                 compute_cycles_per_s: float,
                 engine: QueryEngine | None = None) -> None:
        self.server_id = server_id
        self.budget = int(storage_budget_bytes)
        self.F = float(compute_cycles_per_s)
        self.engine = engine or QueryEngine()
        self.placement = DynamicPlacement(budget_bytes=self.budget)
        self.index = PatternIndex()
        self.store: RDFStore | None = None
        self._resident: dict[tuple, Pattern] = {}
        self._edge_ids: dict[tuple, np.ndarray] = {}

    # -- deployment ---------------------------------------------------------
    def measure_pattern(self, cloud_store: RDFStore, p: Pattern,
                        size_cache: dict[tuple, tuple] | None = None) -> int:
        """Compute |G[{p}]| bytes (cached across servers by pattern key)."""
        if size_cache is not None and p.key in size_cache:
            eids, nbytes = size_cache[p.key]
        else:
            eids = induced_edge_ids(cloud_store, [p])
            nbytes = triples_size_bytes(len(eids))
            if size_cache is not None:
                size_cache[p.key] = (eids, nbytes)
        self._edge_ids[p.key] = eids
        self.placement.set_size(p, nbytes)
        return nbytes

    def deploy(self, cloud_store: RDFStore,
               patterns: list[Pattern]) -> None:
        """Materialize G[P] for the given resident set.

        Built through the :class:`RDFStore` protocol: ``subgraph`` preserves
        the cloud store's kind, so a sharded cloud yields sharded
        pattern-induced edge stores (possibly with empty shards)."""
        self._resident = {p.key: p for p in patterns if p.indexable}
        self.index = PatternIndex()
        all_eids = [self._edge_ids[k] for k in self._resident
                    if k in self._edge_ids]
        eids = (np.unique(np.concatenate(all_eids)) if all_eids
                else np.zeros(0, dtype=np.int64))
        self.store = cloud_store.subgraph(eids)
        for p in self._resident.values():
            self.index.add(p, self.server_id)
        self.placement.resident = set(self._resident)

    def rebalance(self, cloud_store: RDFStore,
                  size_cache: dict | None = None) -> tuple[int, int]:
        """Dynamic update (paper §3.2): apply the placement policy.

        Returns (n_added, n_evicted). Asynchronous in the paper; callers run
        it between scheduling rounds.
        """
        # ensure sizes are known for all observed patterns
        for k, p in self.placement.patterns.items():
            if k not in self.placement.sizes:
                self.measure_pattern(cloud_store, p, size_cache)
        added, evicted = self.placement.rebalance()
        if added or evicted:
            self.deploy(cloud_store,
                        [self.placement.patterns[k]
                         for k in self.placement.resident])
        return len(added), len(evicted)

    # -- query path ----------------------------------------------------------
    def can_execute(self, q_pattern: Pattern) -> bool:
        return bool(self.index.lookup(q_pattern))

    def execute(self, q: QueryGraph) -> tuple[MatchResult, ExecutionRecord]:
        assert self.store is not None, "edge server has no deployed data"
        t0 = time.perf_counter()
        res = self.engine.execute(self.store, q)
        dt = time.perf_counter() - t0
        return res, ExecutionRecord.of(res, q.projection, dt)

    def execute_batch(self, queries: list[QueryGraph],
                      ) -> list[tuple[MatchResult, ExecutionRecord]]:
        assert self.store is not None, "edge server has no deployed data"
        return _execute_batch(self.store, self.engine, queries)

    def used_bytes(self) -> int:
        return self.store.size_bytes() if self.store is not None else 0
