"""Asynchronous, delta-based placement rebalancing (the paper's "dynamic
update ... as an asynchronous background task", §3.2, made real).

The seed reproduction's ``rebalance_all`` was a synchronous stop-the-world
step: between rounds it re-derived every resident pattern's induced
subgraph and re-shipped entire edge stores. :class:`RebalanceManager`
replaces that with a two-phase pipeline in the spirit of partial-evaluation
distributed SPARQL systems (Peng et al., VLDB'16) — placement maintenance
stays disjoint from the query path:

**Compute phase (overlaps query rounds, takes no system lock).** For every
edge server: measure any observed-but-unmeasured patterns through the
shared :class:`repro.core.induced.InducedIndex` (memoized per ``(cloud
version, pattern key)`` — unchanged patterns cost zero matcher calls), plan
the target residency with :meth:`repro.core.placement.DynamicPlacement.
plan` (total + per-shard budgets, hysteresis) WITHOUT mutating it, and diff
the live edge store against the target into a
:class:`repro.rdf.deltas.TripleDelta`. All of this reads only the immutable
cloud store and the edge stores the manager itself owns mutation of (one
rebalance runs at a time, enforced by an internal lock), so concurrent
query rounds proceed untouched.

**Commit phase (the epoch barrier).** Under the system's placement lock —
the same lock every query round holds from scheduling through execution —
each edge applies its delta in place (or falls back to a full ``subgraph``
rebuild if its store version moved) and republishes its pattern index,
then frequencies decay and ``EdgeCloudSystem.placement_epoch`` advances
once. A round therefore observes either the pre-commit placement or the
post-commit placement, never a half-applied one: the scheduler's
feasibility matrix ``e_nk`` (built from the pattern indexes inside the same
lock) can never route a query to an edge mid-eviction. Commit cost is
array-append/delete on edge-sized stores — the expensive matching already
happened in the compute phase.

``RebalanceManager.start()`` runs compute+commit on a daemon thread and
returns a :class:`RebalanceHandle`; ``run()`` is the synchronous form
(still delta-shipping). ``use_deltas=False`` keeps the full re-ship
data-plane for A/B comparison (``benchmarks/bench_engine.py
--rebalance`` measures both: bytes shipped and wall-clock).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from ..rdf.deltas import ADD_WIRE_BYTES


@dataclass
class EdgeRebalance:
    """Per-edge outcome of one rebalance."""

    server_id: int
    n_added: int                  # patterns added to residency
    n_evicted: int                # patterns evicted
    mode: str                     # "delta" | "full" | "noop"
    triples_added: int = 0
    triples_evicted: int = 0
    shipped_bytes: int = 0        # modeled wire bytes actually moved
    full_bytes: int = 0           # counterfactual: full re-ship of target


@dataclass
class RebalanceReport:
    """System-wide outcome of one rebalance epoch."""

    changes: dict[int, tuple[int, int]] = field(default_factory=dict)
    per_edge: list[EdgeRebalance] = field(default_factory=list)
    epoch: int = 0                # placement epoch after commit
    compute_seconds: float = 0.0  # lock-free phase (overlaps rounds)
    commit_seconds: float = 0.0   # under the placement lock (the barrier)
    matcher_calls: int = 0        # induced-id computations actually run
    induced_hits: int = 0         # memoized induced-id lookups

    @property
    def shipped_bytes(self) -> int:
        return sum(e.shipped_bytes for e in self.per_edge)

    @property
    def full_bytes(self) -> int:
        return sum(e.full_bytes for e in self.per_edge)

    @property
    def changed(self) -> bool:
        return any(a or e for a, e in self.changes.values())


class RebalanceHandle:
    """Join handle for a background rebalance (re-raises worker errors)."""

    def __init__(self, thread: threading.Thread) -> None:
        self._thread = thread
        self.report: RebalanceReport | None = None
        self.error: BaseException | None = None

    def done(self) -> bool:
        return not self._thread.is_alive()

    def join(self, timeout: float | None = None) -> RebalanceReport:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("rebalance still running")
        if self.error is not None:
            raise self.error
        assert self.report is not None
        return self.report


class RebalanceManager:
    """Two-phase (compute || rounds, then epoch-barrier commit) placement
    rebalancer for one :class:`repro.edge.system.EdgeCloudSystem`."""

    def __init__(self, system, use_deltas: bool = True) -> None:
        self.system = system
        self.use_deltas = bool(use_deltas)
        # one rebalance at a time: the compute phase diffs edge stores the
        # commit phase mutates, so overlapping rebalances would race
        self._busy = threading.Lock()
        # test/instrumentation seam: called after compute, before the
        # commit barrier is taken (lets tests pin a round mid-overlap)
        self.pre_commit_hook = None

    # -- phases --------------------------------------------------------------
    def _compute(self, use_deltas: bool) -> list[tuple]:
        """Plan every edge (independent state: own placement/store, shared
        lock-guarded InducedIndex) through the shared thread pool — the
        matcher's NumPy hot paths release the GIL on large arrays, so
        multi-edge plans overlap like server batches do in a round."""
        from ..core.parallel import thread_map
        cloud = self.system.cloud.store
        return thread_map(
            lambda es: (es, *es.plan_rebalance(cloud, use_delta=use_deltas)),
            self.system.edges)

    def _commit(self, plans: list[tuple],
                plan_cloud_version) -> RebalanceReport | None:
        """Apply planned residencies under the epoch barrier.

        Returns ``None`` (caller recomputes) if the cloud store's version
        moved since the plans were computed: every planned ``target_eids``
        / delta is expressed in the plan-time cloud's id space, so
        committing it against a newer cloud would resync edges to stale —
        or, through the full-rebuild fallback, plain wrong — content.
        """
        report = RebalanceReport()
        sys_ = self.system
        with sys_._placement_lock:
            if sys_.cloud.store.version != plan_cloud_version:
                return None
            for es, chosen, added, evicted, eids, delta, needs in plans:
                if needs:
                    mode = es.commit_residency(sys_.cloud.store, chosen,
                                               eids, delta)
                else:
                    mode = "noop"
                # counterfactual full re-ship: every target row crosses the
                # wire (indexes are rebuilt edge-side, so raw rows only)
                full = len(eids) * ADD_WIRE_BYTES if needs else 0
                if mode == "delta" and delta is not None:
                    shipped = delta.shipped_bytes
                    t_add, t_ev = delta.n_add, delta.n_evict
                elif mode == "full" and needs:
                    shipped, t_add, t_ev = full, len(eids), 0
                else:
                    shipped = t_add = t_ev = 0
                report.per_edge.append(EdgeRebalance(
                    server_id=es.server_id, n_added=len(added),
                    n_evicted=len(evicted), mode=mode,
                    triples_added=t_add, triples_evicted=t_ev,
                    shipped_bytes=shipped, full_bytes=full))
                report.changes[es.server_id] = (len(added), len(evicted))
                es.placement.decay_round()
            sys_.placement_epoch += 1
            report.epoch = sys_.placement_epoch
        return report

    def _warm_induced(self) -> None:
        """Next-epoch prefetch: pull every observed pattern's induced edge
        ids into the shared memo against the CURRENT cloud version. Touches
        only the (lock-guarded) InducedIndex and the cloud store — never
        the edge stores a concurrent commit mutates — so it runs while the
        previous epoch commits; the next compute phase then hits the memo
        instead of the matcher. Best-effort: a cloud write mid-prefetch
        just supersedes the warmed version."""
        cloud = self.system.cloud.store
        try:
            for es in self.system.edges:
                for p in list(es.placement.patterns.values()):
                    self.system.induced.edge_ids(cloud, p)
        except Exception:
            pass        # prefetch only; the compute phase recomputes

    def _compute_commit(self, use: bool, max_attempts: int = 3,
                        overlap_next: bool = False) -> RebalanceReport:
        """One epoch: lock-free compute -> (optional next-epoch prefetch
        thread) -> epoch-barrier commit. Caller holds ``_busy``.

        The cloud may advance through live ingest while the lock-free
        compute phase runs; plans are id-space-bound to the version they
        were computed against, so a moved cloud triggers a recompute. If
        sustained write traffic outruns ``max_attempts`` lock-free tries,
        the final attempt computes AND commits atomically inside the
        placement lock (reentrant, so ``_commit`` re-enters it): writes
        queue for the duration of one compute instead of placement
        maintenance wedging forever.
        """
        ind = self.system.induced
        h0, m0 = ind.hits, ind.misses
        compute_dt = commit_dt = 0.0
        report = None
        warm = None
        for _ in range(max_attempts):
            version = self.system.cloud.store.version
            t0 = time.perf_counter()
            plans = self._compute(use)
            compute_dt += time.perf_counter() - t0
            if self.pre_commit_hook is not None:
                self.pre_commit_hook()
            if overlap_next and warm is None:
                # pipeline: epoch N+1's expensive matching overlaps epoch
                # N's commit (the commit never mutates the cloud store the
                # prefetch reads)
                warm = threading.Thread(target=self._warm_induced,
                                        name="rebalance-warm", daemon=True)
                warm.start()
            t1 = time.perf_counter()
            report = self._commit(plans, version)
            commit_dt = time.perf_counter() - t1
            if report is not None:
                break
        if report is None:
            with self.system._placement_lock:
                version = self.system.cloud.store.version
                t0 = time.perf_counter()
                plans = self._compute(use)
                compute_dt += time.perf_counter() - t0
                t1 = time.perf_counter()
                report = self._commit(plans, version)
                commit_dt = time.perf_counter() - t1
            assert report is not None   # version cannot move under the lock
        report.compute_seconds = compute_dt
        report.commit_seconds = commit_dt
        report.matcher_calls = ind.misses - m0
        report.induced_hits = ind.hits - h0
        self.system.last_rebalance = report
        return report

    # -- entry points --------------------------------------------------------
    def run(self, use_deltas: bool | None = None) -> RebalanceReport:
        """Compute + commit, synchronously (but still delta-shipping)."""
        use = self.use_deltas if use_deltas is None else bool(use_deltas)
        with self._busy:
            return self._compute_commit(use)

    def run_pipeline(self, epochs: int = 2,
                     use_deltas: bool | None = None) -> list[RebalanceReport]:
        """Multi-epoch pipelined rebalance for continuous-ingest regimes.

        Runs ``epochs`` back-to-back placement epochs; within each, the
        next epoch's induced-id prefetch overlaps the current commit
        (``_compute_commit(overlap_next=True)``), and BETWEEN epochs no
        lock is held — write traffic (``EdgeCloudSystem.apply_update``)
        and query rounds are admitted freely. Sustained writes can never
        starve an epoch: the per-epoch locked fallback bounds how long the
        cloud can keep moving under a compute phase. Returns the per-epoch
        reports (``system.last_rebalance`` keeps the final one).
        """
        use = self.use_deltas if use_deltas is None else bool(use_deltas)
        reports: list[RebalanceReport] = []
        with self._busy:
            for _ in range(max(1, int(epochs))):
                reports.append(self._compute_commit(use, overlap_next=True))
        return reports

    def start(self, use_deltas: bool | None = None) -> RebalanceHandle:
        """Run the rebalance on a background daemon thread, overlapping
        query rounds; only the commit serializes (epoch barrier)."""
        handle: RebalanceHandle

        def work():
            try:
                handle.report = self.run(use_deltas)
            except BaseException as exc:   # re-raised at join()
                handle.error = exc

        t = threading.Thread(target=work, name="rebalance", daemon=True)
        handle = RebalanceHandle(t)
        t.start()
        return handle
