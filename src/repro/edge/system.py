"""End-to-end edge-cloud system simulator (paper Fig. 1 / §5 environment).

Wires together: the RDF cloud store, K edge servers with pattern-induced
subgraphs, N end users with link rates, the executability matrix E built via
the pattern hash index, and the MINLP scheduler. One ``run_round`` performs
the full paper pipeline:

  queries -> patterns -> E-matrix (isomorphism lookup) -> schedule (B&B or
  baseline) -> execute at assigned servers -> response-time accounting.

Response time per query follows the paper's cost model (Eq. 5) with the
CRA-optimal resource split; wall-clock matcher times are also recorded so
benchmarks can report both modeled and measured numbers.

``run_round_batched`` executes each server's assignment as one engine batch;
``overlap=True`` resolves per backend (:func:`resolve_overlap_mode`):
process mode for numpy engines, thread mode for jax. ``overlap="thread"``
runs the per-server batches through a thread pool so edge and cloud
execution no longer serialize (the shared engine's caches are
lock-guarded; per-server wall clocks are measured inside each thread and
feed the Eq. 5 accounting unchanged). ``overlap="process"``
instead dispatches batches to a persistent fork-based worker pool — true
parallelism for GIL-bound NumPy deployments: workers inherit the stores
copy-on-write and return only the tiny :class:`ExecutionRecord`s (match
results are not shipped back; the round loop never reads them). The pool is
rebuilt automatically when any store version changes (prepare/rebalance);
worker engines keep their own version-keyed caches — use
``clear_engine_caches`` to cold-start both sides. Process mode requires a
jax-free process: forking live XLA runtime threads is unsafe, so jax
engines — or any process where an XLA backend was already initialized —
fall back to thread overlap.

**Placement epochs (the rebalance handshake).** Placement is a first-class,
continuously running part of the system: ``rebalance_async`` starts a
:class:`repro.edge.rebalance.RebalanceManager` pass whose expensive compute
phase (matching new patterns through the shared memoized
:class:`repro.core.induced.InducedIndex`, planning residency under total +
per-shard budgets, diffing edge stores into
:class:`repro.rdf.deltas.TripleDelta`s) overlaps query rounds. Every round
holds ``_placement_lock`` from scheduling through execution and the
rebalance commits under the same lock, bumping ``placement_epoch`` — so the
feasibility matrix ``e_nk``, the pattern indexes, and the edge stores
always belong to ONE epoch and ``schedule(policy="bnb")`` can never route a
query to an edge mid-eviction. ``rebalance_all`` is the synchronous form;
both ship deltas by default (``use_deltas=False`` re-ships full induced
subgraphs, kept for A/B in ``benchmarks/bench_engine.py --rebalance``).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cost import (CYCLES_BASE, CYCLES_PER_ROW, BITS_PER_CELL,
                         PartialOption, QueryTasks, SystemParams,
                         estimate_query_cost, partial_free_cost)
from ..core.induced import InducedIndex
from ..core.pattern import (VAR_PRED_LABEL, Pattern, feasibility_patterns,
                            observed_patterns)
from ..core.placement import PatternProfile, greedy_knapsack
from ..core.scheduler import ScheduleResult, schedule
from ..rdf.graph import RDFStore
from ..sparql.algebra import compile_query
from ..sparql.engine import QueryEngine
from ..sparql.matcher import MatchResult
from ..sparql.partial_eval import execute_partial_batch, plan_partial
from ..sparql.query import QueryGraph, parse_query
from .rebalance import RebalanceHandle, RebalanceManager, RebalanceReport
from .server import CloudServer, EdgeServer, ExecutionRecord

# ``QueryOutcome.assigned_to`` / batched-round sentinel: the query ran as a
# PARTIAL plan — resident-leaf fragments at several edges, assembly at the
# cloud (see repro.sparql.partial_eval). -1 remains whole-query cloud.
PARTIAL = -2


# Fork-inheritance slots for process-mode overlapped rounds: the parent sets
# these just before forking the pool, so workers see the full system
# (stores, servers, engine) copy-on-write without any pickling. They stay
# set while the pool is alive (Pool forks REPLACEMENT workers when one
# dies), which also enforces one live process pool per process: creating a
# pool for another system closes the previous owner's pool first.
# _WORKER_SYSTEM is a weakref on the parent side so an abandoned system can
# still be collected (its __del__ closes the pool); inside a worker the
# referent was alive at fork time, so the copy-on-write snapshot resolves.
_WORKER_SYSTEM = None       # weakref.ref to the pool-owning system, or None
_WORKER_EPOCH = 0


def resolve_overlap_mode(overlap: bool | str, backend_name: str) -> str:
    """Resolve a ``run_round_batched(overlap=...)`` argument to a mode.

    Explicit ``"thread"`` / ``"process"`` strings are honored as given
    (the safety downgrades in :meth:`EdgeCloudSystem.run_round_batched`
    still apply afterwards). ``overlap=True`` auto-picks by engine
    backend: **process** for numpy — thread overlap there is GIL-bound,
    ~0.75x vs sequential (see ROADMAP), while the fork pool actually wins
    — and **thread** for jax, whose kernels release the GIL and whose
    live XLA runtime makes forking unsafe anyway. ``False`` -> ``""``
    (sequential).
    """
    if not overlap:
        return ""
    if isinstance(overlap, str):
        return overlap
    return "process" if backend_name == "numpy" else "thread"


def _xla_initialized() -> bool:
    """True once any XLA backend is live in this process — forking then is
    unsafe (XLA's runtime threads can leave locks held in the child).

    Fails CLOSED: if jax is imported but the introspection point moved
    (private API — ``jax._src.xla_bridge._backends`` in jax 0.4.x), a live
    runtime can't be ruled out and process-mode overlap is disabled rather
    than risking a fork deadlock.
    """
    import sys
    if "jax" not in sys.modules:
        return False
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is not None and hasattr(xb, "_backends"):
        return bool(xb._backends)
    return True


def _strip_plan_for_ipc(q):
    """Shallow-copy an algebra plan without its attached ``dictionary`` /
    ``parsed`` payload: fork-pool workers already hold the system's
    dictionary copy-on-write, so shipping megabytes of term tables per
    payload would defeat PR 3's records-only IPC design. The operator
    tree itself is shared by reference in the copy (read-only)."""
    from ..sparql.algebra import is_algebra_plan
    if not is_algebra_plan(q) or getattr(q, "dictionary", None) is None:
        return q
    import copy
    lite = copy.copy(q)
    lite.dictionary = None
    lite.parsed = None
    return lite


def _round_worker(task):
    """Pool worker: execute one server's batch, return (k, records, wall).

    ``epoch`` mirrors the parent's ``clear_engine_caches`` counter: when it
    advances, the worker cold-starts its own engine caches first — so a
    benchmark clearing caches between rounds measures both sides cold.
    """
    global _WORKER_EPOCH
    k, qs, epoch = task
    # the weakref trade-off: a strong ref here (or in Pool initargs) would
    # be pinned by the pool's maintenance thread and make an abandoned
    # system uncollectable (the leak __del__ exists to prevent). The cost:
    # a REPLACEMENT worker forked after the owner died cannot resolve it —
    # fail with an actionable message (the parent's map() re-raises).
    sys_ = _WORKER_SYSTEM() if _WORKER_SYSTEM is not None else None
    if sys_ is None:
        raise RuntimeError(
            "process-overlap worker has no live system (pool owner was "
            "garbage-collected); call close_overlap_pool() and retry")
    if epoch != _WORKER_EPOCH:
        sys_.engine.clear_cache()
        _WORKER_EPOCH = epoch
    for q in qs:                 # reattach the fork-shared dictionary to
        if hasattr(q, "bgp_leaves"):     # plans stripped for the pipe
            q.dictionary = sys_.dictionary
    server = sys_.cloud if k < 0 else sys_.edges[k]
    t0 = time.perf_counter()
    out = server.execute_batch(qs)
    return k, [rec for _, rec in out], time.perf_counter() - t0


@dataclass
class QueryOutcome:
    user: int
    assigned_to: int              # -1 cloud, -2 partial, else edge server id
    modeled_latency: float        # paper cost model w/ ESTIMATED (c, w)
    realized_latency: float       # paper cost model w/ MEASURED result size
    measured_exec_seconds: float  # actual matcher wall time
    n_matches: int
    executable_edges: list[int]
    # multi-server (partial-evaluation) assignments only:
    partial_servers: tuple = ()   # edges that contributed fragments
    shipped_bits: float = 0.0     # binding-table egress over the backhaul


@dataclass
class RoundReport:
    policy: str
    outcomes: list[QueryOutcome]
    objective: float              # scheduler objective (modeled total cost)
    schedule_seconds: float
    assignment_counts: dict[int, int]  # -1 cloud, k per edge
    overlapped: bool = False      # batches dispatched through a worker pool
    overlap_mode: str = ""        # "", "thread", or "process"
    execute_wall_seconds: float = 0.0  # wall clock of the execute phase
    # per-server batch wall clock (-1 cloud, k per edge); in an overlapped
    # round these overlap each other, so their sum exceeds the phase wall
    server_wall_seconds: dict[int, float] = field(default_factory=dict)
    # per-query match results aligned with ``outcomes`` — populated only by
    # ``run_round_batched(collect_results=True)`` (the serving front end
    # needs the bindings, not just the accounting records)
    results: list | None = None
    # partial-evaluation accounting (batched rounds only): queries that ran
    # as multi-edge partial plans, their total dictionary-free binding-table
    # egress, and plans that fell back to the cloud on a stale placement
    partial_queries: int = 0
    partial_bytes_shipped: int = 0
    partial_fallbacks: int = 0

    @property
    def total_modeled_latency(self) -> float:
        return sum(o.modeled_latency for o in self.outcomes)

    @property
    def total_realized_latency(self) -> float:
        return sum(o.realized_latency for o in self.outcomes)

    @property
    def assignment_ratio(self) -> dict[int, float]:
        n = max(1, len(self.outcomes))
        return {k: v / n for k, v in sorted(self.assignment_counts.items())}


@dataclass
class IngestReport:
    """Outcome of one live-ingest write (cloud ``apply_delta`` path)."""

    kind: str = ""                 # insert_data | delete_data | delete_where
    n_add: int = 0                 # triples added to the cloud
    n_evict: int = 0               # triples removed from the cloud
    new_terms: int = 0             # dictionary terms minted (version bumps)
    dropped_rows: int = 0          # no-op delete rows (unknown terms)
    touched_predicates: list[int] | None = None   # None == all predicates
    patterns_carried: int = 0      # induced-memo entries carried forward
    patterns_invalidated: int = 0  # entries dropped (must re-match)
    edges_updated: int = 0         # edge stores that received a delta
    shipped_bytes: int = 0         # cloud->edge delta wire bytes
    cloud_version_before: object = None
    cloud_version: object = None
    placement_epoch: int = 0
    apply_seconds: float = 0.0

    @property
    def is_noop(self) -> bool:
        return not (self.n_add or self.n_evict)


def _pattern_key_labels(key: tuple) -> set[int]:
    """Edge labels of a canonical pattern key (``(n_vertices, code)`` —
    every DFS-code entry carries its label last)."""
    return {entry[-1] for entry in key[1]}


class EdgeCloudSystem:
    """K edge servers + cloud + N users, with pattern-based data placement.

    ``store`` may be a monolithic :class:`~repro.rdf.graph.TripleStore` or a
    :class:`~repro.rdf.sharding.ShardedTripleStore`; edge deployments inherit
    the cloud store's kind through ``subgraph``.
    """

    def __init__(self, store: RDFStore, dictionary, params: SystemParams,
                 storage_budgets: np.ndarray | int,
                 backend: str = "numpy",
                 engine: QueryEngine | None = None,
                 shard_budgets=None,
                 enable_partial: bool = True) -> None:
        # three-way scheduling {edge, cloud, partial}: batched rounds may
        # split a cloud-bound query's resident leaves across several edges
        # (repro.sparql.partial_eval); False restores the binary paper model
        self.enable_partial = bool(enable_partial)
        # one engine serves cloud + all edges: its result cache keys embed
        # the store version, so entries from different stores never collide
        self.engine = engine or QueryEngine(backend=backend)
        self.cloud = CloudServer(store, engine=self.engine)
        self.dictionary = dictionary
        self.params = params
        budgets = (np.full(params.K, storage_budgets)
                   if np.isscalar(storage_budgets) else storage_budgets)
        # per-shard byte budgets (sharded cloud only): scalar = same budget
        # for every shard, or a [num_shards] vector; applied at every edge
        if shard_budgets is not None and np.isscalar(shard_budgets):
            shard_budgets = np.full(getattr(store, "num_shards", 1),
                                    int(shard_budgets))
        # shared memoized induced-edge-id index: patterns measured once per
        # cloud version across all edges (and across rebalances)
        self.induced = InducedIndex()
        self.edges = [EdgeServer(k, int(budgets[k]), params.F[k],
                                 engine=self.engine,
                                 shard_budgets=shard_budgets,
                                 induced=self.induced)
                      for k in range(params.K)]
        self.construction_seconds = 0.0
        self._proc_pool = None
        self._proc_pool_versions: tuple | None = None
        self._engine_epoch = 0
        # epoch/barrier handshake with the rebalance data-plane: rounds hold
        # the lock from scheduling through execution; rebalance commits under
        # it and bumps the epoch, so a round never observes a half-applied
        # placement (see repro.edge.rebalance)
        self._placement_lock = threading.RLock()
        self.placement_epoch = 0
        self.rebalancer = RebalanceManager(self)
        self.last_rebalance: RebalanceReport | None = None

    # -- process-mode overlap pool -------------------------------------------
    def _store_versions(self) -> tuple:
        return (self.cloud.store.version,
                *(es.store.version if es.store is not None else None
                  for es in self.edges))

    def _ensure_process_pool(self):
        """Persistent fork pool for overlapped rounds; rebuilt whenever any
        store version changes (workers hold the stores copy-on-write)."""
        versions = self._store_versions()
        if (self._proc_pool is not None
                and self._proc_pool_versions == versions):
            return self._proc_pool
        global _WORKER_SYSTEM, _WORKER_EPOCH
        import weakref
        prev = _WORKER_SYSTEM() if _WORKER_SYSTEM is not None else None
        if prev is not None and prev is not self:
            # one live pool per process: replacement workers forked later
            # inherit the CURRENT globals, so another system's stale pool
            # must not outlive its ownership of them
            prev.close_overlap_pool()
        self.close_overlap_pool()
        import multiprocessing as mp
        import os
        ctx = mp.get_context("fork")
        workers = max(2, min(self.params.K + 1, os.cpu_count() or 2))
        # workers inherit the current epoch so fork-warmed engine caches
        # survive until the next clear_engine_caches
        _WORKER_SYSTEM = weakref.ref(self)
        _WORKER_EPOCH = self._engine_epoch
        self._proc_pool = ctx.Pool(workers)
        self._proc_pool_versions = versions
        return self._proc_pool

    def close_overlap_pool(self) -> None:
        global _WORKER_SYSTEM
        if self._proc_pool is not None:
            self._proc_pool.terminate()
            self._proc_pool = None
            self._proc_pool_versions = None
        if _WORKER_SYSTEM is not None and _WORKER_SYSTEM() is self:
            _WORKER_SYSTEM = None

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.close_overlap_pool()
        except Exception:
            pass

    def clear_engine_caches(self) -> None:
        """Cold-start the shared engine AND any process-overlap workers
        (each worker clears its own engine before its next task)."""
        self.engine.clear_cache()
        self._engine_epoch += 1

    # -- offline preparation (paper: construction overhead, Table 11) -------
    def prepare(self, history_queries: list[list[str]]) -> None:
        """Deploy pattern-induced subgraphs from per-user query history.

        ``history_queries[n]`` = past SPARQL strings of user n. Each edge
        server considers patterns seen by its associated users, selects under
        its budget (greedy knapsack), and materializes G[P].
        """
        t0 = time.perf_counter()
        per_user_patterns: list[list[Pattern]] = []
        for qs in history_queries:
            pats = []
            for text in qs:
                # full-grammar history: every BGP leaf of an algebra query
                # (OPTIONAL sides included) is a placement candidate
                plan = compile_query(parse_query(text, self.dictionary),
                                     self.dictionary)
                pats += [p for p in observed_patterns(plan) if p.indexable]
            per_user_patterns.append(pats)

        with self._placement_lock:
            for es in self.edges:
                users = np.flatnonzero(self.params.assoc[:, es.server_id])
                freq: dict[tuple, float] = {}
                pat_by_key: dict[tuple, Pattern] = {}
                for n in users:
                    if n < len(per_user_patterns):
                        for p in per_user_patterns[n]:
                            freq[p.key] = freq.get(p.key, 0.0) + 1.0
                            pat_by_key.setdefault(p.key, p)
                profiles = []
                keys = list(freq)
                for k in keys:
                    size = es.measure_pattern(self.cloud.store,
                                              pat_by_key[k])
                    profiles.append(PatternProfile(
                        pat_by_key[k], freq[k], size,
                        es.placement.shard_sizes.get(k)))
                chosen = greedy_knapsack(profiles, es.budget,
                                         es.placement.shard_budgets)
                resident = [pat_by_key[keys[i]] for i in chosen]
                es.deploy(self.cloud.store, resident)
                for p in resident:
                    es.placement.observe(p, freq[p.key])
            self.placement_epoch += 1
        self.construction_seconds = time.perf_counter() - t0

    # -- the online path ------------------------------------------------------
    def _plan_partial_option(self, user: int, q, w_n: float,
                             ) -> PartialOption | None:
        """Estimate the generalized-Eq.-5 partial option for one query.

        Plans the fragment split (:func:`repro.sparql.partial_eval.
        plan_partial`) over the user's associated edges, then prices it:
        per-edge fragment cycles/result bits are estimated against that
        edge's (much smaller) G[P] store; residual + OPTIONAL fragments and
        the compatibility joins are cloud-side assembly cycles. Returns
        None when no edge can contribute. Caller holds the placement lock.
        """
        servers = [es for es in self.edges
                   if self.params.assoc[user, es.server_id]
                   and es.store is not None]
        if not servers:
            return None
        plan = plan_partial(q, servers)
        if plan is None:
            return None
        by_id = {es.server_id: es for es in servers}
        cycles: dict[int, float] = {}
        bits: dict[int, float] = {}
        assemble = CYCLES_BASE
        for frag in plan.fragments:
            store = (self.cloud.store if frag.server_id < 0
                     else by_id[frag.server_id].store)
            c_f, w_f = estimate_query_cost(store, frag.query)
            if frag.server_id < 0:
                assemble += c_f          # residual runs at the assembler
            else:
                cycles[frag.server_id] = cycles.get(frag.server_id, 0) + c_f
                bits[frag.server_id] = bits.get(frag.server_id, 0) + w_f
        # the compatibility joins + final operators: work proportional to
        # the estimated result rows (same calibration as measured costs)
        n_proj = max(1, len(q.projection) if getattr(q, "projection", None)
                     else len(getattr(q, "variables", [])) or 1)
        assemble += CYCLES_PER_ROW * (w_n / (BITS_PER_CELL * n_proj))
        eids = np.array(sorted(cycles), dtype=np.int64)
        return PartialOption(
            edges=eids,
            cycles=np.array([cycles[k] for k in eids], dtype=np.float64),
            ship_bits=np.array([bits[k] for k in eids], dtype=np.float64),
            assemble_cycles=float(assemble), plan=plan)

    def build_tasks(self, queries: list[tuple[int, QueryGraph]],
                    cost_source: str = "estimate",
                    include_partial: bool = False) -> QueryTasks:
        """(c, w, e) for a batch of (user, query) pairs (Eq. 2 via index).

        ``queries`` may mix plain :class:`QueryGraph`\\ s and compiled
        algebra plans. Feasibility is per-BGP-leaf
        (:func:`~repro.core.pattern.feasibility_patterns`): an algebra
        query is edge-executable iff EVERY required leaf's pattern is
        resident at that edge (OPTIONAL right sides excluded), so the B&B
        scheduler routes algebra queries exactly like BGPs.

        Taken under the placement lock so the feasibility matrix ``e_nk``
        snapshots ONE placement epoch — it can never mix pre- and
        post-rebalance residency across rows.

        ``include_partial=True`` (and ``enable_partial``) additionally
        plans a :class:`PartialOption` for every query NO single edge can
        fully serve — the three-way {edge, cloud, partial} plan space the
        B&B scheduler prices via the generalized Eq. 5.
        """
        N = len(queries)
        c = np.zeros(N)
        w = np.zeros(N)
        e = np.zeros((N, self.params.K))
        partial: list | None = None
        with self._placement_lock:
            for i, (user, q) in enumerate(queries):
                c[i], w[i] = estimate_query_cost(self.cloud.store, q)
                pats = feasibility_patterns(q)
                if pats is None:
                    continue        # nothing certifies edge execution
                for es in self.edges:
                    if self.params.assoc[user, es.server_id] and \
                            all(es.can_execute(p) for p in pats):
                        e[i, es.server_id] = 1.0
            if include_partial and self.enable_partial:
                partial = [None] * N
                for i, (user, q) in enumerate(queries):
                    if e[i].sum() == 0:   # full-edge already dominates
                        partial[i] = self._plan_partial_option(
                            user, q, float(w[i]))
                if not any(p is not None for p in partial):
                    partial = None
        return QueryTasks(c=c, w=w, e=e, partial=partial)

    def _schedule_round(self, queries: list[tuple[int, QueryGraph]],
                        policy: str, sched_kw: dict,
                        include_partial: bool = False,
                        ) -> tuple[QueryTasks, SystemParams,
                                   ScheduleResult, float]:
        tasks = self.build_tasks(queries, include_partial=include_partial)
        # user->link rows: task i belongs to user queries[i][0]; backhaul
        # rates are per-EDGE uplinks, so they pass through un-sliced
        users = [u for (u, _) in queries]
        params_batch = SystemParams(
            F=self.params.F,
            r_edge=self.params.r_edge[users],
            r_cloud=self.params.r_cloud[users],
            assoc=self.params.assoc[users],
            r_backhaul=self.params.r_backhaul,
            F_cloud=self.params.F_cloud,
        )
        if policy == "bnb":
            # anytime budget: at paper scale (K=4, N=20) optimality is
            # proven in ms; at fleet scale the incumbent is returned
            sched_kw.setdefault("max_seconds", 2.0)
        t0 = time.perf_counter()
        sr: ScheduleResult = schedule(tasks, params_batch, policy=policy,
                                      **sched_kw)
        return tasks, params_batch, sr, time.perf_counter() - t0

    def _observe_pattern(self, user: int, q) -> None:
        # algebra plans observe every BGP leaf (OPTIONAL sides included) so
        # dynamic placement can learn the full shape of the workload
        for p in observed_patterns(q):
            if p.indexable:
                for es in self.edges:
                    if self.params.assoc[user, es.server_id]:
                        es.placement.observe(p)

    @staticmethod
    def _realized_latency(rec, i: int, k: int, sr: ScheduleResult,
                          params_batch: SystemParams) -> float:
        # realized response time: same cost model, measured w and measured
        # cycles — per-phase engine wall (prescan+join) when available,
        # floored at the row-derived figure (repro.core.cost.
        # measured_cycles); the paper reports measured response times,
        # estimates only drive the scheduler
        from ..core.cost import measured_cycles
        c_real = measured_cycles(rec.n_matches,
                                 getattr(rec, "engine_seconds", 0.0))
        if k >= 0:
            f = max(sr.f[i, k], 1e-30)
            return c_real / f + rec.result_bits / params_batch.r_edge[i, k]
        # generalized cloud path: delivery + (finite-F_cloud) compute;
        # with the paper's free cloud (F_cloud = inf) the term vanishes
        return (rec.result_bits / params_batch.r_cloud[i]
                + c_real / params_batch.F_cloud)

    def _realized_partial_latency(self, pe, rec, i: int,
                                  params_batch: SystemParams) -> float:
        # generalized Eq. 5 with MEASURED per-edge rows/wall and egress
        # bits: fragment compute per contributing edge, binding-table
        # shipping over each edge's backhaul, assembly at the cloud
        # (per-server engine wall feeds measured_cycles the same way the
        # single-server path does), final delivery over the user's cloud
        # link
        from ..core.cost import measured_cycles
        bh = params_batch.backhaul
        # engine-phase seconds (prescan+join) when the executor recorded
        # them; raw walls otherwise — symmetric with the single-server
        # path's ExecutionRecord.engine_seconds
        secs = (getattr(pe, "per_server_engine_seconds", None)
                or pe.per_server_seconds)
        t = 0.0
        for sid, rows in pe.per_server_rows.items():
            if sid >= 0:
                t += measured_cycles(rows, secs.get(sid, 0.0)
                                     ) / self.params.F[sid]
        for sid, bits in pe.per_server_bits.items():
            t += bits / bh[sid]
        t += measured_cycles(rec.n_matches, secs.get(-1, 0.0)
                             ) / params_batch.F_cloud
        return float(t + rec.result_bits / params_batch.r_cloud[i])

    def explain_assignment(self, q, user: int = 0) -> str:
        """Dry-run the scheduler for one query and render the chosen plan
        kind — ``edge ESk`` / ``cloud`` / ``partial`` — plus, for partial,
        the per-server leaf split (used by ``SparqlEndpoint.explain``)."""
        with self._placement_lock:
            tasks, params_batch, sr, _ = self._schedule_round(
                [(user, q)], "bnb", {}, include_partial=True)
        opt = tasks.partial_option(0)
        if sr.partial is not None and sr.partial[0] and opt is not None:
            lines = ["assignment: partial "
                     f"(edges {np.asarray(opt.edges).tolist()} -> cloud "
                     "assembler)"]
            lines += ["  " + s for s in opt.plan.describe()]
            return "\n".join(lines)
        De = sr.D[0] * tasks.e[0]
        k = int(De.argmax()) if De.sum() > 0 else -1
        if k >= 0:
            return (f"assignment: edge ES{k} "
                    "(every required leaf resident)")
        why = (" (partial option available but estimated dearer)"
               if opt is not None else "")
        return "assignment: cloud" + why

    def run_round(self, queries: list[tuple[int, QueryGraph]],
                  policy: str = "bnb", execute: bool = True,
                  observe: bool = True, **sched_kw) -> RoundReport:
        # the round holds the placement lock from scheduling through
        # execution: a concurrent rebalance computes in parallel but its
        # commit (store mutation + index republish) waits for the barrier
        with self._placement_lock:
            return self._run_round_locked(queries, policy, execute,
                                          observe, sched_kw)

    def _run_round_locked(self, queries, policy, execute, observe,
                          sched_kw) -> RoundReport:
        tasks, params_batch, sr, sched_dt = self._schedule_round(
            queries, policy, sched_kw)

        outcomes: list[QueryOutcome] = []
        counts: dict[int, int] = {}
        for i, (user, q) in enumerate(queries):
            De = sr.D[i] * tasks.e[i]
            k = int(De.argmax()) if De.sum() > 0 else -1
            counts[k] = counts.get(k, 0) + 1
            if k >= 0:
                f = sr.f[i, k]
                modeled = (tasks.c[i] / max(f, 1e-30)
                           + tasks.w[i] / params_batch.r_edge[i, k])
            else:
                modeled = (tasks.w[i] / params_batch.r_cloud[i]
                           + tasks.c[i] / params_batch.F_cloud)
            n_matches, wall = 0, 0.0
            realized = modeled
            if execute:
                if k >= 0:
                    res, rec = self.edges[k].execute(q)
                else:
                    res, rec = self.cloud.execute(q)
                n_matches, wall = rec.n_matches, rec.wall_seconds
                realized = self._realized_latency(rec, i, k, sr,
                                                  params_batch)
            if observe:
                self._observe_pattern(user, q)
            outcomes.append(QueryOutcome(
                user=user, assigned_to=k, modeled_latency=float(modeled),
                realized_latency=float(realized),
                measured_exec_seconds=wall, n_matches=n_matches,
                executable_edges=np.flatnonzero(tasks.e[i]).tolist()))
        return RoundReport(policy=policy, outcomes=outcomes,
                           objective=sr.objective,
                           schedule_seconds=sched_dt,
                           assignment_counts=counts)

    def run_round_batched(self, queries: list[tuple[int, QueryGraph]],
                          policy: str = "bnb", execute: bool = True,
                          observe: bool = True,
                          overlap: bool | str = False,
                          max_workers: int | None = None,
                          collect_results: bool = False,
                          **sched_kw) -> RoundReport:
        """One scheduling round where each server executes its assignment as
        ONE batch through the shared :class:`QueryEngine` (scan dedup +
        result cache) instead of a per-query Python loop.

        Scheduling, cost accounting, and placement observation are identical
        to :meth:`run_round`; only the execution strategy differs, so the two
        produce the same solution multisets per query (asserted in
        ``tests/test_engine.py``). Per-query ``measured_exec_seconds`` is the
        batch wall time apportioned evenly over the batch.

        ``overlap=True`` auto-picks the mode per backend
        (:func:`resolve_overlap_mode`): process overlap for numpy engines
        (thread overlap is GIL-bound there) and thread overlap for jax.
        ``overlap="thread"`` dispatches each server's batch
        through a thread pool so edge and cloud batches no longer serialize
        — the engine's caches are lock-guarded and the NumPy/JAX hot paths
        release the GIL where they can. ``overlap="process"`` uses the
        persistent fork pool instead (see the module docstring): full
        parallelism for GIL-bound numpy deployments; requires the numpy
        backend (jax engines fall back to threads). In every mode each
        server's wall clock is measured inside its own worker
        (``RoundReport.server_wall_seconds``) and feeds the Eq. 5 accounting
        exactly as in a sequential round, so overlapped and sequential
        rounds report identical outcomes (asserted in
        ``tests/test_join_pipeline.py``); only the round's
        ``execute_wall_seconds`` shrinks.

        ``collect_results=True`` additionally returns each query's match
        result (``RoundReport.results``, aligned with ``outcomes``) — the
        serving front end reads the bindings, not just the accounting
        records. Process-mode overlap ships only the tiny records back
        over the pipe by design, so ``collect_results`` downgrades
        ``overlap="process"`` to thread overlap.

        Like :meth:`run_round`, the whole round runs under the placement
        lock (the rebalance epoch barrier).
        """
        with self._placement_lock:
            return self._run_round_batched_locked(
                queries, policy, execute, observe, overlap, max_workers,
                collect_results, sched_kw)

    def _run_round_batched_locked(self, queries, policy, execute, observe,
                                  overlap, max_workers, collect_results,
                                  sched_kw) -> RoundReport:
        tasks, params_batch, sr, sched_dt = self._schedule_round(
            queries, policy, sched_kw, include_partial=True)

        # assignment per query (edge k, cloud -1, or PARTIAL), then group
        # the single-server rows into one batch per server
        assigned: list[int] = []
        for i in range(len(queries)):
            opt = tasks.partial_option(i)
            if (sr.partial is not None and sr.partial[i] and opt is not None
                    and opt.plan is not None):
                assigned.append(PARTIAL)
                continue
            De = sr.D[i] * tasks.e[i]
            k = int(De.argmax()) if De.sum() > 0 else -1
            assigned.append(k)

        mode = resolve_overlap_mode(overlap, self.engine.backend.name)
        if mode == "process":
            import multiprocessing as mp
            if (self.engine.backend.name == "jax" or _xla_initialized()
                    or "fork" not in mp.get_all_start_methods()
                    or collect_results):
                # forking with live XLA runtime threads (this engine's or
                # ANY prior jax use in this process) risks a child
                # deadlock; spawn-only platforms have no fork at all; and
                # the fork pool ships records only — results can't come
                # back over the pipe
                mode = "thread"

        records: list = [None] * len(queries)
        results: list | None = ([None] * len(queries) if collect_results
                                else None)
        server_wall: dict[int, float] = {}
        exec_wall = 0.0
        partial_idx = [i for i, k in enumerate(assigned) if k == PARTIAL]
        partial_exec: dict[int, object] = {}
        if execute:
            by_server: dict[int, list[int]] = {}
            for i, k in enumerate(assigned):
                if k != PARTIAL:
                    by_server.setdefault(k, []).append(i)

            def run_server(k: int, idxs: list[int]):
                batch = [queries[i][1] for i in idxs]
                server = self.cloud if k < 0 else self.edges[k]
                t0 = time.perf_counter()
                out = server.execute_batch(batch)
                dt = time.perf_counter() - t0
                if collect_results:
                    for i, (res, _) in zip(idxs, out):
                        results[i] = res
                return k, [rec for _, rec in out], dt

            if len(by_server) <= 1:
                mode = ""            # nothing to overlap: report truthfully
            # pool (re)construction is deployment cost, not round latency —
            # keep it outside the timed execute phase
            pool = (self._ensure_process_pool()
                    if mode == "process" else None)
            t_exec = time.perf_counter()
            if pool is not None:
                payload = [(k, [_strip_plan_for_ipc(queries[i][1])
                                for i in idxs], self._engine_epoch)
                           for k, idxs in by_server.items()]
                done = pool.map(_round_worker, payload)
            elif mode:
                from ..core.parallel import thread_map
                done = thread_map(lambda kv: run_server(*kv),
                                  by_server.items(), max_workers)
            else:
                done = [run_server(k, idxs)
                        for k, idxs in by_server.items()]
            if partial_idx:
                # partial plans run in the coordinating process (fragment
                # batches are per-edge engine batches inside): their store
                # versions are re-verified there, so a rebalance that
                # slipped between scheduling and execution degrades to a
                # whole-query cloud fallback instead of a stale assembly
                pex = execute_partial_batch(
                    [tasks.partial_option(i).plan for i in partial_idx],
                    self.cloud.store, self.engine,
                    {es.server_id: es for es in self.edges})
                for i, pe in zip(partial_idx, pex):
                    partial_exec[i] = pe
            exec_wall = time.perf_counter() - t_exec
            for k, recs, dt in done:
                server_wall[k] = dt
                for i, rec in zip(by_server[k], recs):
                    records[i] = rec
            for i, pe in partial_exec.items():
                if pe.fallback:
                    assigned[i] = -1   # ran whole at the cloud; say so
                wall = sum(pe.per_server_seconds.values())
                records[i] = ExecutionRecord.of(
                    pe.result, list(queries[i][1].projection), wall)
                if collect_results:
                    results[i] = pe.result
                for sid, dts in pe.per_server_seconds.items():
                    server_wall[sid] = server_wall.get(sid, 0.0) + dts

        # counts reflect what actually RAN (stale partial plans fell back
        # to the cloud above and were reassigned)
        counts: dict[int, int] = {}
        for k in assigned:
            counts[k] = counts.get(k, 0) + 1

        outcomes: list[QueryOutcome] = []
        for i, (user, q) in enumerate(queries):
            k = assigned[i]
            pe = partial_exec.get(i)
            p_servers: tuple = ()
            p_bits = 0.0
            rec = records[i]
            if k == PARTIAL:
                modeled = partial_free_cost(tasks.partial_option(i),
                                            float(tasks.w[i]), params_batch,
                                            i)
                if pe is not None:
                    p_servers, p_bits = pe.servers, pe.shipped_bits
            elif k >= 0:
                modeled = (tasks.c[i] / max(sr.f[i, k], 1e-30)
                           + tasks.w[i] / params_batch.r_edge[i, k])
            else:
                modeled = (tasks.w[i] / params_batch.r_cloud[i]
                           + tasks.c[i] / params_batch.F_cloud)
            if rec is not None:
                if k == PARTIAL:
                    realized = self._realized_partial_latency(
                        pe, rec, i, params_batch)
                else:
                    realized = self._realized_latency(rec, i, k, sr,
                                                      params_batch)
                n_matches, wall = rec.n_matches, rec.wall_seconds
            else:
                realized, n_matches, wall = modeled, 0, 0.0
            if observe:
                self._observe_pattern(user, q)
            outcomes.append(QueryOutcome(
                user=user, assigned_to=k, modeled_latency=float(modeled),
                realized_latency=float(realized),
                measured_exec_seconds=wall, n_matches=n_matches,
                executable_edges=np.flatnonzero(tasks.e[i]).tolist(),
                partial_servers=p_servers, shipped_bits=float(p_bits)))
        shipped_total = sum(pe.shipped_bits for pe in partial_exec.values()
                            if not pe.fallback)
        return RoundReport(policy=policy, outcomes=outcomes,
                           objective=sr.objective,
                           schedule_seconds=sched_dt,
                           assignment_counts=counts,
                           overlapped=bool(mode and execute),
                           overlap_mode=mode if execute else "",
                           execute_wall_seconds=exec_wall,
                           server_wall_seconds=server_wall,
                           results=results,
                           partial_queries=sum(1 for k in assigned
                                               if k == PARTIAL),
                           partial_bytes_shipped=int(shipped_total // 8),
                           partial_fallbacks=sum(
                               1 for pe in partial_exec.values()
                               if pe.fallback))

    # -- live ingest (the write path) ----------------------------------------
    def apply_update(self, update) -> IngestReport:
        """THE ingest path: execute one SPARQL UPDATE against the live
        system.

        ``update`` is an update text, a parsed
        :class:`~repro.sparql.query.ParsedUpdate`, or a compiled
        :class:`~repro.sparql.update.CompiledUpdate`. Under the placement
        lock (so no query round ever observes a half-applied write):

        1. compile through the shared dictionary (new INSERT DATA terms
           bump ``Dictionary.version`` — plan memos keyed on it invalidate);
        2. turn it into a version-guarded cloud :class:`TripleDelta`
           (``DELETE WHERE`` evaluates its template here, against the
           locked store) and apply it — :meth:`ShardedTripleStore.
           apply_delta` routes rows to owning shards id-stably, mutating
           only touched shards;
        3. carry the :class:`InducedIndex` memo forward for patterns whose
           edge labels are disjoint from the delta's predicates (their
           matched-triple *content* provably cannot change — every matched
           triple carries one of the pattern's bound labels), remapping
           their edge ids into the new global id space; patterns touching
           a written predicate (or with a variable-predicate edge) are
           invalidated and re-match lazily;
        4. propagate version-consistently to every edge holding data: each
           edge's residency is re-derived against the new cloud (memo hits
           for carried patterns) and shipped as a content delta through the
           existing pipeline, then its index republishes at the new cloud
           version — feasibility certificates never go stale.
        """
        from ..sparql.query import ParsedUpdate, parse_update
        from ..sparql.update import (CompiledUpdate, compile_update,
                                     ground_delta, where_evict_rows)
        if isinstance(update, str):
            update = parse_update(update, self.dictionary)
        if isinstance(update, ParsedUpdate):
            update = compile_update(update, self.dictionary)
        if not isinstance(update, CompiledUpdate):
            raise TypeError(f"not an update: {type(update).__name__}")
        from ..rdf.deltas import TripleDelta
        with self._placement_lock:
            cloud = self.cloud.store
            if update.where is not None:
                delta = TripleDelta(base_version=cloud.version,
                                    evict=where_evict_rows(update, cloud))
            else:
                delta = ground_delta(update, cloud)
            rep = self._apply_cloud_delta(delta,
                                          update.touched_predicates())
            rep.kind = update.kind
            rep.new_terms = update.new_terms
            rep.dropped_rows = update.dropped_rows
            return rep

    def apply_delta(self, add=None, evict=None) -> IngestReport:
        """Raw-rows ingest: apply ``[N, 3]`` add/evict triple rows to the
        cloud through the same locked path as :meth:`apply_update` (bulk
        loaders and tests write here; SPARQL UPDATE compiles onto it)."""
        from ..rdf.deltas import as_rows
        from ..sparql.update import CompiledUpdate, ground_delta
        cu = CompiledUpdate(
            kind="raw",
            add=as_rows(add if add is not None
                        else np.zeros((0, 3), dtype=np.int64)),
            evict=as_rows(evict if evict is not None
                          else np.zeros((0, 3), dtype=np.int64)))
        with self._placement_lock:
            delta = ground_delta(cu, self.cloud.store)
            rep = self._apply_cloud_delta(delta, cu.touched_predicates())
            rep.kind = "raw"
            return rep

    def _apply_cloud_delta(self, delta,
                           touched: set[int] | None) -> IngestReport:
        """Commit one cloud delta + memo carry-forward + edge propagation.
        Caller holds the placement lock."""
        from ..rdf.deltas import delta_between, rows_at
        t0 = time.perf_counter()
        cloud = self.cloud.store
        v_before = cloud.version
        rep = IngestReport(n_add=delta.n_add, n_evict=delta.n_evict,
                           touched_predicates=(None if touched is None
                                               else sorted(touched)),
                           cloud_version_before=v_before,
                           cloud_version=v_before,
                           placement_epoch=self.placement_epoch)
        if delta.is_noop:
            rep.apply_seconds = time.perf_counter() - t0
            return rep

        old_rows = cloud.triples()               # pre-write content snapshot
        old_entries = self.induced.entries_for(v_before)
        cloud.apply_delta(delta)                 # id-stable shard routing
        rep.cloud_version = cloud.version

        # induced-memo carry-forward: a pattern is untouched iff every edge
        # label is bound AND outside the written predicate set — then its
        # matched-triple content is unchanged and only the global ids moved
        # (stores re-sort on mutation). One bytewise argsort of the new
        # content remaps all survivors.
        survivors: dict[tuple, np.ndarray] = {}
        if old_entries:
            sorted_flat = order = None
            void = np.dtype((np.void, old_rows.dtype.itemsize * 3))
            for key, eids in old_entries.items():
                labels = _pattern_key_labels(key)
                if (touched is None or VAR_PRED_LABEL in labels
                        or labels & touched):
                    rep.patterns_invalidated += 1
                    continue
                if not len(eids):
                    survivors[key] = eids
                    continue
                if sorted_flat is None:
                    new_flat = np.ascontiguousarray(
                        cloud.triples()).view(void).ravel()
                    order = np.argsort(new_flat)
                    sorted_flat = new_flat[order]
                keys = np.ascontiguousarray(
                    old_rows[eids]).view(void).ravel()
                pos = np.searchsorted(sorted_flat, keys)
                # untouched-pattern invariant: every matched row survived
                assert np.array_equal(sorted_flat[pos], keys), \
                    "carry-forward remap lost rows of an untouched pattern"
                survivors[key] = np.sort(order[pos])
        rep.patterns_carried = len(survivors)
        self.induced.install(cloud.version, survivors)

        # version-consistent propagation: every edge with resident data
        # re-derives its residency against the NEW cloud (memo hits for
        # carried patterns, fresh matches for invalidated ones) and takes
        # the content diff through the existing delta pipeline
        for es in self.edges:
            if es.store is None:
                continue
            resident = dict(es._resident)
            target = self.induced.union_edge_ids(cloud,
                                                 list(resident.values()))
            edge_delta = delta_between(es.store, rows_at(cloud, target))
            if not edge_delta.is_noop:
                es.store.apply_delta(edge_delta)
                rep.edges_updated += 1
                rep.shipped_bytes += edge_delta.shipped_bytes
            es._publish(resident, target, cloud.version)
        self.placement_epoch += 1
        rep.placement_epoch = self.placement_epoch
        rep.apply_seconds = time.perf_counter() - t0
        return rep

    def rebalance_pipeline(self, epochs: int = 2,
                           use_deltas: bool = True) -> list[RebalanceReport]:
        """Run ``epochs`` pipelined rebalance passes (compute N+1 overlaps
        commit N; writes admitted between epochs) — see
        :meth:`repro.edge.rebalance.RebalanceManager.run_pipeline`."""
        return self.rebalancer.run_pipeline(epochs=epochs,
                                            use_deltas=use_deltas)

    def rebalance_all(self, use_deltas: bool = True,
                      ) -> dict[int, tuple[int, int]]:
        """Synchronous dynamic placement update across edge servers.

        Runs the full :class:`repro.edge.rebalance.RebalanceManager`
        pipeline inline (incremental induced-id memo, delta shipping,
        epoch-barrier commit) and returns ``{server_id: (n_added,
        n_evicted)}``; the full :class:`~repro.edge.rebalance.
        RebalanceReport` (bytes shipped, per-edge modes, timings) is kept
        on ``self.last_rebalance``. ``use_deltas=False`` re-ships full
        induced subgraphs (the pre-delta data-plane, kept for A/B).
        """
        return self.rebalancer.run(use_deltas=use_deltas).changes

    def rebalance_async(self, use_deltas: bool = True) -> RebalanceHandle:
        """Kick off a rebalance overlapping query rounds (paper §3.2's
        "asynchronous background task"). The expensive compute phase runs
        on a daemon thread; only the commit waits for the round barrier.
        ``handle.join()`` returns the :class:`RebalanceReport`."""
        return self.rebalancer.start(use_deltas=use_deltas)
