"""Closed-loop concurrency benchmark for the serving front end (ISSUE 6).

Measures what the micro-batch admission window actually buys under
concurrent load: a paced client fleet offers queries at a target rate
(``--qps`` levels) against two admission configurations of the SAME
endpoint —

- ``seq``   — ``window_s=0, max_batch=1``: the sequential per-request
              baseline (every query is its own engine dispatch);
- ``coal``  — ``window_s=--window-ms, max_batch=--max-batch``: concurrent
              arrivals coalesce into ONE ``query_many`` engine batch.

Each (mode, temperature, qps) cell reports per-request latency percentiles
and achieved throughput. Pacing is closed-loop with a bounded worker
fleet: arrival *i* is scheduled at ``start + i/qps`` round-robin across
``--workers`` clients; a client that falls behind its schedule sends
immediately (so offered load saturates rather than stacking unbounded
threads), and latency is measured from the *scheduled* arrival — queueing
delay counts, as in any serving benchmark.

Temperatures: ``cold`` clears the endpoint+engine caches right before the
run; ``warm`` primes every workload text once. The warm/saturating cell is
the acceptance gate: coalesced admission must beat sequential on p99 —
batching amortizes the per-dispatch overhead that serializes the baseline.

Rows follow the harness contract (``name,us_per_call,derived`` —
``us_per_call`` is MEAN request latency in microseconds); machine-readable
JSON lands in ``BENCH_serving.json`` (``--json``) and CI uploads it next
to ``BENCH_engine.json``.

An optional end-to-end smoke (``--http``) drives one burst through the
real HTTP listener (sockets included) and reports the coalescing stats
observed by ``GET /stats``.
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.runtime.admission import AdmissionError, AdmissionQueue
from repro.sparql.endpoint import SparqlEndpoint

try:
    from common import emit
except ImportError:                       # invoked as benchmarks/bench_...
    from benchmarks.common import emit


def run_level(ep: SparqlEndpoint, texts: list[str], *, qps: float,
              duration: float, window_s: float, max_batch: int,
              max_queue: int, workers: int, warm: bool) -> dict:
    """Offer ``qps`` for ``duration`` seconds; return latency/throughput."""
    if warm:
        ep.query_many(texts)              # prime result memo + engine LRUs
    else:
        ep.clear_cache()
    n = max(1, int(qps * duration))
    w = min(workers, n)
    lat = np.full(n, np.nan)
    rejected = [0] * w
    expired = [0] * w
    queue = AdmissionQueue(ep, window_s=window_s, max_batch=max_batch,
                           max_queue=max_queue)
    start = time.perf_counter() + 0.05    # common epoch for all clients

    def client(j: int) -> None:
        for i in range(j, n, w):
            due = start + i / qps
            delay = due - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                queue.query(texts[i % len(texts)])
            except AdmissionError as err:
                from repro.runtime.admission import DeadlineExceeded
                if isinstance(err, DeadlineExceeded):
                    expired[j] += 1
                else:
                    rejected[j] += 1
                continue
            # latency from the SCHEDULED arrival: queueing delay counts
            lat[i] = time.perf_counter() - due

    threads = [threading.Thread(target=client, args=(j,)) for j in range(w)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    queue.close(drain=True)
    ok = lat[~np.isnan(lat)]
    st = queue.stats
    return {
        "offered_qps": qps,
        "achieved_qps": float(len(ok) / wall) if wall > 0 else 0.0,
        "completed": int(len(ok)),
        "rejected": int(sum(rejected)), "expired": int(sum(expired)),
        "mean_ms": float(ok.mean() * 1e3) if len(ok) else float("nan"),
        "p50_ms": float(np.percentile(ok, 50) * 1e3) if len(ok) else
        float("nan"),
        "p99_ms": float(np.percentile(ok, 99) * 1e3) if len(ok) else
        float("nan"),
        "batches": st.batches,
        "mean_batch": round(st.mean_batch_size, 2),
        "max_coalesced": st.max_coalesced,
    }


def http_smoke(ep: SparqlEndpoint, texts: list[str], window_s: float,
               max_batch: int, clients: int = 24) -> dict:
    """One concurrent burst through the real HTTP listener.

    Texts are LIMIT-bounded: this cell isolates the serving path (sockets
    + admission + engine), not W3C-JSON encoding of 10k-row tables — the
    in-process cells already charge full result materialization.
    """
    import urllib.request
    from urllib.parse import quote

    from repro.runtime.http import SparqlHttpServer
    texts = [t + " LIMIT 64" for t in texts]
    ep.query_many(texts)                  # warm: overhead, not cold eval
    lat = [0.0] * clients
    with SparqlHttpServer(ep, window_s=window_s,
                          max_batch=max_batch) as srv:
        def client(j: int) -> None:
            url = (srv.url + "/sparql?query="
                   + quote(texts[j % len(texts)]))
            t0 = time.perf_counter()
            with urllib.request.urlopen(url) as r:
                r.read()
            lat[j] = time.perf_counter() - t0

        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.stats_dict()
    return {
        "clients": clients,
        "mean_ms": float(np.mean(lat) * 1e3),
        "max_ms": float(np.max(lat) * 1e3),
        "batches": stats["admission"]["batches"],
        "max_coalesced": stats["admission"]["max_coalesced"],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scale", type=float, default=2.0)
    ap.add_argument("--unique", type=int, default=12,
                    help="distinct query texts in the workload")
    ap.add_argument("--qps", type=str, default="500,4000,40000",
                    help="comma-separated offered-qps levels; the top "
                         "level should exceed the sequential dispatch "
                         "ceiling (~25k qps warm) so the baseline "
                         "actually saturates")
    ap.add_argument("--duration", type=float, default=1.5,
                    help="seconds of offered load per level")
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-queue", type=int, default=8192)
    ap.add_argument("--workers", type=int, default=96,
                    help="client fleet size (in-flight bound)")
    ap.add_argument("--http", action="store_true",
                    help="also run the end-to-end HTTP burst smoke")
    ap.add_argument("--json", type=str, default=None,
                    help="write machine-readable results (BENCH_serving"
                         ".json)")
    args = ap.parse_args()

    g = generate_watdiv_like(scale=args.scale, seed=0)
    texts = workload_sparql(g, args.unique, seed=1)
    levels = [float(x) for x in args.qps.split(",") if x]
    print(f"# serving bench: {g.store.num_triples} triples, "
          f"{len(texts)} distinct texts, levels={levels}, "
          f"window={args.window_ms}ms, max_batch={args.max_batch}")

    modes = {"seq": (0.0, 1), "coal": (args.window_ms * 1e-3,
                                       args.max_batch)}
    rows: list[tuple[str, float, dict]] = []
    cells: dict[tuple, dict] = {}

    def run_cell(mode: str, temp: str, qps: float) -> dict:
        win, mb = modes[mode]
        # fresh endpoint per cell: no cross-cell memo leakage
        ep = SparqlEndpoint(g.store, g.dictionary)
        r = run_level(ep, texts, qps=qps,
                      duration=args.duration, window_s=win,
                      max_batch=mb, max_queue=args.max_queue,
                      workers=args.workers, warm=temp == "warm")
        name = f"serve_{mode}_{temp}_q{int(qps)}"
        derived = {
            "p50_ms": f"{r['p50_ms']:.3f}",
            "p99_ms": f"{r['p99_ms']:.3f}",
            "achieved_qps": f"{r['achieved_qps']:.0f}",
            "completed": r["completed"],
            "rejected": r["rejected"],
            "batches": r["batches"],
            "mean_batch": r["mean_batch"],
            "max_coalesced": r["max_coalesced"],
        }
        emit(name, r["mean_ms"] * 1e3, **derived)
        rows.append((name, r["mean_ms"] * 1e3, {**derived, **r}))
        cells[(mode, temp, qps)] = r
        return r

    for temp in ("cold", "warm"):
        for mode in modes:
            for qps in levels:
                run_cell(mode, temp, qps)

    # -- gate level selection (de-flaked) ---------------------------------
    # The p99 gate is only meaningful when the offered rate exceeds the
    # sequential dispatch ceiling — below it there is no backlog for the
    # window to coalesce and seq-vs-coal p99 is pure noise. If the top
    # configured level failed to saturate the sequential baseline (it
    # achieved >= 80% of offered), auto-raise to 4x the measured
    # sequential throughput and re-run the two warm cells there.
    gate_qps = max(levels)
    gate_ok = True
    seq = cells[("seq", "warm", gate_qps)]
    if seq["achieved_qps"] >= 0.8 * gate_qps:
        boosted = float(round(4.0 * seq["achieved_qps"]))
        print(f"# gate: {int(gate_qps)} qps did not saturate the "
              f"sequential baseline (achieved "
              f"{seq['achieved_qps']:.0f} qps) — auto-raising the gate "
              f"level to {int(boosted)} qps")
        for mode in modes:
            run_cell(mode, "warm", boosted)
        gate_qps = boosted
        seq = cells[("seq", "warm", gate_qps)]
        if seq["achieved_qps"] >= 0.8 * gate_qps:
            gate_ok = False
            print("# WARNING: sequential dispatch still keeps up at "
                  f"{int(gate_qps)} offered qps (achieved "
                  f"{seq['achieved_qps']:.0f}); this machine/workload has "
                  "no dispatch backlog to amortize — SKIPPING the "
                  "coalesced-p99 gate")

    if args.http:
        ep = SparqlEndpoint(g.store, g.dictionary)
        r = http_smoke(ep, texts, args.window_ms * 1e-3, args.max_batch)
        emit("serve_http_burst", r["mean_ms"] * 1e3,
             clients=r["clients"], batches=r["batches"],
             max_coalesced=r["max_coalesced"],
             max_ms=f"{r['max_ms']:.3f}")
        rows.append(("serve_http_burst", r["mean_ms"] * 1e3, r))

    if args.json:
        payload = {
            "meta": {
                "bench": "bench_serving",
                "timestamp": time.time(),
                "scale": args.scale,
                "num_triples": int(g.store.num_triples),
                "unique_texts": len(texts),
                "qps_levels": levels,
                "duration_s": args.duration,
                "window_ms": args.window_ms,
                "max_batch": args.max_batch,
                "workers": args.workers,
                "http_smoke": bool(args.http),
            },
            "rows": [{"name": n, "us_per_call": round(us, 3),
                      "derived": d} for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    # acceptance gate (ISSUE 6): at a genuinely saturating offered rate,
    # warm, coalesced micro-batch admission must beat sequential on p99
    if gate_ok:
        seq99 = cells[("seq", "warm", gate_qps)]["p99_ms"]
        coal99 = cells[("coal", "warm", gate_qps)]["p99_ms"]
        print(f"# warm @ {int(gate_qps)} qps: seq p99={seq99:.3f}ms "
              f"coal p99={coal99:.3f}ms")
        assert coal99 < seq99, (
            f"coalesced admission (p99 {coal99:.3f}ms) should beat "
            f"sequential per-request (p99 {seq99:.3f}ms) at "
            f"{gate_qps:.0f} offered qps warm")


if __name__ == "__main__":
    main()
