"""Per-backend batch throughput of the BGP query engine, with a shard axis.

Contract (benchmarks/common.py): ``name,us_per_call,derived`` CSV rows —
``us_per_call`` is microseconds per *query* (per *scan* for ``scan_*`` rows).
Modes:

- ``engine_loop``         per-query ``match_bgp`` calls (the pre-engine path)
- ``engine_numpy_batch``  engine batch, NumPy backend, cold cache
- ``engine_numpy_warm``   same batch again: LRU result-cache hits
- ``engine_jax_batch``    engine batch, ``triple_scan`` Pallas backend
                          (interpret mode off-TPU — compiled on TPU; the CPU
                          number is an upper bound, reported for completeness)
- ``..._s{S}``            the same against a ``ShardedTripleStore`` with S
                          predicate-hash shards
- ``scan_{backend}_*``    candidate-scan microbench: one ``prescan`` of the
                          workload's deduplicated bound-predicate patterns.
                          This isolates shard pruning: on the monolithic
                          store the JAX backend streams all T triples per
                          scan, on the sharded store only the owning shard's
                          ~T/S — the sharded scan should win on
                          bound-predicate workloads (the common case).

``--join`` adds the join-pipeline axis (PR 3):

- ``join_shard_s{S}`` /   cold engine batches on the sharded store with the
  ``join_global_s{S}``    shard-local presorted join pipeline vs the global
                          scan+argsort baseline (``shard_local_joins=False``)
                          — per-phase timings (prescan/join seconds) and
                          ``JoinStats`` counters land in ``derived``.
- ``round_seq_s{S}``      one multi-edge ``EdgeCloudSystem`` scheduling round
  ``round_thread_s{S}``   executed sequentially, with per-server batches
  ``round_process_s{S}``  through a thread pool (``overlap=True`` — wins
                          where the hot paths release the GIL), and through
                          the persistent fork pool (``overlap="process"`` —
                          true parallelism for the GIL-bound numpy path);
                          the number reported is the execute phase's wall
                          clock, best of interleaved repeats.

``--algebra`` adds the SPARQL algebra axis (PR 5): three operator-heavy
workloads (FILTER-heavy, OPTIONAL-heavy / left-joins, UNION fan-out) run
through :class:`repro.sparql.endpoint.SparqlEndpoint` on the largest
sharded store, cold (caches cleared) and warm (repeated texts hit the
endpoint's version-keyed full-result memo; distinct-but-alpha-equivalent
sub-BGPs hit the engine's result LRU) —
``algebra_{filter,optional,union}_{cold,warm}_s{S}`` rows with per-operator
counters (``bgp_leaves`` / ``filters_applied`` / ``optional_joins`` /
``union_branches``) in ``derived``. Warm must beat cold: that is the
cache-reuse contract of compiling algebra onto the batched BGP engine.

``--rebalance`` adds the placement data-plane axis (PR 4): two identically
drifted systems rebalance with full re-ship vs delta shipping
(``rebalance_full_s{S}`` / ``rebalance_delta_s{S}`` — wall clock per
rebalance, modeled wire bytes in ``derived``; delta must move strictly
fewer bytes at the 100k+ scale), and a rebalance+round pair runs
sequentially vs overlapped (``round_rebalance_sync_s{S}`` /
``round_rebalance_overlap_s{S}`` — the async compute phase overlaps the
round; commit waits at the epoch barrier).

``--kernels`` adds the device-kernel axis (PR 7):

- ``kernel_triple_scan_many``   batched candidate scan: Q deduplicated
                                patterns x T triples in one launch; derived
                                reports bytes streamed per scan and the
                                achieved GB/s (compare against the roofline's
                                memory-bound peak)
- ``kernel_probe_sorted_many``  the sorted-probe join kernel over the hottest
                                predicate's sorted index
- ``engine_jax_{device,host}_s{S}``  cold engine batches with the
                                device-resident join pipeline vs the forced
                                host path (``device_resident=False``) —
                                ``host_transfers`` / ``transfer_bytes`` /
                                ``scalar_syncs`` in ``derived`` record the
                                one-bulk-transfer-per-batch contract

``--partial`` adds the collaborative partial-evaluation axis (PR 8): a
bandwidth-constrained placement where two edges each hold ONE leaf of a
two-leaf join runs one scheduling round with the three-way scheduler
(``round_partial_eval``) vs the legacy binary cloud-only scheduler
(``round_cloudonly_eval``, ``enable_partial=False``) — Eq. 5
modeled/realized response times, cloud-server wall, and
``partial_bytes_shipped`` vs the full induced-subgraph re-ship bytes
land in ``derived``; partial must win response time AND ship fewer
bytes than full re-ship.

The workload repeats a pool of template queries (users re-issue hot
queries), so scan dedup and the result cache both engage — the acceptance
targets are ``engine_numpy_batch`` beating ``engine_loop`` on a >=64-query
batch over a >=100k-triple store, and sharded ``scan_jax`` beating the
monolithic scan at the same scale.

Timings are also written as machine-readable JSON (``--json``, default
``BENCH_engine.json``) so the perf trajectory is tracked across PRs; CI
uploads it as an artifact.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.engine import QueryEngine, get_backend, scan_key
from repro.sparql.matcher import match_bgp
from repro.sparql.query import parse_query, parse_sparql


def bench(fn, n_calls: int, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / n_calls




def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=15.0,
                    help="graph scale (15 ~= 100k+ triples)")
    ap.add_argument("--batch", type=int, default=96,
                    help="queries per batch (>=64 for the acceptance run)")
    ap.add_argument("--unique", type=int, default=16,
                    help="distinct query texts in the pool")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", type=str, default="4,8",
                    help="comma-separated shard counts for the sharded-store "
                         "axis ('' disables)")
    ap.add_argument("--json", type=str, default="BENCH_engine.json",
                    help="write timings as machine-readable JSON "
                         "('' disables)")
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the interpret-mode JAX backend (slow off-TPU)")
    ap.add_argument("--join", action="store_true",
                    help="join-pipeline axis: shard-local vs global joins "
                         "+ overlapped vs sequential multi-edge rounds")
    ap.add_argument("--algebra", action="store_true",
                    help="SPARQL algebra axis: FILTER-heavy / "
                         "OPTIONAL-heavy / UNION fan-out workloads through "
                         "SparqlEndpoint, cold vs warm")
    ap.add_argument("--rebalance", action="store_true",
                    help="placement data-plane axis: full re-ship vs delta "
                         "rebalance bytes/wall-clock + sync vs overlapped "
                         "rebalance-round pairs")
    ap.add_argument("--kernels", action="store_true",
                    help="device-kernel axis (PR 7): triple_scan_many / "
                         "probe_sorted_many throughput + the device-resident "
                         "vs host join pipeline with transfer accounting")
    ap.add_argument("--partial", action="store_true",
                    help="collaborative partial-evaluation axis (PR 8): a "
                         "bandwidth-constrained multi-edge placement where "
                         "no single edge holds every leaf — partial "
                         "(edge-set -> cloud assembler) vs the cloud-only "
                         "legacy round on Eq. 5 response time and shipped "
                         "bytes")
    ap.add_argument("--round-edges", type=int, default=4,
                    help="edge servers in the --join/--rebalance rounds")
    args = ap.parse_args()
    if args.batch < 1 or args.unique < 1 or args.scale <= 0:
        ap.error("--batch/--unique must be >= 1 and --scale > 0")
    shard_counts = [int(x) for x in args.shards.split(",") if x.strip()]
    if any(s < 1 for s in shard_counts):
        ap.error("--shards entries must be >= 1")

    g = generate_watdiv_like(scale=args.scale, seed=0)
    texts = workload_sparql(g, args.unique, seed=123)
    pool = [parse_sparql(t, g.dictionary) for t in texts]
    queries = [pool[i % len(pool)] for i in range(args.batch)]
    stores = [("", g.store)]
    stores += [(f"_s{S}", ShardedTripleStore.from_store(g.store, S))
               for S in shard_counts]
    # deduplicated candidate scans of the pool — all templates use bound
    # predicates, so this is the partition-pruned common case
    scan_tps = list({scan_key(tp): tp
                     for q in pool for tp in q.patterns}.values())
    print(f"# store: {g.store.num_triples} triples, "
          f"{g.store.num_entities} entities; batch {len(queries)} "
          f"({len(pool)} unique, {len(scan_tps)} distinct scans); "
          f"shards {shard_counts or '-'}")

    rows: list[tuple[str, float, str]] = []

    t_loop = bench(lambda: [match_bgp(g.store, q) for q in queries],
                   len(queries), args.repeats)
    rows.append(("engine_loop", t_loop * 1e6, "backend=none"))

    t_scan: dict[tuple[str, str], float] = {}   # (backend, suffix) -> s/scan

    def bench_backend(backend: str, suffix: str, store, repeats: int) -> float:
        eng = QueryEngine(backend=backend)

        def cold():
            eng.clear_cache()
            eng.execute_batch(store, queries)
        t_cold = bench(cold, len(queries), repeats)
        s = eng.stats
        rows.append((f"engine_{backend}_batch{suffix}", t_cold * 1e6,
                     f"backend={backend}|scans_deduped={s.scans_deduped}"
                     f"|speedup_vs_loop={t_loop / t_cold:.2f}x"))
        # scan microbench: prescan the deduplicated bound-predicate pool
        # directly (bypasses the engine's scan LRU)
        be = eng.backend
        be.prescan(store, scan_tps)              # stage arrays / compile
        t_s = bench(lambda: be.prescan(store, scan_tps), len(scan_tps),
                    repeats)
        t_scan[(backend, suffix)] = t_s
        rows.append((f"scan_{backend}{suffix}", t_s * 1e6,
                     f"backend={backend}|scans={len(scan_tps)}"))
        return t_cold

    t_cold = bench_backend("numpy", "", g.store, args.repeats)

    eng = QueryEngine(backend="numpy")
    eng.execute_batch(g.store, queries)          # prime
    t_warm = bench(lambda: eng.execute_batch(g.store, queries),
                   len(queries), args.repeats)
    rows.append(("engine_numpy_warm", t_warm * 1e6,
                 f"backend=numpy|cache=hit"
                 f"|speedup_vs_loop={t_loop / t_warm:.2f}x"))

    for suffix, store in stores[1:]:
        bench_backend("numpy", suffix, store, args.repeats)

    # ---- join-pipeline axis (--join): shard-local vs global joins ---------
    # Runs BEFORE the jax section: interpret-mode Pallas leaves XLA worker
    # threads and staged device buffers behind that perturb the wall-clock
    # A/B below. Reps are interleaved shard/global for the same reason.
    t_join: dict[str, float] = {}
    t_round: dict[str, float] = {}
    if args.join and shard_counts:
        S = max(shard_counts)
        store_s = dict(stores)[f"_s{S}"]
        join_engines = {mode: QueryEngine(backend="numpy",
                                          shard_local_joins=flag)
                        for mode, flag in (("shard", True),
                                           ("global", False))}
        t_join = {mode: float("inf") for mode in join_engines}
        join_reps = max(3, args.repeats)
        for _ in range(join_reps):                   # interleaved best-of
            for mode, eng in join_engines.items():
                eng.clear_cache()
                t0 = time.perf_counter()
                eng.execute_batch(store_s, queries)
                t_join[mode] = min(t_join[mode],
                                   (time.perf_counter() - t0)
                                   / len(queries))
        for mode, eng in join_engines.items():
            # stats accumulate over all repeats (clear_cache keeps them);
            # report per-repeat values (exact for counters, mean for the
            # phase seconds) so they pair with the per-repeat best-of time
            js = eng.stats.join
            rows.append((
                f"join_{mode}_s{S}", t_join[mode] * 1e6,
                f"backend=numpy|shard_local={eng.shard_local_joins}"
                f"|pred_index_joins={js.joins_pred_index // join_reps}"
                f"|vertex_joins={js.joins_vertex // join_reps}"
                f"|pred_var_joins={js.joins_pred_var // join_reps}"
                f"|merged_joins={js.merged_joins // join_reps}"
                f"|prescan_s={eng.stats.prescan_seconds / join_reps:.4f}"
                f"|join_s={eng.stats.join_seconds / join_reps:.4f}"))
        rows[-2] = (rows[-2][0], rows[-2][1], rows[-2][2] +
                    f"|speedup_vs_global="
                    f"{t_join['global'] / t_join['shard']:.2f}x")

        # ---- overlapped vs sequential multi-edge round --------------------
        from repro.core.cost import SystemParams
        from repro.edge.system import EdgeCloudSystem
        K = max(2, args.round_edges)
        params = SystemParams.synthetic(n_users=max(8, 2 * K), n_edges=K,
                                        seed=5)
        sys_ = EdgeCloudSystem(store_s, g.dictionary, params,
                               storage_budgets=10**9, backend="numpy")
        sys_.prepare([texts for _ in range(params.N)])
        round_queries = [(i % params.N, q) for i, q in enumerate(queries)]
        servers = len(sys_.run_round_batched(            # warm indexes
            round_queries, policy="greedy",
            observe=False).assignment_counts)

        # explicit mode strings: overlap=True now auto-picks process for
        # numpy engines, so the thread row must ask for threads by name
        modes = (("seq", False), ("thread", "thread"),
                 ("process", "process"))
        t_round = {name: float("inf") for name, _ in modes}
        mode_seen = {name: "seq" for name, _ in modes}
        for _ in range(max(3, args.repeats)):            # interleaved
            for name, ov in modes:
                sys_.clear_engine_caches()
                rep = sys_.run_round_batched(round_queries, policy="greedy",
                                             observe=False, overlap=ov)
                t_round[name] = min(t_round[name], rep.execute_wall_seconds)
                mode_seen[name] = rep.overlap_mode or "seq"
        sys_.close_overlap_pool()
        for name, _ in modes:
            extra = ("" if name == "seq" else
                     f"|speedup_vs_seq={t_round['seq'] / t_round[name]:.2f}x")
            rows.append((f"round_{name}_s{S}", t_round[name] * 1e6,
                         f"backend=numpy|edges={K}|servers={servers}"
                         f"|batch={len(round_queries)}"
                         f"|mode={mode_seen[name]}{extra}"))

    # ---- SPARQL algebra axis (--algebra) ----------------------------------
    t_alg: dict[tuple[str, str], float] = {}
    if args.algebra:
        from repro.sparql.endpoint import SparqlEndpoint
        S = max(shard_counts) if shard_counts else None
        alg_suffix = f"_s{S}" if S else ""
        store_a = dict(stores)[alg_suffix] if S else g.store
        n_c = min(8, len(g.class_of["Country"]))
        workloads = {
            "filter": [
                f'SELECT ?x ?c WHERE {{ ?x <country> ?c . ?x <likes> ?p . '
                f'FILTER (?c != "Country{k}" && REGEX(?c, "Country[0-9]$")) '
                f'}}' for k in range(n_c)],
            "optional": [
                f'SELECT ?x ?g ?rt WHERE {{ ?x <likes> ?p . '
                f'OPTIONAL {{ ?p <hasGenre> ?g }} . '
                f'OPTIONAL {{ ?p <retailedBy> ?rt }} . '
                f'?x <country> ?c . FILTER (?c = "Country{k}") }}'
                for k in range(n_c)],
            "union": [
                f'SELECT ?x ?y WHERE {{ '
                f'{{ ?x <follows> ?y }} UNION {{ ?x <likes> ?y }} '
                f'UNION {{ ?x <makesPurchase> ?y }} . '
                f'?x <country> ?c . FILTER (?c = "Country{k}") }}'
                for k in range(n_c)],
        }
        for name, pool_t in workloads.items():
            batch_t = [pool_t[i % len(pool_t)] for i in range(args.batch)]
            ep = SparqlEndpoint(store_a, g.dictionary, backend="numpy")

            def cold():
                ep.clear_cache()
                ep.query_many(batch_t)
            t_c = bench(cold, len(batch_t), args.repeats)
            ep.query_many(batch_t)               # prime
            t_w = bench(lambda: ep.query_many(batch_t), len(batch_t),
                        args.repeats)
            t_alg[(name, "cold")] = t_c
            t_alg[(name, "warm")] = t_w
            s = ep.stats
            ops = (f"bgp_leaves={s.bgp_leaves}"
                   f"|filters={s.filters_applied}"
                   f"|optional_joins={s.optional_joins}"
                   f"|union_branches={s.union_branches}")
            rows.append((f"algebra_{name}_cold{alg_suffix}", t_c * 1e6,
                         f"backend=numpy|workload={name}|{ops}"))
            rows.append((f"algebra_{name}_warm{alg_suffix}", t_w * 1e6,
                         f"backend=numpy|workload={name}|cache=hit"
                         f"|speedup_vs_cold={t_c / t_w:.2f}x"))

    # ---- placement data-plane axis (--rebalance) --------------------------
    reb_stats: dict[str, dict] = {}
    if args.rebalance and shard_counts:
        from repro.core.cost import SystemParams
        from repro.edge.system import EdgeCloudSystem
        S = max(shard_counts)
        store_s = dict(stores)[f"_s{S}"]
        K = max(2, args.round_edges)
        # budget admits the whole prepared residency with room for the
        # drift's additions: the regime delta shipping targets is
        # incremental growth/partial overlap (a swap of one of few HUGE
        # patterns is near-total churn, where plan_rebalance's wire-cost
        # fallback re-ships in full — bounded at parity by construction)
        budget = store_s.size_bytes()

        def drifted_system():
            """Deterministic system with *incremental* workload drift:
            prepared on the template pool, then a few new templates turn
            hot on top of it — the regime dynamic placement targets (most
            residency unchanged, a handful of adds/evicts per epoch)."""
            params = SystemParams.synthetic(n_users=max(8, 2 * K),
                                            n_edges=K, seed=11)
            sys_ = EdgeCloudSystem(store_s, g.dictionary, params,
                                   storage_budgets=budget, backend="numpy")
            sys_.prepare([texts for _ in range(params.N)])
            drift_texts = texts + workload_sparql(
                g, max(4, args.unique // 2), seed=777)
            dq = [(i % params.N, parse_sparql(t, g.dictionary))
                  for i, t in enumerate(drift_texts)]
            for _ in range(3):
                sys_.run_round_batched(dq, policy="greedy", execute=False)
            return sys_, dq

        for mode, use_deltas in (("full", False), ("delta", True)):
            sys_r, dq = drifted_system()
            t0 = time.perf_counter()
            sys_r.rebalance_all(use_deltas=use_deltas)
            dt = time.perf_counter() - t0
            rep = sys_r.last_rebalance
            reb_stats[mode] = {
                "wall": dt, "bytes": rep.shipped_bytes,
                "full_bytes": rep.full_bytes, "changed": rep.changed,
                "matcher_calls": rep.matcher_calls,
                "induced_hits": rep.induced_hits,
                "changes": sum(a + e for a, e in rep.changes.values())}
            rows.append((
                f"rebalance_{mode}_s{S}", dt * 1e6,
                f"backend=numpy|edges={K}|use_deltas={use_deltas}"
                f"|bytes_shipped={rep.shipped_bytes}"
                f"|pattern_changes={reb_stats[mode]['changes']}"
                f"|matcher_calls={rep.matcher_calls}"
                f"|induced_hits={rep.induced_hits}"
                f"|commit_s={rep.commit_seconds:.4f}"))
        if reb_stats["delta"]["bytes"]:
            rows[-1] = (rows[-1][0], rows[-1][1], rows[-1][2] +
                        f"|bytes_vs_full={reb_stats['full']['bytes'] / reb_stats['delta']['bytes']:.1f}x")

        # sync (rebalance then round) vs overlapped (compute || round)
        for mode in ("sync", "overlap"):
            sys_r, dq = drifted_system()
            t0 = time.perf_counter()
            if mode == "sync":
                sys_r.rebalance_all()
                sys_r.run_round_batched(dq, policy="greedy", observe=False)
            else:
                handle = sys_r.rebalance_async()
                sys_r.run_round_batched(dq, policy="greedy", observe=False)
                handle.join(120)
            dt = time.perf_counter() - t0
            reb_stats[f"round_{mode}"] = {"wall": dt}
            extra = ("" if mode == "sync" else
                     f"|speedup_vs_sync="
                     f"{reb_stats['round_sync']['wall'] / dt:.2f}x")
            rows.append((f"round_rebalance_{mode}_s{S}", dt * 1e6,
                         f"backend=numpy|edges={K}|batch={len(dq)}{extra}"))

    # ---- collaborative partial evaluation axis (--partial, PR 8) ----------
    part_stats: dict[str, dict] = {}
    reship = 0
    if args.partial:
        import numpy as np

        from repro.core.cost import SystemParams
        from repro.core.induced import reship_bytes
        from repro.core.pattern import pattern_of
        from repro.edge.system import EdgeCloudSystem
        from repro.sparql.algebra import compile_query

        # Bandwidth-constrained placement: two edges each hold ONE leaf of
        # a two-leaf join, the user->cloud uplink is slow (5 Mbps) and the
        # cloud compute pool is congested (finite F_cloud), while the
        # edge->assembler backhaul is a fast datacenter link — the regime
        # partial evaluation targets. Neither edge can run the whole query,
        # so the legacy binary scheduler's only option is cloud.
        Kp, Np = 2, 4
        pparams = SystemParams(
            F=np.full(Kp, 1.0e9),
            r_edge=np.full((Np, Kp), 75e6),
            r_cloud=np.full(Np, 5e6),
            assoc=np.ones((Np, Kp), dtype=bool),
            r_backhaul=np.full(Kp, 1e9),
            F_cloud=0.05e9,
        )
        d = g.dictionary
        pat_a = pattern_of(parse_sparql(
            "SELECT ?x ?p WHERE { ?x <likes> ?p }", d))
        pat_b = pattern_of(parse_sparql(
            "SELECT ?p ?gn WHERE { ?p <hasGenre> ?gn }", d))
        plan_p = compile_query(parse_query(
            "SELECT ?x ?gn WHERE { { ?x <likes> ?p } "
            "{ ?p <hasGenre> ?gn } }", d), d)
        # one query per round: the shipped-bytes gate compares ONE partial
        # evaluation's binding tables against ONE full induced-subgraph
        # re-ship — q identical partial queries would q-count the tables
        # while full residency ships the subgraph once
        pqueries = [(0, plan_p)]
        reship = reship_bytes(g.store, [pat_a, pat_b])
        for mode, enable in (("partial", True), ("cloudonly", False)):
            sys_p = EdgeCloudSystem(g.store, d, pparams,
                                    storage_budgets=10**9,
                                    enable_partial=enable, backend="numpy")
            sys_p.edges[0].deploy(g.store, [pat_a])
            sys_p.edges[1].deploy(g.store, [pat_b])
            rep = sys_p.run_round_batched(pqueries, policy="bnb",
                                          observe=False)
            n = len(pqueries)
            part_stats[mode] = {
                "modeled": rep.total_modeled_latency / n,
                "realized": rep.total_realized_latency / n,
                "cloud_wall": rep.server_wall_seconds.get(-1, 0.0),
                "partial_queries": rep.partial_queries,
                "bytes": rep.partial_bytes_shipped,
                "fallbacks": rep.partial_fallbacks,
            }
            st = part_stats[mode]
            extra = ""
            if mode == "cloudonly" and part_stats["partial"]["modeled"]:
                extra = (f"|modeled_speedup_of_partial="
                         f"{st['modeled'] / part_stats['partial']['modeled']:.2f}x")
            rows.append((
                f"round_{mode}_eval", rep.execute_wall_seconds / n * 1e6,
                f"backend=numpy|edges={Kp}|batch={n}"
                f"|partial_queries={st['partial_queries']}"
                f"|partial_bytes_shipped={st['bytes']}"
                f"|reship_bytes={reship}"
                f"|modeled_ms={st['modeled'] * 1e3:.3f}"
                f"|realized_ms={st['realized'] * 1e3:.3f}"
                f"|cloud_wall_s={st['cloud_wall']:.4f}"
                f"|fallbacks={st['fallbacks']}{extra}"))

    if not args.skip_jax:
        import jax
        mode = ("compiled" if jax.default_backend() == "tpu"
                else "interpret")
        jax_repeats = max(1, args.repeats - 2)
        for suffix, store in stores:
            bench_backend("jax", suffix, store, jax_repeats)
            rows[-2] = (rows[-2][0], rows[-2][1],
                        rows[-2][2] + f"|pallas={mode}")

    # ---- device-kernel axis (--kernels, PR 7) -----------------------------
    if args.kernels:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from collections import Counter

        from repro.kernels import default_interpret
        from repro.kernels.join_probe import probe_sorted_many
        from repro.kernels.triple_scan import triple_scan_many
        from repro.sparql.engine import JaxBackend

        interp = default_interpret()
        pmode = "interpret" if interp else "compiled"
        kern_repeats = max(1, args.repeats - 2)

        # batched candidate scan over the workload's deduplicated patterns:
        # each of the Q patterns streams all T triple rows (12 B each)
        triples = jnp.asarray(g.store.triples(), jnp.int32)
        pat_mat = jnp.asarray(np.stack(
            [[tp.s if isinstance(tp.s, int) else -1,
              tp.p if isinstance(tp.p, int) else -1,
              tp.o if isinstance(tp.o, int) else -1] for tp in scan_tps]),
            jnp.int32)

        def scan_call():
            jax.block_until_ready(
                triple_scan_many(triples, pat_mat, interpret=interp))

        scan_call()                              # stage + compile
        t_sc = bench(scan_call, len(scan_tps), kern_repeats)
        rows.append((
            "kernel_triple_scan_many", t_sc * 1e6,
            f"backend=jax|pallas={pmode}|patterns={len(scan_tps)}"
            f"|triples={g.store.num_triples}"
            f"|bytes_per_scan={int(triples.nbytes)}"
            f"|achieved_gbps={triples.nbytes / t_sc / 1e9:.3f}"))

        # sorted-probe join kernel over the hottest predicate's index:
        # each probe row streams all K keys (4 B each)
        pid = Counter(tp.p for tp in scan_tps
                      if isinstance(tp.p, int)).most_common(1)[0][0]
        keys = jnp.asarray(g.store.pred_index(pid).s_sorted, jnp.int32)
        rng_p = np.random.default_rng(0)
        probes = jnp.asarray(
            rng_p.integers(0, g.store.num_entities, (8, 1024)), jnp.int32)

        def probe_call():
            jax.block_until_ready(
                probe_sorted_many(keys, probes, interpret=interp))

        probe_call()
        t_pr = bench(probe_call, int(probes.shape[0]), kern_repeats)
        rows.append((
            "kernel_probe_sorted_many", t_pr * 1e6,
            f"backend=jax|pallas={pmode}|keys={int(keys.shape[0])}"
            f"|probes_per_row={int(probes.shape[1])}"
            f"|bytes_per_row={int(keys.nbytes)}"
            f"|achieved_gbps={max(keys.nbytes, 1) / t_pr / 1e9:.3f}"))

        # end-to-end: device-resident join pipeline vs forced host path on
        # the largest sharded store, with the transfer accounting that
        # backs the one-bulk-transfer-per-batch contract
        ks = f"_s{max(shard_counts)}" if shard_counts else ""
        store_k = dict(stores)[ks]
        for dr_name, dr in (("device", True), ("host", False)):
            bk = JaxBackend(device_resident=dr)
            eng_k = QueryEngine(backend=bk)

            def cold_k():
                eng_k.clear_cache()
                eng_k.execute_batch(store_k, queries)

            t_k = bench(cold_k, len(queries), kern_repeats)
            s = eng_k.stats
            rows.append((
                f"engine_jax_{dr_name}{ks}", t_k * 1e6,
                f"backend=jax|pallas={pmode}|device_resident={dr}"
                f"|device_queries={s.device_queries}"
                f"|device_fallbacks={s.device_fallbacks}"
                f"|device_joins={s.join.joins_device}"
                f"|host_transfers={s.host_transfers}"
                f"|transfer_bytes={s.host_transfer_bytes}"
                f"|scalar_syncs={s.scalar_syncs}"))

    for name, us, derived in rows:
        qps = 1e6 / us
        print(f"{name},{us:.1f},{derived}|qps={qps:.0f}")

    if args.json:
        payload = {
            "meta": {
                "bench": "bench_engine",
                "timestamp": time.time(),
                "scale": args.scale,
                "num_triples": int(g.store.num_triples),
                "num_entities": int(g.store.num_entities),
                "batch": len(queries),
                "unique": len(pool),
                "distinct_scans": len(scan_tps),
                "shards": shard_counts,
                "repeats": args.repeats,
                "jax": not args.skip_jax,
                "join_axis": bool(args.join),
                "kernel_axis": bool(args.kernels),
                "algebra_axis": bool(args.algebra),
                "rebalance_axis": bool(args.rebalance),
                "partial_axis": bool(args.partial),
                "round_edges": (args.round_edges
                                if args.join or args.rebalance else None),
            },
            "rows": [{"name": n, "us_per_call": round(us, 3),
                      "qps": round(1e6 / us, 1), "derived": d}
                     for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    assert t_cold < t_loop, "batched engine should beat the per-query loop"
    if (not args.skip_jax and shard_counts
            and g.store.num_triples >= 100_000):
        mono = t_scan[("jax", "")]
        best_s = min(t_scan[("jax", f"_s{S}")] for S in shard_counts)
        assert best_s < mono, (
            f"sharded bound-predicate scan ({best_s * 1e6:.0f}us) should "
            f"beat the monolithic scan ({mono * 1e6:.0f}us)")
    if args.join and shard_counts and g.store.num_triples >= 100_000:
        assert t_join["shard"] < t_join["global"], (
            f"shard-local join ({t_join['shard'] * 1e6:.0f}us/q) should "
            f"beat the global join ({t_join['global'] * 1e6:.0f}us/q)")
        # thread overlap is advisory (GIL-releasing fraction is platform-
        # dependent); the fork pool must genuinely overlap
        assert t_round["process"] < t_round["seq"], (
            f"process-overlapped round ({t_round['process']:.3f}s) should "
            f"beat the sequential round ({t_round['seq']:.3f}s)")
    if args.algebra:
        for name in ("filter", "optional", "union"):
            assert t_alg[(name, "warm")] < t_alg[(name, "cold")], (
                f"warm algebra batch ({name}) should beat cold — leaf BGPs "
                f"must resolve from the result cache")
    if args.partial:
        ps, cs = part_stats["partial"], part_stats["cloudonly"]
        assert ps["partial_queries"] > 0, (
            "the bandwidth-constrained placement should route queries "
            "through the partial (edge-set -> assembler) path")
        assert cs["partial_queries"] == 0, (
            "enable_partial=False must keep the legacy binary assignment")
        assert ps["modeled"] < cs["modeled"], (
            f"partial round modeled response ({ps['modeled'] * 1e3:.3f}ms) "
            f"should beat cloud-only ({cs['modeled'] * 1e3:.3f}ms) on the "
            f"bandwidth-constrained placement")
        # the realized metric now derives server cycles from measured
        # per-phase engine wall (prescan + join seconds), not final row
        # counts alone — it registers the cloud's intermediate join work,
        # so the partial win is GATED on both metrics
        assert ps["realized"] < cs["realized"], (
            f"partial round realized response ({ps['realized'] * 1e3:.3f}"
            f"ms) should beat cloud-only ({cs['realized'] * 1e3:.3f}ms) "
            f"once cloud cycles derive from measured engine wall")
        assert 0 < ps["bytes"] < reship, (
            f"partial binding tables ({ps['bytes']}B) should ship fewer "
            f"bytes than re-shipping the full induced subgraph ({reship}B)")
    if args.rebalance and shard_counts:
        assert reb_stats["delta"]["changed"], (
            "drift workload produced no placement changes — the "
            "full-vs-delta comparison is vacuous")
        if g.store.num_triples >= 100_000:
            assert (reb_stats["delta"]["bytes"]
                    < reb_stats["full"]["bytes"]), (
                f"delta rebalance ({reb_stats['delta']['bytes']}B) should "
                f"ship strictly fewer bytes than full re-ship "
                f"({reb_stats['full']['bytes']}B)")


if __name__ == "__main__":
    main()
