"""Per-backend batch throughput of the BGP query engine.

Contract (benchmarks/common.py): ``name,us_per_call,derived`` CSV rows —
``us_per_call`` is microseconds per *query*. Modes:

- ``loop``         per-query ``match_bgp`` calls (the pre-engine path)
- ``numpy-batch``  engine batch, NumPy backend, cold cache
- ``numpy-warm``   same batch again: LRU result-cache hits
- ``jax-batch``    engine batch, ``triple_scan`` Pallas backend (interpret
                   mode off-TPU — compiled on TPU; the CPU number is an
                   upper bound and reported for completeness)

The workload repeats a pool of template queries (users re-issue hot
queries), so scan dedup and the result cache both engage — the acceptance
target is ``numpy-batch`` beating ``loop`` on a >=64-query batch over a
>=100k-triple store.
"""

from __future__ import annotations

import argparse
import time

from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.sparql.engine import QueryEngine
from repro.sparql.matcher import match_bgp
from repro.sparql.query import parse_sparql


def bench(fn, n_queries: int, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / n_queries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=15.0,
                    help="graph scale (15 ~= 100k+ triples)")
    ap.add_argument("--batch", type=int, default=96,
                    help="queries per batch (>=64 for the acceptance run)")
    ap.add_argument("--unique", type=int, default=16,
                    help="distinct query texts in the pool")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the interpret-mode JAX backend (slow off-TPU)")
    args = ap.parse_args()
    if args.batch < 1 or args.unique < 1 or args.scale <= 0:
        ap.error("--batch/--unique must be >= 1 and --scale > 0")

    g = generate_watdiv_like(scale=args.scale, seed=0)
    texts = workload_sparql(g, args.unique, seed=123)
    pool = [parse_sparql(t, g.dictionary) for t in texts]
    queries = [pool[i % len(pool)] for i in range(args.batch)]
    print(f"# store: {g.store.num_triples} triples, "
          f"{g.store.num_entities} entities; batch {len(queries)} "
          f"({len(pool)} unique)")

    rows: list[tuple[str, float, str]] = []

    t_loop = bench(lambda: [match_bgp(g.store, q) for q in queries],
                   len(queries), args.repeats)
    rows.append(("engine_loop", t_loop * 1e6, "backend=none"))

    eng = QueryEngine(backend="numpy")
    # cold: fresh cache each repeat
    def cold():
        eng.clear_cache()
        eng.execute_batch(g.store, queries)
    t_cold = bench(cold, len(queries), args.repeats)
    s = eng.stats
    rows.append(("engine_numpy_batch", t_cold * 1e6,
                 f"backend=numpy|scans_deduped={s.scans_deduped}"
                 f"|speedup_vs_loop={t_loop / t_cold:.2f}x"))

    eng.execute_batch(g.store, queries)          # prime
    t_warm = bench(lambda: eng.execute_batch(g.store, queries),
                   len(queries), args.repeats)
    rows.append(("engine_numpy_warm", t_warm * 1e6,
                 f"backend=numpy|cache=hit"
                 f"|speedup_vs_loop={t_loop / t_warm:.2f}x"))

    if not args.skip_jax:
        import jax
        jeng = QueryEngine(backend="jax")
        def jax_cold():
            jeng.clear_cache()
            jeng.execute_batch(g.store, queries)
        t_jax = bench(jax_cold, len(queries), max(1, args.repeats - 2))
        mode = ("compiled" if jax.default_backend() == "tpu"
                else "interpret")
        rows.append(("engine_jax_batch", t_jax * 1e6,
                     f"backend=jax|pallas={mode}"))

    for name, us, derived in rows:
        qps = 1e6 / us
        print(f"{name},{us:.1f},{derived}|qps={qps:.0f}")

    assert t_cold < t_loop, "batched engine should beat the per-query loop"


if __name__ == "__main__":
    main()
