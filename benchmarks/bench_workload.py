"""Workload-harness benchmark: verified traffic through the full stack.

Samples a ≥4-shape template mix (star / path / flower / snowflake) from
the live store with :class:`repro.workload.PatternSampler` — every
template carries its exact sample-time cardinality — then replays seeded
Zipf-skewed schedules through the admission queue in three A/B arms:

- ``admission``  — sequential per-request dispatch vs coalesced
  micro-batch admission on a read-only skewed mix (what the window buys
  under template popularity skew: memo/cache hit trajectories included);
- ``scheduler``  — ``mode="round"`` with the greedy placement policy vs
  full branch-and-bound on a system-attached endpoint (per-window
  full-edge / cloud / partial assignment counts and modeled objectives);
- ``writes``     — a churn-style read/write mix (burst arrivals) with
  per-ticket commits vs window-level write coalescing on a LIVE system:
  each commit pays placement propagation, so the arm also reports the
  rebalance churn (placement-epoch movement) the coalescing amortizes.

Acceptance gates (asserted, non-zero exit on failure):

- every served answer in every arm matches its template's recorded
  cardinality (the churn write style never invalidates them — writes ride
  a sampler-excluded predicate with fresh entities);
- no arm produces a single admission/scheduler/engine error;
- round-mode arms account every read in their assignment counts.

Rows follow the harness contract (``name,us_per_call,derived``;
``us_per_call`` is mean request latency); ``--json`` writes
``BENCH_workload.json`` (``{"meta": ..., "rows": [...]}``) for the CI
artifact trail next to ``BENCH_engine.json`` / ``BENCH_serving.json``.
"""

from __future__ import annotations

import argparse
import json

from repro.rdf.generator import generate_watdiv_like
from repro.runtime.admission import AdmissionQueue
from repro.sparql.endpoint import SparqlEndpoint
from repro.workload import (PatternSampler, ShapeConfig, TrafficConfig,
                            build_schedule, replay)
from repro.workload.sampler import SHAPES

try:
    from common import build_system, emit
except ImportError:                       # invoked as benchmarks/bench_...
    from benchmarks.common import build_system, emit

CHURN_PREDICATE = "country"               # reserved for the write mix


def sample_templates(store, dictionary, *, n_per: int, seed: int):
    smp = PatternSampler(store, dictionary, seed=seed,
                         exclude_predicates=[CHURN_PREDICATE])
    cfgs = [ShapeConfig(s, size=3, const_frac=0.3,
                        decorations=(None, "filter", "limit"))
            for s in SHAPES]
    templates = smp.sample_mix(cfgs, n_per)
    got = {q.shape for q in templates}
    assert got == set(SHAPES), f"missing shapes: {set(SHAPES) - got}"
    return templates


def row_from_report(name: str, rep, **extra) -> dict:
    lats = [l for r in list(rep.per_shape.values()) + [rep.writes]
            for l in r.latencies]
    mean_s = sum(lats) / len(lats) if lats else 0.0
    shape_p99 = {f"p99_ms_{s}": round(r.percentiles()["p99"] * 1e3, 3)
                 for s, r in sorted(rep.per_shape.items())}
    row = {"name": name, "us_per_call": round(mean_s * 1e6, 1),
           "completed": rep.completed, "errors": rep.errors,
           "verified": rep.verified, "mismatched": rep.mismatched,
           **shape_p99, **extra}
    emit(name, row["us_per_call"],
         **{k: v for k, v in row.items()
            if k not in ("name", "us_per_call")})
    return row


def gate(name: str, rep, schedule) -> None:
    assert rep.errors == 0, f"{name}: {rep.errors} errors"
    assert rep.completed == len(schedule.events), \
        f"{name}: {rep.completed}/{len(schedule.events)} completed"
    assert rep.verification_ok, \
        f"{name}: cardinality mismatches {rep.mismatches}"
    assert rep.verified == schedule.n_queries, \
        f"{name}: verified {rep.verified} != {schedule.n_queries} reads"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--per-shape", type=int, default=3,
                    help="templates sampled per shape")
    ap.add_argument("--qps", type=float, default=300.0)
    ap.add_argument("--duration", type=float, default=0.6,
                    help="schedule length in seconds (per arm)")
    ap.add_argument("--window-ms", type=float, default=4.0)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--write-fraction", type=float, default=0.25)
    ap.add_argument("--json", type=str, default=None,
                    help="write machine-readable results "
                         "(BENCH_workload.json)")
    args = ap.parse_args()

    rows: list[dict] = []
    g = generate_watdiv_like(scale=args.scale, seed=args.seed)
    templates = sample_templates(g.store, g.dictionary,
                                 n_per=args.per_shape, seed=args.seed)
    print(f"# {len(templates)} templates over {len(SHAPES)} shapes, "
          f"{g.store.num_triples} triples")

    # -- arm 1: sequential vs coalesced admission under skew ---------------
    read_cfg = TrafficConfig(duration_s=args.duration, qps=args.qps,
                             zipf_s=1.2, cold_fraction=0.15,
                             seed=args.seed + 1)
    sched = build_schedule(templates, read_cfg)
    for mode, window_s, max_batch in (
            ("seq", 0.0, 1),
            ("coal", args.window_ms / 1e3, args.max_batch)):
        ep = SparqlEndpoint(g.store, g.dictionary)
        with AdmissionQueue(ep, window_s=window_s,
                            max_batch=max_batch) as q:
            rep = replay(q, sched)
        gate(f"admission_{mode}", rep, sched)
        traj = rep.cache_trajectory
        rows.append(row_from_report(
            f"workload_admission_{mode}", rep,
            batches=len(traj),
            memo_hits=sum(b["memo_hits"] for b in traj),
            engine_cache_hits=sum(b["engine_cache_hits"] for b in traj)))

    # -- arm 2: greedy vs branch-and-bound round scheduling ----------------
    bench = build_system(scale=args.scale, seed=args.seed,
                         n_users=8, n_edges=3)
    # re-deploy edge residency from the SAMPLED templates (every user saw
    # the whole template pool), so the scheduling A/B has edge-eligible
    # patterns to place rather than an unrelated history
    n_users = bench.system.params.assoc.shape[0]
    bench.system.prepare([[q.text for q in templates]
                          for _ in range(n_users)])
    r_sched = build_schedule(templates, TrafficConfig(
        duration_s=args.duration, qps=min(args.qps, 150.0),
        zipf_s=1.2, seed=args.seed + 2))
    for policy in ("greedy", "bnb"):
        bench.system.engine.clear_cache()
        ep = SparqlEndpoint(system=bench.system)
        with AdmissionQueue(ep, window_s=args.window_ms / 1e3,
                            max_batch=8, mode="round",
                            mode_kw={"policy": policy}) as q:
            rep = replay(q, r_sched)
        gate(f"scheduler_{policy}", rep, r_sched)
        counts = {int(k): v for k, v in rep.assignment_counts.items()}
        assert sum(counts.values()) == r_sched.n_queries, \
            f"scheduler_{policy}: unaccounted reads {counts}"
        rows.append(row_from_report(
            f"workload_scheduler_{policy}", rep,
            cloud=counts.get(-1, 0), partial=counts.get(-2, 0),
            edge=sum(v for k, v in counts.items() if k >= 0)))

    # -- arm 3: churn write mix, per-ticket vs coalesced commits -----------
    w_cfg = TrafficConfig(duration_s=args.duration, qps=args.qps,
                          arrival="burst", zipf_s=1.2,
                          write_fraction=args.write_fraction,
                          write_style="churn", seed=args.seed + 3)
    w_sched = build_schedule(templates, w_cfg,
                             churn_predicate=CHURN_PREDICATE)
    assert w_sched.has_writes and w_sched.verifiable
    for mode, coalesce in (("seq", False), ("coal", True)):
        ep = SparqlEndpoint(system=bench.system)
        epoch0 = bench.system.placement_epoch
        with AdmissionQueue(ep, window_s=args.window_ms / 1e3,
                            max_batch=args.max_batch,
                            coalesce_writes=coalesce) as q:
            rep = replay(q, w_sched)
        gate(f"writes_{mode}", rep, w_sched)
        adm = rep.admission
        assert adm["updates_served"] == w_sched.n_updates
        rows.append(row_from_report(
            f"workload_writes_{mode}", rep,
            updates=adm["updates_served"],
            write_commits=adm["write_commits"],
            writes_coalesced=adm["writes_coalesced"],
            epochs=bench.system.placement_epoch - epoch0))

    if args.json:
        payload = {
            "meta": {
                "bench": "workload",
                "scale": args.scale, "seed": args.seed,
                "qps": args.qps, "duration_s": args.duration,
                "shapes": list(SHAPES),
                "templates": len(templates),
                "per_shape": args.per_shape,
                "window_ms": args.window_ms,
                "max_batch": args.max_batch,
                "write_fraction": args.write_fraction,
                "triples": int(g.store.num_triples),
            },
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")
    print("# workload gates passed: all answers matched recorded "
          "cardinalities; zero scheduler/admission errors")


if __name__ == "__main__":
    main()
