"""Shared benchmark harness for the paper's §5 experiment grid.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract): ``us_per_call`` is the per-query modeled response time in
microseconds; ``derived`` carries auxiliary values (edge ratio, schedule
ms, objective, ...) as ``k=v|k=v``.

Sizes are scaled to this CPU container (graph ~20k triples vs the paper's
100M+); the cost model and all *relative* trends are the paper's. See
EXPERIMENTS.md for the mapping.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem, RoundReport
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.sparql.query import parse_sparql

POLICIES = ["cloud_only", "random", "edge_first", "greedy", "bnb"]


@dataclass
class Bench:
    g: object
    system: EdgeCloudSystem
    queries: list


def build_system(n_users: int = 20, n_edges: int = 4, scale: float = 2.0,
                 storage_bytes: int = 400_000, f_ghz: float = 0.2,
                 edge_mbps: float = 75.0, cloud_mbps: float = 5.0,
                 seed: int = 0, history_per_user: int = 5,
                 n_queries: int | None = None) -> Bench:
    g = generate_watdiv_like(scale=scale, seed=seed)
    params = SystemParams.synthetic(
        n_users, n_edges, seed=seed + 1, edge_mbps=edge_mbps,
        cloud_mbps=cloud_mbps, f_ghz=f_ghz)
    system = EdgeCloudSystem(g.store, g.dictionary, params,
                             storage_budgets=storage_bytes)
    history = [workload_sparql(g, history_per_user, seed=1000 + n)
               for n in range(n_users)]
    system.prepare(history)
    nq = n_queries if n_queries is not None else n_users
    texts = workload_sparql(g, nq, seed=7777 + seed)
    queries = [(i % n_users, parse_sparql(t, g.dictionary))
               for i, t in enumerate(texts)]
    return Bench(g=g, system=system, queries=queries)


def run_policies(bench: Bench, policies: list[str] | None = None,
                 execute: bool = True) -> dict[str, RoundReport]:
    out = {}
    for policy in (policies or POLICIES):
        out[policy] = bench.system.run_round(
            bench.queries, policy=policy, execute=execute, observe=False)
    return out


def emit(name: str, us_per_call: float, **derived) -> None:
    d = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}")


def report_row(name: str, rep: RoundReport) -> None:
    n = max(1, len(rep.outcomes))
    edge_frac = 1.0 - rep.assignment_ratio.get(-1, 0.0)
    emit(name,
         rep.total_realized_latency / n * 1e6,
         objective=f"{rep.objective:.3f}",
         edge_ratio=f"{edge_frac:.2f}",
         sched_ms=f"{rep.schedule_seconds * 1e3:.2f}")
