"""Paper §5 experiment grid — one function per table/figure.

Fig. 7/Table 5   storage sweep        Fig. 8/Table 6   compute sweep
Fig. 9/Table 7   bandwidth sweep      Fig. 10          fleet scale
Fig. 11/Table 8  graph size           Fig. 12/Table 9  queries per user
Fig. 13/Table 10 selectivity          Fig. 14          scheduling overhead
Table 11         construction overhead

Each emits CSV rows via benchmarks.common.emit and asserts the paper's
qualitative claims (B&B <= every baseline; trend directions).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.cost import measured_query_cost
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.sparql.query import parse_sparql

from .common import POLICIES, build_system, emit, report_row, run_policies


def _assert_bnb_best(results, context: str) -> None:
    for policy, rep in results.items():
        assert results["bnb"].objective <= rep.objective + 1e-9, \
            f"{context}: bnb lost to {policy}"


def bench_storage(quick: bool = True) -> None:
    """Fig. 7 / Table 5: bigger edge storage -> more resident patterns."""
    budgets = [100_000, 200_000, 400_000, 800_000]
    prev_edge_ratio = -1.0
    for budget in budgets:
        bench = build_system(storage_bytes=budget, seed=0)
        results = run_policies(bench, execute=not quick)
        _assert_bnb_best(results, f"storage={budget}")
        for policy, rep in results.items():
            report_row(f"storage_{budget}b_{policy}", rep)
        edge_ratio = 1.0 - results["bnb"].assignment_ratio.get(-1, 0.0)
        assert edge_ratio >= prev_edge_ratio - 0.25  # rising trend (noisy)
        prev_edge_ratio = max(prev_edge_ratio, edge_ratio)


def bench_compute(quick: bool = True) -> None:
    """Fig. 8 / Table 6: faster edge CPUs -> lower response time."""
    objs = []
    for f_ghz in [0.2, 0.4, 0.6, 0.8]:
        bench = build_system(f_ghz=f_ghz, seed=1)
        results = run_policies(bench, execute=not quick)
        _assert_bnb_best(results, f"f={f_ghz}GHz")
        for policy, rep in results.items():
            report_row(f"compute_{f_ghz}GHz_{policy}", rep)
        objs.append(results["bnb"].objective)
    assert objs[-1] <= objs[0] + 1e-9  # more compute never hurts


def bench_bandwidth(quick: bool = True) -> None:
    """Fig. 9 / Table 7: better edge links -> more edge placement."""
    edge_ratios, objs = [], []
    for mbps in [10, 30, 50, 70]:
        bench = build_system(edge_mbps=float(mbps), seed=2)
        results = run_policies(bench, execute=not quick)
        _assert_bnb_best(results, f"bw={mbps}")
        for policy, rep in results.items():
            report_row(f"bandwidth_{mbps}Mbps_{policy}", rep)
        edge_ratios.append(1.0 - results["bnb"].assignment_ratio.get(-1, 0))
        objs.append(results["bnb"].objective)
    assert objs[-1] <= objs[0] + 1e-9
    assert edge_ratios[-1] >= edge_ratios[0] - 1e-9


def bench_scale(quick: bool = True) -> None:
    """Fig. 10: scale (K, N) together; B&B advantage persists."""
    grid = [(4, 20), (8, 40), (16, 80)] + ([] if quick else [(32, 160)])
    for (K, N) in grid:
        bench = build_system(n_users=N, n_edges=K, scale=2.0, seed=3,
                             history_per_user=4)
        results = run_policies(bench, execute=False)
        _assert_bnb_best(results, f"scale=({K},{N})")
        for policy, rep in results.items():
            report_row(f"scale_K{K}_N{N}_{policy}", rep)


def bench_graph_size(quick: bool = True) -> None:
    """Fig. 11 / Table 8: larger graphs -> higher response times."""
    scales = [1.0, 2.0, 3.0] + ([] if quick else [4.0, 5.0])
    objs = []
    for s in scales:
        bench = build_system(scale=s, storage_bytes=int(200_000 * s), seed=4)
        results = run_policies(bench, execute=not quick)
        _assert_bnb_best(results, f"graph_scale={s}")
        for policy, rep in results.items():
            report_row(f"graphsize_{s:g}x_{policy}", rep)
        objs.append(results["bnb"].objective)
    assert objs[-1] >= objs[0] * 0.8  # grows (roughly) with graph size


def bench_queries_per_user(quick: bool = True) -> None:
    """Fig. 12 / Table 9: 1-4 queries per user."""
    prev = 0.0
    for q_per_user in [1, 2, 3, 4]:
        bench = build_system(n_queries=20 * q_per_user, seed=5)
        results = run_policies(bench, execute=False)
        _assert_bnb_best(results, f"qpu={q_per_user}")
        for policy, rep in results.items():
            report_row(f"qpu_{q_per_user}_{policy}", rep)
        assert results["bnb"].objective >= prev - 1e-9  # workload grows
        prev = results["bnb"].objective


def bench_selectivity(quick: bool = True) -> None:
    """Fig. 13 / Table 10: bucket queries by measured result size."""
    bench = build_system(n_queries=60, seed=6)
    store = bench.system.cloud.store
    buckets: dict[str, list] = {"small": [], "medium": [], "large": []}
    for (u, q) in bench.queries:
        _, w_bits, rows = measured_query_cost(store, q)
        w = w_bits / 8
        if w < 1e3:
            buckets["small"].append((u, q))
        elif w < 2e4:
            buckets["medium"].append((u, q))
        else:
            buckets["large"].append((u, q))
    for name, qs in buckets.items():
        if len(qs) < 2:
            emit(f"selectivity_{name}_bnb", 0.0, note="empty-bucket")
            continue
        for policy in POLICIES:
            rep = bench.system.run_round(qs, policy=policy, execute=True,
                                         observe=False)
            report_row(f"selectivity_{name}_{policy}", rep)


def bench_sched_overhead(quick: bool = True) -> None:
    """Fig. 14: scheduling time share; + the beyond-paper solver ablation."""
    from repro.core.bnb import branch_and_bound
    for (K, N) in [(4, 20), (8, 40), (16, 80)]:
        bench = build_system(n_users=N, n_edges=K, seed=7)
        rep = bench.system.run_round(bench.queries, policy="bnb",
                                     execute=True, observe=False)
        total = rep.total_realized_latency
        share = rep.schedule_seconds / max(total, 1e-12)
        emit(f"sched_overhead_K{K}_N{N}",
             rep.schedule_seconds / max(1, len(bench.queries)) * 1e6,
             sched_ms=f"{rep.schedule_seconds * 1e3:.2f}",
             share=f"{share:.4f}")
        assert share < 0.6, "scheduling dominates response time"
    # ablation: marginal-bound B&B (ours) vs paper-faithful R-QAD bounding
    bench = build_system(n_users=20, n_edges=4, seed=8)
    tasks = bench.system.build_tasks(bench.queries)
    import numpy as np
    users = [u for (u, _) in bench.queries]
    from repro.core.cost import SystemParams
    params = SystemParams(F=bench.system.params.F,
                          r_edge=bench.system.params.r_edge[users],
                          r_cloud=bench.system.params.r_cloud[users],
                          assoc=bench.system.params.assoc[users])
    t0 = time.perf_counter()
    r1 = branch_and_bound(tasks, params, bound="marginal")
    t_marg = time.perf_counter() - t0
    t0 = time.perf_counter()
    r2 = branch_and_bound(tasks, params, bound="rqad", warm_start="cloud",
                          order="given")
    t_rqad = time.perf_counter() - t0
    assert abs(r1.objective - r2.objective) < 1e-6 * max(1, r1.objective)
    emit("bnb_bound_marginal", t_marg * 1e6, nodes=r1.nodes_explored,
         objective=f"{r1.objective:.3f}")
    emit("bnb_bound_rqad_paper", t_rqad * 1e6, nodes=r2.nodes_explored,
         objective=f"{r2.objective:.3f}",
         speedup=f"{t_rqad / max(t_marg, 1e-9):.1f}x")


def bench_construction(quick: bool = True) -> None:
    """Table 11: pattern-induced subgraph construction time vs (K, N)."""
    grid = [(4, 20), (8, 40), (16, 80)] + ([] if quick else [(32, 160)])
    times = []
    for (K, N) in grid:
        t0 = time.perf_counter()
        bench = build_system(n_users=N, n_edges=K, seed=9,
                             history_per_user=4)
        dt = bench.system.construction_seconds
        times.append(dt)
        total_resident = sum(len(es.index) for es in bench.system.edges)
        emit(f"construction_K{K}_N{N}", dt * 1e6 / max(1, K),
             seconds=f"{dt:.3f}", resident_patterns=total_resident)
    # near-linear growth in K (paper's claim): allow generous slack
    assert times[-1] <= times[0] * (grid[-1][0] / grid[0][0]) * 3.0


def bench_matcher(quick: bool = True) -> None:
    """Framework micro-bench: matcher throughput on the cloud store."""
    g = generate_watdiv_like(scale=2.0, seed=10)
    texts = workload_sparql(g, 30, seed=11)
    from repro.sparql.matcher import match_bgp
    total = 0.0
    n_rows = 0
    for t in texts:
        q = parse_sparql(t, g.dictionary)
        t0 = time.perf_counter()
        res = match_bgp(g.store, q)
        total += time.perf_counter() - t0
        n_rows += res.num_matches
    emit("matcher_cloud_store", total / len(texts) * 1e6,
         triples=g.store.num_triples, queries=len(texts),
         total_rows=n_rows)


ALL = [bench_storage, bench_compute, bench_bandwidth, bench_scale,
       bench_graph_size, bench_queries_per_user, bench_selectivity,
       bench_sched_overhead, bench_construction, bench_matcher]


def bench_induced_methods(quick: bool = True) -> None:
    """Beyond-paper: exact (Def. 5) vs semijoin full-reducer construction.

    The semijoin path never enumerates matches — exact for acyclic patterns,
    a sound superset for cyclic ones. Reports speedup + size overhead.
    """
    from repro.core.induced import (induced_edge_ids,
                                    induced_edge_ids_semijoin)
    from repro.core.pattern import pattern_of

    g = generate_watdiv_like(scale=4.0, seed=21)
    texts = workload_sparql(g, 12, seed=22)
    pats = []
    seen = set()
    for t in texts:
        p = pattern_of(parse_sparql(t, g.dictionary))
        if p.indexable and p.key not in seen:
            seen.add(p.key)
            pats.append(p)
    t0 = time.perf_counter()
    exact = induced_edge_ids(g.store, pats)
    t_exact = time.perf_counter() - t0
    t0 = time.perf_counter()
    semi = induced_edge_ids_semijoin(g.store, pats)
    t_semi = time.perf_counter() - t0
    assert set(exact.tolist()) <= set(semi.tolist())  # sound superset
    emit("induced_exact", t_exact * 1e6 / max(1, len(pats)),
         edges=len(exact), patterns=len(pats))
    emit("induced_semijoin", t_semi * 1e6 / max(1, len(pats)),
         edges=len(semi),
         size_overhead=f"{len(semi) / max(1, len(exact)):.3f}",
         speedup=f"{t_exact / max(t_semi, 1e-9):.1f}x")


ALL.append(bench_induced_methods)
