"""Sustained mixed read/write ingest benchmark (ISSUE 9).

Measures what the live-ingest write path costs concurrent readers — and
gates that it stays bounded. One sharded edge-cloud system serves a
closed-loop reader fleet through the micro-batch admission queue
(``mode="round"``: every read is a scheduled round under the placement
lock, exactly the path writes and rebalance commits contend with) in two
phases:

- ``base``  — readers only: the read-only p99 baseline.
- ``mixed`` — the same fleet, plus a writer issuing ``INSERT DATA`` /
              ``DELETE DATA`` through the SAME admission queue (writes
              serialize against the micro-batch windows they invalidate),
              plus a multi-epoch **pipelined rebalance**
              (``RebalanceManager.run_pipeline``) running mid-phase — the
              continuous-ingest regime where placement maintenance must
              never block reads.

Acceptance gates (process exits nonzero on violation):

- the pipelined rebalance commits ``>= --epochs`` placement epochs while
  the mixed traffic runs;
- mixed-phase read p99 stays within ``--factor`` of
  ``max(base p99, 2 * window)`` — write traffic and rebalances may tax
  reads but never wedge them behind a stop-the-world ingest;
- post-quiesce, every populated edge replica sits at the cloud store's
  exact version and one scheduled round is bit-identical to the cloud
  oracle.

Rows follow the harness contract (``name,us_per_call,derived`` —
``us_per_call`` is MEAN read latency); ``--json`` writes
``BENCH_ingest.json`` for CI upload next to the other bench artifacts.
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core.cost import SystemParams
from repro.core.pattern import pattern_of
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.sharding import ShardedTripleStore
from repro.runtime.admission import AdmissionError, AdmissionQueue
from repro.sparql.endpoint import SparqlEndpoint
from repro.sparql.query import parse_sparql

try:
    from common import emit
except ImportError:                       # invoked as benchmarks/bench_...
    from benchmarks.common import emit

LEAVES = {
    0: ["SELECT ?x ?p WHERE { ?x <likes> ?p }"],
    1: ["SELECT ?p ?gn WHERE { ?p <hasGenre> ?gn }",
        "SELECT ?x ?y WHERE { ?x <follows> ?y }"],
    2: ["SELECT ?x ?c WHERE { ?x <country> ?c }"],
}


def build_system(g, shards: int) -> EdgeCloudSystem:
    store = ShardedTripleStore.from_store(g.store, num_shards=shards)
    K, N = 3, 4
    params = SystemParams(
        F=np.full(K, 1.0e9),
        r_edge=np.full((N, K), 75e6),
        r_cloud=np.full(N, 5e6),
        assoc=np.ones((N, K), dtype=bool),
        r_backhaul=np.full(K, 1e9),
        F_cloud=0.05e9,
    )
    sys_ = EdgeCloudSystem(store, g.dictionary, params,
                           storage_budgets=10_000_000, backend="numpy")
    for k, texts in LEAVES.items():
        sys_.edges[k].deploy(store, [pattern_of(parse_sparql(
            t, g.dictionary)) for t in texts])
    return sys_


def read_phase(queue: AdmissionQueue, texts: list[str], *,
               duration: float, readers: int) -> np.ndarray:
    """Closed-loop reader fleet: each client issues back-to-back reads
    until the deadline; returns the per-request latencies (seconds)."""
    lats: list[list[float]] = [[] for _ in range(readers)]
    deadline = time.perf_counter() + duration

    def client(j: int) -> None:
        i = j
        while time.perf_counter() < deadline:
            t0 = time.perf_counter()
            try:
                queue.query(texts[i % len(texts)], user=i % 4)
            except AdmissionError:
                i += 1
                continue
            lats[j].append(time.perf_counter() - t0)
            i += 1

    threads = [threading.Thread(target=client, args=(j,))
               for j in range(readers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return np.array([x for row in lats for x in row])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--duration", type=float, default=1.5,
                    help="seconds of offered load per phase")
    ap.add_argument("--readers", type=int, default=6)
    ap.add_argument("--write-interval-ms", type=float, default=5.0,
                    help="writer think time between updates")
    ap.add_argument("--epochs", type=int, default=2,
                    help="pipelined rebalance epochs during the mixed "
                         "phase (the gate requires all to commit)")
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--factor", type=float, default=30.0,
                    help="mixed p99 must stay within this factor of "
                         "max(base p99, 2*window)")
    ap.add_argument("--json", type=str, default=None,
                    help="write machine-readable results (BENCH_ingest"
                         ".json)")
    args = ap.parse_args()

    g = generate_watdiv_like(scale=args.scale, seed=0)
    sys_ = build_system(g, args.shards)
    store = sys_.cloud.store
    ep = SparqlEndpoint(system=sys_)
    texts = workload_sparql(g, 8, seed=1)
    window_s = args.window_ms * 1e-3
    queue = AdmissionQueue(ep, window_s=window_s, max_batch=64,
                           max_queue=4096, mode="round",
                           mode_kw={"policy": "greedy"})
    print(f"# ingest bench: {store.num_triples} triples, "
          f"{args.shards} shards, {args.readers} readers, "
          f"{args.duration}s/phase, window={args.window_ms}ms")

    # -- phase 1: read-only baseline -----------------------------------------
    ep.query_many(texts)                  # warm plans + engine LRUs
    base = read_phase(queue, texts, duration=args.duration,
                      readers=args.readers)
    base_p99 = float(np.percentile(base, 99))

    # -- phase 2: mixed read/write with pipelined rebalances ------------------
    writes_done = [0]
    stop_writer = threading.Event()
    writer_err: list[BaseException] = []

    def writer() -> None:
        i = 0
        try:
            while not stop_writer.is_set():
                if i % 3 == 2:
                    text = (f"DELETE DATA {{ <ing_u{i - 1}> <likes> "
                            f"<ing_p{i - 1}> }}")
                else:
                    text = (f"INSERT DATA {{ <ing_u{i}> <likes> "
                            f"<ing_p{i}> . <ing_u{i}> <country> "
                            f"<ing_c{i % 2}> }}")
                queue.query(text)         # writes ride the same admission
                writes_done[0] += 1
                i += 1
                time.sleep(args.write_interval_ms * 1e-3)
        except BaseException as err:
            writer_err.append(err)

    pipe_reports: list = []
    pipe_err: list[BaseException] = []

    def rebalancer() -> None:
        time.sleep(args.duration * 0.25)  # mid-phase, under live traffic
        try:
            pipe_reports.extend(
                sys_.rebalancer.run_pipeline(epochs=args.epochs))
        except BaseException as err:
            pipe_err.append(err)

    wt = threading.Thread(target=writer, name="ingest-writer")
    rt = threading.Thread(target=rebalancer, name="ingest-rebalance")
    wt.start()
    rt.start()
    mixed = read_phase(queue, texts, duration=args.duration,
                       readers=args.readers)
    stop_writer.set()
    wt.join(15.0)
    rt.join(30.0)
    queue.close(drain=True)
    mixed_p99 = float(np.percentile(mixed, 99))

    # -- post-quiesce consistency --------------------------------------------
    for es in sys_.edges:
        if es.store is not None:
            assert es.resident_cloud_version == store.version, (
                f"edge ES{es.server_id} replica at "
                f"{es.resident_cloud_version}, cloud at {store.version}")
    queries = [(i % 4, parse_sparql(t, g.dictionary))
               for i, t in enumerate(texts)]
    rep = sys_.run_round_batched(queries, policy="greedy", execute=True,
                                 collect_results=True)

    def rows_of(res):                     # column-order-independent rows
        idx = [res.var_names.index(v) for v in sorted(res.var_names)]
        return sorted(map(tuple, res.bindings[:, idx].tolist()))

    for (res, (_, q)) in zip(rep.results, queries):
        want = sys_.engine.execute(store, q)
        assert rows_of(res) == rows_of(want), (
            "scheduled round diverged from the cloud oracle post-ingest")

    floor = 2.0 * window_s
    rows = [
        ("read_base", float(base.mean() * 1e6),
         {"p50_ms": round(float(np.percentile(base, 50)) * 1e3, 3),
          "p99_ms": round(base_p99 * 1e3, 3), "n": int(len(base))}),
        ("read_mixed", float(mixed.mean() * 1e6),
         {"p50_ms": round(float(np.percentile(mixed, 50)) * 1e3, 3),
          "p99_ms": round(mixed_p99 * 1e3, 3), "n": int(len(mixed)),
          "writes": writes_done[0],
          "rebalance_epochs": len(pipe_reports)}),
    ]
    for name, us, derived in rows:
        emit(name, us, **derived)

    if args.json:
        payload = {
            "meta": {
                "bench": "bench_ingest",
                "timestamp": time.time(),
                "scale": args.scale, "shards": args.shards,
                "num_triples": int(store.num_triples),
                "readers": args.readers, "duration": args.duration,
                "window_ms": args.window_ms, "factor": args.factor,
                "epochs_requested": args.epochs,
            },
            "rows": [{"name": n, "us_per_call": round(us, 3), **d}
                     for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    assert not writer_err, writer_err
    assert not pipe_err, pipe_err
    assert writes_done[0] > 0, "writer made no progress"
    assert len(pipe_reports) >= args.epochs, (
        f"pipelined rebalance committed {len(pipe_reports)} epochs, "
        f"wanted >= {args.epochs}")
    bound = args.factor * max(base_p99, floor)
    assert mixed_p99 <= bound, (
        f"mixed read p99 ({mixed_p99 * 1e3:.2f}ms) blew past "
        f"{args.factor}x the read-only baseline "
        f"(p99 {base_p99 * 1e3:.2f}ms, floor {floor * 1e3:.1f}ms): "
        "ingest is blocking reads")
    print(f"# gate ok: mixed p99 {mixed_p99 * 1e3:.2f}ms <= "
          f"{bound * 1e3:.2f}ms across {len(pipe_reports)} pipelined "
          f"rebalance epochs and {writes_done[0]} writes")


if __name__ == "__main__":
    main()
