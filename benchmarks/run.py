"""Benchmark entry point: one function per paper table + roofline report.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # full grid
  PYTHONPATH=src python -m benchmarks.run --only storage,matcher
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated bench names (e.g. storage,scale)")
    ap.add_argument("--full", action="store_true",
                    help="larger grids (slower)")
    args, _ = ap.parse_known_args()

    from . import paper_tables, roofline
    selected = [s for s in args.only.split(",") if s]
    benches = [(fn.__name__.replace("bench_", ""), fn)
               for fn in paper_tables.ALL]
    benches.append(("roofline", lambda quick: roofline.main(quick=quick)))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if selected and name not in selected:
            continue
        t0 = time.time()
        try:
            fn(quick=not args.full)
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},ok=1")
        except Exception as e:  # noqa: BLE001 — report, keep going
            failures += 1
            traceback.print_exc()
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},"
                  f"ok=0|error={type(e).__name__}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
