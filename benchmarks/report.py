"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

  PYTHONPATH=src python -m benchmarks.report > artifacts/roofline_tables.md
"""

from __future__ import annotations

import json

import numpy as np

from .roofline import load_all


def fmt_us(x: float) -> str:
    return f"{x * 1e6:,.0f}"


def main() -> None:
    rows = load_all()
    if not rows:
        print("(run repro.launch.dryrun --all --mesh both first)")
        return
    from repro.configs.registry import skipped_cells

    for mesh in ("single", "multi"):
        sel = [r for r in rows if r["mesh"] == mesh]
        n_fit = sum(r["fits_16GiB"] for r in sel)
        print(f"\n### Mesh `{mesh}` "
              f"({'16x16 = 256 chips' if mesh == 'single' else '2x16x16 = 512 chips'})"
              f" — {len(sel)} cells compiled, {n_fit} fit 16 GiB HBM\n")
        print("| arch | shape | compute (µs) | memory (µs) | collective (µs)"
              " | dominant | MODEL/HLO | roofline frac | peak GB | fits |")
        print("|---|---|---:|---:|---:|---|---:|---:|---:|---|")
        for r in sorted(sel, key=lambda r: (r["arch"], r["shape"])):
            print(f"| {r['arch']} | {r['shape']} | {fmt_us(r['t_compute'])} "
                  f"| {fmt_us(r['t_memory'])} | {fmt_us(r['t_collective'])} "
                  f"| {r['dominant']} | {r['useful_ratio']:.2f} "
                  f"| {r['roofline_frac']:.3f} | {r['peak_gb']:.2f} "
                  f"| {'yes' if r['fits_16GiB'] else 'NO'} |")
    print("\n### Skipped cells (per brief)\n")
    for a, s, why in skipped_cells():
        print(f"- `{a}` x `{s}`: {why}")

    # collective-bound and worst-fraction cells (hillclimb candidates)
    sel = [r for r in rows if r["mesh"] == "single"]
    coll = sorted(sel, key=lambda r: -r["t_collective"]
                  / max(r["t_compute"] + r["t_memory"], 1e-12))[:3]
    worst = sorted(sel, key=lambda r: r["roofline_frac"])[:3]
    print("\n### Hillclimb candidates (single mesh)\n")
    print("most collective-bound:",
          ", ".join(f"{r['arch']}x{r['shape']}" for r in coll))
    print("worst roofline fraction:",
          ", ".join(f"{r['arch']}x{r['shape']}" for r in worst))


if __name__ == "__main__":
    main()
