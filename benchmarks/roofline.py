"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-step terms in SECONDS:

  compute    = HLO_FLOPs        / (peak FLOP/s per chip)
  memory     = HLO_bytes        / (HBM bytes/s per chip)
  collective = collective_bytes / (ICI bytes/s per chip)

cost_analysis is PER-DEVICE after SPMD partitioning; while-loop (layer-scan)
bodies are counted once, so LM cells apply the correction
  total = module + (L - 1) x single-layer-probe
to flops / bytes / collective bytes alike. MODEL_FLOPS uses 6·N·D (dense) or
6·N_active·D (MoE) per *global* step divided over chips, and analytic
per-family formulas for GNN / recsys; the ratio MODEL/HLO exposes remat and
dispatch overheads.

TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (brief constants).

A ``roofline_query_*`` section models the SPARQL device kernels
(``triple_scan_many`` / ``probe_sorted_many``) against the HBM roof —
they stream bytes with no reuse, so the memory floor IS the roofline —
and reports achieved-vs-peak when ``BENCH_engine.json`` carries a
``bench_engine --kernels`` run (see :func:`query_kernel_rooflines`).
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

LM_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
             "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str, n_chips: int) -> float:
    """Analytic useful-FLOPs per step per chip."""
    from repro.configs.registry import GNN_SHAPES, get_spec
    spec = get_spec(arch)
    if spec.family == "lm":
        cfg = spec.config
        n_active = cfg.active_param_count()
        toks = LM_TOKENS[shape]
        mult = 6.0 if shape == "train_4k" else 2.0   # fwd-only for serving
        return mult * n_active * toks / n_chips
    if spec.family == "gnn":
        cfg = spec.config
        sh = GNN_SHAPES[shape]
        if sh["kind"] == "molecule":
            N = sh["n_graphs"] * sh["nodes_per"]
            E = sh["n_graphs"] * sh["edges_per"]
        else:
            N, E = sh["n_nodes"], sh["n_edges"]
        h = cfg.d_hidden
        d_in = sh.get("d_feat", h)
        # per layer: edge MLP ~ (2h)*h*2 flops/edge + node transform h*h*2
        per_layer = E * (4 * h * h) + N * (2 * h * h)
        first = N * 2 * d_in * h
        return 6.0 * (first + cfg.n_layers * per_layer) / n_chips  # train
    cfg = spec.config
    from repro.configs.registry import RECSYS_SHAPES
    B = RECSYS_SHAPES[shape]["batch"]
    d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dims = (d_in,) + cfg.mlp_dims
    mlp = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    fwd = B * 2 * mlp
    if shape == "train_batch":
        return 6.0 * B * mlp / n_chips
    if shape == "retrieval_cand":
        return (fwd + 2 * B * cfg.n_candidates * cfg.retrieval_dim) / n_chips
    return fwd / n_chips


def corrected(record: dict) -> dict:
    """Apply the scan trip-count correction when a probe exists."""
    f = record["flops"]
    b = record["bytes_accessed"]
    c = record["collectives"]["total_bytes"]
    if record.get("probe"):
        r = record["probe_repeat"]
        f += r * record["probe"]["flops"]
        b += r * record["probe"]["bytes_accessed"]
        c += r * record["probe"]["collectives"]["total_bytes"]
    # grad-accumulation scan body counted once too -> scale by microbatches
    m = record.get("cost_multiplier", 1)
    return {"flops": f * m, "bytes": b * m, "coll_bytes": c * m}


def analyze_record(record: dict) -> dict | None:
    if not record.get("ok"):
        return None
    n_chips = int(np.prod(record["mesh_shape"]))
    tot = corrected(record)
    t_compute = tot["flops"] / PEAK_FLOPS
    t_memory = tot["bytes"] / HBM_BW
    t_coll = tot["coll_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(record["arch"], record["shape"], n_chips)
    t_model = mf / PEAK_FLOPS
    return {
        "arch": record["arch"], "shape": record["shape"],
        "mesh": record["mesh"],
        "t_compute": t_compute, "t_memory": t_memory,
        "t_collective": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / max(tot["flops"], 1e-9),
        "roofline_frac": t_model / max(bound, 1e-12),
        "peak_gb": record["peak_bytes"] / 1e9,
        "fits_16GiB": record["peak_bytes"] <= 16 * 2**30,
    }


def load_all(dryrun_dir: str = "artifacts/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row is not None:
            rows.append(row)
    return rows


# bytes streamed per call when no bench artifact exists (nominal sizes:
# a 100k-triple store scan, a 25k-entry predicate index probe row)
_QUERY_KERNEL_NOMINAL = {
    "kernel_triple_scan_many": ("bytes_per_scan", 100_000 * 3 * 4),
    "kernel_probe_sorted_many": ("bytes_per_row", 25_000 * 4),
}


def query_kernel_rooflines(bench_json: str = "BENCH_engine.json"
                           ) -> list[str]:
    """Query-kernel section (PR 7): both device join kernels are streaming
    compare-and-reduce pipelines with no data reuse, so their roofline is
    purely memory-bound — the floor is bytes_streamed / HBM_BW. When a
    ``bench_engine --kernels`` run left ``BENCH_engine.json`` behind, the
    achieved time is reported against that floor (``frac_of_peak`` is only
    meaningful for compiled TPU runs; CPU interpret mode is a correctness
    tool, not a fast path)."""
    by_name: dict[str, dict] = {}
    note = ""
    if os.path.exists(bench_json):
        # degrade gracefully on any artifact problem: a truncated write, a
        # bench run without --kernels, or a schema drift must leave the
        # section reporting nominal floors with a clear message, never
        # crash the whole roofline report
        try:
            with open(bench_json) as f:
                payload = json.load(f)
            by_name = {r["name"]: r for r in payload.get("rows", [])
                       if isinstance(r, dict) and "name" in r}
        except (json.JSONDecodeError, OSError) as err:
            note = f" ({bench_json} unreadable: {err})"
            by_name = {}
    lines = []
    for name, (bytes_key, default_bytes) in _QUERY_KERNEL_NOMINAL.items():
        rec = by_name.get(name)
        nbytes, achieved_us = default_bytes, None
        if rec is not None:
            try:
                derived = rec.get("derived", {})
                if isinstance(derived, str):
                    derived = dict(kv.split("=", 1)
                                   for kv in derived.split("|") if "=" in kv)
                nbytes = int(derived.get(bytes_key, default_bytes))
                achieved_us = float(rec["us_per_call"])
            except (KeyError, TypeError, ValueError, AttributeError):
                nbytes, achieved_us = default_bytes, None
                note = note or (f" ({bench_json} row {name!r} "
                                "unparseable; using nominal sizes)")
        floor_us = nbytes / HBM_BW * 1e6
        extra = (f"|achieved_us={achieved_us:.1f}"
                 f"|frac_of_peak={floor_us / achieved_us:.4f}"
                 if achieved_us else
                 "|achieved=n/a (run bench_engine --kernels first)" + note)
        lines.append(f"roofline_query_{name.removeprefix('kernel_')},"
                     f"{floor_us:.3f},bytes_streamed={nbytes}"
                     f"|hbm_floor_us={floor_us:.3f}{extra}")
    return lines


def main(quick: bool = True, mesh: str = "single") -> None:
    for line in query_kernel_rooflines():
        print(line)
    rows = [r for r in load_all() if r["mesh"] == mesh]
    if not rows:
        print("roofline_no_data,0.0,run=repro.launch.dryrun --all first")
        return
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}"
        print(f"{name},{r['t_compute'] * 1e6:.1f},"
              f"mem_us={r['t_memory'] * 1e6:.1f}"
              f"|coll_us={r['t_collective'] * 1e6:.1f}"
              f"|dominant={r['dominant']}"
              f"|roofline_frac={r['roofline_frac']:.3f}"
              f"|useful={r['useful_ratio']:.2f}"
              f"|peak_gb={r['peak_gb']:.2f}"
              f"|fits={int(r['fits_16GiB'])}")


if __name__ == "__main__":
    main()
