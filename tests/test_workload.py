"""Workload subsystem (PR 10): sampler oracle parity + traffic + driver.

- Every undecorated sampled shape is re-counted by an independent
  pure-Python indexed backtracking matcher; the count must equal BOTH the
  recorded cardinality and a live evaluation — crossed over
  {numpy, jax} x {monolithic, sharded} stores.
- Decorated queries (FILTER / OPTIONAL / UNION / VALUES / LIMIT) are
  checked for cross-implementation agreement with the recorded count on
  the same matrix.
- Schedules are byte-deterministic from their seed; popularity is
  Zipf-skewed over the hot pool; the cold reserve is used at most once
  per template; write styles synthesize parseable updates with the
  documented verifiability contract (churn verifiable, touch not).
- The driver replays a seeded mix through an `AdmissionQueue` and every
  served answer matches its sample-time cardinality, including under a
  churn write mix with window-level write coalescing.
- Empty and near-empty stores degrade to fewer/no samples, never errors.
"""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.rdf.dictionary import Dictionary
from repro.rdf.generator import generate_watdiv_like
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.runtime.admission import AdmissionQueue
from repro.sparql.algebra import compile_query, evaluate_plan
from repro.sparql.endpoint import SparqlEndpoint
from repro.sparql.engine import QueryEngine
from repro.sparql.query import parse_query
from repro.workload import (PatternSampler, SampledQuery, Schedule,
                            ScheduledEvent, ShapeConfig, TrafficConfig,
                            build_schedule, replay)
from repro.workload.sampler import SHAPES

BACKENDS = ["numpy", "jax"]
KINDS = ["mono", "sharded"]


def build_graph():
    g = generate_watdiv_like(scale=0.4, seed=13)
    return g.store, g.dictionary


@pytest.fixture(scope="module")
def graph():
    return build_graph()


def make_store(store: TripleStore, kind: str):
    if kind == "mono":
        return store
    return ShardedTripleStore(store.s, store.p, store.o,
                              store.num_entities, store.num_predicates,
                              num_shards=3)


# ---------------------------------------------------------------------------
# independent reference: indexed backtracking over the raw triple list
# ---------------------------------------------------------------------------


def ref_count(store, patterns) -> int:
    """Count BGP solutions by pure-Python backtracking with an
    (s, p) -> objects index — polynomial on these shapes, and sharing no
    code with the engine under test."""
    by_sp: dict[tuple, list] = {}
    by_p: dict[int, list] = {}
    for s, p, o in store.triples().tolist():
        by_sp.setdefault((s, p), []).append(o)
        by_p.setdefault(p, []).append((s, o))

    def extend(i: int, env: dict) -> int:
        if i == len(patterns):
            return 1
        sv, pid, ov = patterns[i]
        s_bound = env.get(sv, sv) if isinstance(sv, str) else sv
        o_bound = env.get(ov, ov) if isinstance(ov, str) else ov
        if not isinstance(s_bound, str):        # subject known: use index
            pairs = [(s_bound, o) for o in by_sp.get((s_bound, pid), [])]
        else:
            pairs = by_p.get(pid, [])
        total = 0
        for s, o in pairs:
            if not isinstance(o_bound, str) and o != o_bound:
                continue
            child = dict(env)
            if isinstance(sv, str):
                child[sv] = s
            if isinstance(ov, str):
                child[ov] = o
            total += extend(i + 1, child)
        return total

    return extend(0, {})


def bgp_patterns(text: str, d: Dictionary):
    """(s, pid, o) triples of a PLAIN sampled query (s/o var names or
    entity ids), extracted through the parser only."""
    root = compile_query(parse_query(text, d), d)
    leaves = root.bgp_leaves()
    assert len(leaves) == 1
    return [(tp.s, tp.p, tp.o) for tp in leaves[0].patterns]


# ---------------------------------------------------------------------------
# oracle parity: recorded cardinality == reference == every impl
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
def test_undecorated_shapes_match_reference(graph, backend, kind):
    store, d = graph
    smp = PatternSampler(store, d, seed=21)
    cfgs = [ShapeConfig(s, size=3, const_frac=0.4) for s in SHAPES]
    queries = smp.sample_mix(cfgs, 3)
    assert {q.shape for q in queries} == set(SHAPES)
    target = make_store(store, kind)
    engine = QueryEngine(backend=backend)
    for q in queries:
        expected = ref_count(store, bgp_patterns(q.text, d))
        assert q.cardinality == expected, q.text
        assert expected >= 1                       # witnessed: non-empty
        root = compile_query(parse_query(q.text, d), d)
        assert len(evaluate_plan(root, target, engine)) == expected


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
def test_decorated_shapes_cross_impl_parity(graph, backend, kind):
    store, d = graph
    smp = PatternSampler(store, d, seed=22)
    cfgs = [ShapeConfig(s, size=3, const_frac=0.3,
                        decorations=("filter", "optional", "union",
                                     "values", "limit"))
            for s in SHAPES]
    queries = smp.sample_mix(cfgs, 3)
    assert len({q.decoration for q in queries}) >= 3
    target = make_store(store, kind)
    engine = QueryEngine(backend=backend)
    for q in queries:
        root = compile_query(parse_query(q.text, d), d)
        assert len(evaluate_plan(root, target, engine)) == q.cardinality, \
            q.text


def test_recorded_metadata(graph):
    store, d = graph
    smp = PatternSampler(store, d, seed=23,
                         exclude_predicates=["country"])
    excluded = d.predicate_id("country")
    queries = smp.sample_mix(
        [ShapeConfig(s, size=3) for s in SHAPES], 4)
    for q in queries:
        assert isinstance(q, SampledQuery)
        assert q.store_version == store.version
        assert q.n_patterns >= 2
        assert q.pids and excluded not in q.pids
        root = compile_query(parse_query(q.text, d), d)
        used = {tp.p for leaf in root.bgp_leaves()
                for tp in leaf.patterns}
        assert used == set(q.pids)


def test_sampler_seed_determinism(graph):
    store, d = graph
    cfgs = [ShapeConfig(s, size=3, const_frac=0.5,
                        decorations=("filter", "limit")) for s in SHAPES]
    a = PatternSampler(store, d, seed=7).sample_mix(cfgs, 3)
    b = PatternSampler(store, d, seed=7).sample_mix(cfgs, 3)
    assert [(q.text, q.cardinality) for q in a] == \
        [(q.text, q.cardinality) for q in b]


def test_sampler_empty_and_tiny_stores():
    d = Dictionary()
    z = np.zeros(0, dtype=np.int64)
    empty = TripleStore(z, z, z, 0, 0)
    assert PatternSampler(empty, d, seed=1).sample(
        ShapeConfig("star"), 4) == []

    for t in ("a", "b", "c"):
        d.add_entity(t)
    pid = d.add_predicate("edge")
    tiny = TripleStore(np.array([0, 1]), np.array([pid, pid]),
                       np.array([1, 2]), d.num_entities, 1)
    smp = PatternSampler(tiny, d, seed=1, max_attempts=8)
    for shape in SHAPES:
        queries = smp.sample(ShapeConfig(shape, size=3), 4)
        assert len(queries) <= 4                   # fewer is fine, no error
        for q in queries:
            assert q.cardinality >= 1
    # a 2-hop path exists (a->b->c); at least the path shape must sample
    assert smp.sample(ShapeConfig("path", size=2), 2)


def test_shape_config_validation():
    with pytest.raises(ValueError):
        ShapeConfig("triangle")
    with pytest.raises(ValueError):
        ShapeConfig("star", size=0)
    with pytest.raises(ValueError):
        ShapeConfig("star", const_frac=1.5)
    with pytest.raises(ValueError):
        ShapeConfig("star", decorations=("sparkle",))


# ---------------------------------------------------------------------------
# traffic model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def templates(graph):
    store, d = graph
    smp = PatternSampler(store, d, seed=31,
                         exclude_predicates=["country"])
    qs = smp.sample_mix([ShapeConfig(s, size=3) for s in SHAPES], 4)
    assert len(qs) >= 12
    return qs


def test_schedule_seed_determinism(templates):
    cfg = TrafficConfig(duration_s=0.5, qps=400, cold_fraction=0.2,
                        zipf_s=1.2, seed=5)
    s1 = build_schedule(templates, cfg)
    s2 = build_schedule(templates, cfg)
    assert [(e.at_s, e.kind, e.text, e.cold) for e in s1.events] == \
        [(e.at_s, e.kind, e.text, e.cold) for e in s2.events]
    assert s1.n_queries == len(s1.events) and not s1.has_writes
    other = build_schedule(templates, TrafficConfig(
        duration_s=0.5, qps=400, cold_fraction=0.2, zipf_s=1.2, seed=6))
    assert [e.at_s for e in other.events] != [e.at_s for e in s1.events]


def test_zipf_skew_and_cold_reserve(templates):
    cfg = TrafficConfig(duration_s=2.0, qps=500, cold_fraction=0.2,
                        zipf_s=1.4, seed=8)
    sched = build_schedule(templates, cfg)
    counts = sched.template_counts()
    assert sum(counts.values()) == sched.n_queries
    # skew: the most popular template dominates the median one
    ranked = sorted(counts.values(), reverse=True)
    assert ranked[0] >= 3 * max(1, ranked[len(ranked) // 2])
    # cold templates appear exactly once each
    cold_uses = Counter(e.template for e in sched.events if e.cold)
    assert cold_uses and all(n == 1 for n in cold_uses.values())


def test_arrivals_within_duration_and_sorted(templates):
    for arrival in ("poisson", "burst"):
        cfg = TrafficConfig(duration_s=0.5, qps=300, arrival=arrival,
                            seed=3)
        sched = build_schedule(templates, cfg)
        ts = [e.at_s for e in sched.events]
        assert ts == sorted(ts)
        assert all(0 <= t < cfg.duration_s for t in ts)
        assert len(ts) > 0


def test_burst_arrivals_land_in_every_burst_window(templates):
    # default burst shape: burst_factor * burst_fraction == 1, so the
    # compensating off-window rate is exactly 0 — every arrival must
    # fall inside a burst window, every period must get a burst, and
    # the overall mean must stay ~qps (regression: stepping one
    # exponential at the instantaneous rate collapsed the whole
    # schedule into a single initial burst)
    cfg = TrafficConfig(duration_s=1.0, qps=200, arrival="burst",
                        burst_factor=4.0, burst_fraction=0.25,
                        burst_period_s=0.25, seed=9)
    sched = build_schedule(templates, cfg)
    ts = np.array([e.at_s for e in sched.events])
    assert 140 <= len(ts) <= 260                  # ~Poisson(200)
    window = cfg.burst_fraction * cfg.burst_period_s
    assert np.all(ts % cfg.burst_period_s < window)
    periods = set((ts // cfg.burst_period_s).astype(int).tolist())
    assert periods == {0, 1, 2, 3}


def test_burst_arrivals_partial_offload(templates):
    # burst_factor * burst_fraction < 1: off-window traffic exists but
    # burst windows still run burst_factor/off_factor times hotter
    cfg = TrafficConfig(duration_s=2.0, qps=300, arrival="burst",
                        burst_factor=2.0, burst_fraction=0.25,
                        burst_period_s=0.25, seed=9)
    sched = build_schedule(templates, cfg)
    ts = np.array([e.at_s for e in sched.events])
    assert 480 <= len(ts) <= 720                  # mean stays ~qps
    window = cfg.burst_fraction * cfg.burst_period_s
    in_burst = int(np.sum(ts % cfg.burst_period_s < window))
    # expected in-window share: 2.0*0.25 / (2.0*0.25 + (2/3)*0.75) = 0.5
    assert 0.4 <= in_burst / len(ts) <= 0.6
    assert in_burst < len(ts)                     # off-window arrivals too


def test_write_styles(graph, templates):
    store, d = graph
    churn = build_schedule(templates, TrafficConfig(
        duration_s=0.5, qps=300, write_fraction=0.3, write_style="churn",
        seed=4), churn_predicate="country")
    assert churn.has_writes and churn.verifiable
    touch = build_schedule(templates, TrafficConfig(
        duration_s=0.5, qps=300, write_fraction=0.3, write_style="touch",
        seed=4), store=store, dictionary=d)
    assert touch.has_writes and not touch.verifiable
    # churn only ever touches the reserved predicate; touch's deletes are
    # all re-inserted by end of schedule (net-zero content change)
    for e in churn.events:
        if e.kind == "update":
            assert "<country>" in e.text
    net = Counter()
    for e in touch.events:
        if e.kind == "update":
            row = e.text[e.text.index("{") + 1:e.text.rindex("}")].strip()
            net[row] += 1 if e.text.startswith("INSERT") else -1
    assert all(v == 0 for v in net.values())


def test_write_config_validation(templates):
    with pytest.raises(ValueError):
        build_schedule(templates, TrafficConfig(write_fraction=0.5))
    with pytest.raises(ValueError):
        build_schedule(templates, TrafficConfig(
            write_fraction=0.5, write_style="touch"))
    with pytest.raises(ValueError):
        build_schedule([], TrafficConfig())
    with pytest.raises(ValueError):
        TrafficConfig(arrival="uniformish")
    with pytest.raises(ValueError):
        TrafficConfig(qps=0)


# ---------------------------------------------------------------------------
# driver: replay through the admission queue, verified end to end
# ---------------------------------------------------------------------------


def test_replay_read_only_verifies_every_answer(graph, templates):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    sched = build_schedule(templates, TrafficConfig(
        duration_s=0.3, qps=250, cold_fraction=0.15, seed=12))
    with AdmissionQueue(ep, window_s=0.004, max_batch=32) as q:
        rep = replay(q, sched, speed=2.0)
    assert rep.completed == rep.n_events == len(sched.events)
    assert rep.errors == 0
    assert rep.verification_ok
    assert rep.verified == sched.n_queries
    assert set(rep.per_shape) <= set(SHAPES)
    assert rep.cache_trajectory                   # warmup curve captured
    p = rep.per_temperature
    assert p["cold"].count + p["warm"].count == sched.n_queries
    as_dict = rep.as_dict()
    assert as_dict["admission"]["completed"] >= rep.completed


def test_replay_trajectory_spans_all_batches(graph, templates):
    # the warmup curve must cover EVERY replay dispatch window, not just
    # the last 64 that stats.recent retains — and must exclude batches
    # dispatched before the replay started
    store, d = graph
    ep = SparqlEndpoint(store, d)
    q0 = templates[0]
    events = [ScheduledEvent(at_s=0.0, kind="query", text=q0.text,
                             template=q0.name, shape=q0.shape,
                             cardinality=q0.cardinality)
              for _ in range(80)]
    sched = Schedule(events=events, config=TrafficConfig(),
                     templates=[q0])
    with AdmissionQueue(ep, window_s=0.0, max_batch=1) as q:
        q.query(q0.text)                         # pre-replay batch seq 0
        rep = replay(q, sched, speed=1000.0)
    assert rep.completed == 80 and rep.errors == 0
    assert len(q.stats.recent) <= 64             # the ring trimmed
    assert len(rep.cache_trajectory) == 80       # ...but replay saw all
    seqs = [b["seq"] for b in rep.cache_trajectory]
    assert seqs == sorted(seqs) and seqs[0] >= 1


def test_replay_stays_interruptible():
    # KeyboardInterrupt raised while harvesting a ticket must propagate,
    # not be swallowed as a per-query error
    class FakeTicket:
        def done(self):
            return True

        def result(self, timeout=None):
            raise KeyboardInterrupt

    class FakeStats:
        recent: list = []
        assignment_counts: dict = {}

        def as_dict(self):
            return {}

    class FakeQueue:
        stats = FakeStats()

        def submit(self, text):
            return FakeTicket()

    q0 = SampledQuery(name="t0", shape="star", text="SELECT * WHERE {}",
                      cardinality=1, n_patterns=1, n_consts=0, pids=(0,),
                      decoration=None, store_version=0)
    sched = Schedule(events=[ScheduledEvent(
        at_s=0.0, kind="query", text=q0.text, template=q0.name,
        shape=q0.shape, cardinality=q0.cardinality)],
        config=TrafficConfig(), templates=[q0])
    with pytest.raises(KeyboardInterrupt):
        replay(FakeQueue(), sched, speed=1000.0)


def test_replay_churn_mix_stays_verified_with_coalescing(graph,
                                                         templates):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    sched = build_schedule(templates, TrafficConfig(
        duration_s=0.3, qps=250, write_fraction=0.25,
        write_style="churn", arrival="burst", seed=13),
        churn_predicate="country")
    with AdmissionQueue(ep, window_s=0.004, max_batch=32,
                        coalesce_writes=True) as q:
        rep = replay(q, sched, speed=2.0)
    assert rep.errors == 0
    assert rep.writes.count == sched.n_updates > 0
    # the whole point of the churn style: every read answer still matches
    # its sample-time cardinality while writes land
    assert rep.verification_ok and rep.verified == sched.n_queries
    assert rep.admission["updates_served"] == sched.n_updates
    assert rep.admission["write_commits"] <= sched.n_updates
