"""Explicit-SPMD (shard_map) paths == local paths, on a 1x1 mesh.

The dry-run exercises these paths at 512 devices compile-only; here we run
them numerically on a trivial mesh and assert equality with the mesh-free
implementations (same math, different schedule).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.graphs import molecule_batch
from repro.launch.mesh import make_compat_mesh
from repro.models.common import AxisRules
from repro.models.gnn import GNNConfig, gnn_init, gnn_loss, mp_aggregate
from repro.models.transformer import LMConfig, init_lm_params, lm_loss


@pytest.fixture(scope="module")
def mesh11():
    return make_compat_mesh((1, 1), ("data", "model"))


def test_moe_shardmap_matches_local(mesh11):
    cfg = LMConfig(name="m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                   d_head=8, d_ff=16, vocab=211, n_experts=4, top_k=2,
                   capacity_factor=2.0)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    local_rules = AxisRules(batch=(), fsdp=None, tp=None)
    loss_local, _ = jax.jit(
        lambda p, t: lm_loss(cfg, p, t, local_rules))(params, toks)
    dist_rules = AxisRules.for_mesh(mesh11)
    with mesh11:
        loss_dist, _ = jax.jit(
            lambda p, t: lm_loss(cfg, p, t, dist_rules))(params, toks)
    assert np.isclose(float(loss_local), float(loss_dist), rtol=2e-3), \
        (float(loss_local), float(loss_dist))


def test_mp_aggregate_shardmap_matches_local(mesh11):
    rng = np.random.default_rng(0)
    E, N, D = 128, 32, 8
    msg = jnp.asarray(rng.normal(0, 1, (E, D)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    local = mp_aggregate(msg, dst, N, AxisRules(batch=(), mesh=None))
    rules = AxisRules.for_mesh(mesh11)
    with mesh11:
        dist = jax.jit(lambda m, d: mp_aggregate(m, d, N, rules))(msg, dst)
        dist_max = jax.jit(
            lambda m, d: mp_aggregate(m, d, N, rules, op="max"))(msg, dst)
    np.testing.assert_allclose(np.asarray(local), np.asarray(dist),
                               rtol=1e-6)
    local_max = mp_aggregate(msg, dst, N, AxisRules(batch=(), mesh=None),
                             op="max")
    np.testing.assert_allclose(np.asarray(local_max), np.asarray(dist_max),
                               rtol=1e-6)


@pytest.mark.parametrize("model", ["nequip", "egnn", "pna", "gcn"])
def test_gnn_dist_matches_local(mesh11, model):
    cfg = GNNConfig(name=model, model=model, n_layers=2, d_hidden=8,
                    n_species=8, n_classes=4, d_feat=16)
    params = gnn_init(cfg, jax.random.PRNGKey(0))
    if model in ("gcn", "pna"):
        from repro.data.graphs import cora_like
        data = cora_like(n_nodes=64, n_edges=256, d_feat=16, n_classes=4,
                         seed=2)
    else:
        data = molecule_batch(batch=4, n_nodes=16, n_edges=32, seed=2)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    loss_local, _ = jax.jit(lambda p, b: gnn_loss(
        cfg, p, b, AxisRules(batch=(), mesh=None)))(params, batch)
    rules = AxisRules.for_mesh(mesh11)
    with mesh11:
        loss_dist, _ = jax.jit(
            lambda p, b: gnn_loss(cfg, p, b, rules))(params, batch)
        # grads flow through the shard_map/custom-vjp paths
        g = jax.jit(jax.grad(
            lambda p: gnn_loss(cfg, p, batch, rules)[0]))(params)
    gn = jax.tree.reduce(lambda a, b: a + float(jnp.sum(jnp.abs(b))), g, 0.0)
    assert np.isclose(float(loss_local), float(loss_dist), rtol=1e-4), model
    assert np.isfinite(gn) and gn > 0
