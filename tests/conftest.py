import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running smoke tests (excluded from the fast CI lane "
        "via -m 'not slow')")
    config.addinivalue_line(
        "markers",
        "requires_accelerator: compiled-mode (non-interpret) kernel tests; "
        "auto-skipped when no TPU/GPU is present so the CPU CI lane stays "
        "green while the suite runs unchanged on real hardware")


def pytest_collection_modifyitems(config, items):
    marked = [it for it in items
              if it.get_closest_marker("requires_accelerator")]
    if not marked:
        return
    from repro.kernels import default_interpret
    if default_interpret():
        skip = pytest.mark.skip(
            reason="no TPU/GPU: compiled Pallas mode unavailable")
        for it in marked:
            it.add_marker(skip)
