def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running smoke tests (excluded from the fast CI lane "
        "via -m 'not slow')")
