"""SPARQL algebra layer: oracle-equivalence matrix + end-to-end routing.

Covers the PR-5 surface:

- every operator (FILTER comparisons/BOUND/REGEX/connectives, OPTIONAL,
  UNION, DISTINCT, ORDER BY, LIMIT/OFFSET, ASK) against an independent
  brute-force reference evaluator, crossed over both backends (``numpy``,
  ``jax``) x both store kinds (monolithic, sharded);
- parser regressions: quoted literals containing ``.``/``;``/``?``/spaces
  no longer break tokenization; ParseError behavior of the BGP shim;
- ``SparqlEndpoint`` facade (query/ask/query_many/explain, plan cache);
- per-operator ``EngineStats`` counters + scan-counter invariants
  (``scans_executed == scan_cache_misses``, ``scans_deduped >= 0``) for
  wildcard scans over sharded stores with empty shards and for algebra
  queries sharing sub-BGP cache entries;
- edge-vs-cloud parity through ``EdgeCloudSystem.run_round_batched`` and
  ``OffloadServingPool``, including after a delta-rebalance.
"""

from __future__ import annotations

import re
from collections import Counter

import numpy as np
import pytest

from repro.core.cost import SystemParams, estimate_query_cost
from repro.core.pattern import feasibility_patterns, observed_patterns
from repro.edge.system import EdgeCloudSystem
from repro.rdf.dictionary import Dictionary
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.runtime.serving import (OffloadServingPool, Replica,
                                   make_sparql_runner)
from repro.sparql.algebra import (UNBOUND, AskNode, BGPNode, DistinctNode,
                                  FilterNode, JoinNode, OptionalNode,
                                  OrderSliceNode, ProjectNode, UnionNode,
                                  ValuesNode, _term_key, compare_terms,
                                  compile_query, evaluate_many,
                                  evaluate_plan, explain_plan)
from repro.sparql.endpoint import SparqlEndpoint
from repro.sparql.engine import QueryEngine
from repro.sparql.matcher import match_oracle
from repro.sparql.query import (BoundExpr, Comparison, ParseError,
                                QueryGraph, RegexExpr, TriplePattern,
                                parse_query, parse_sparql)

BACKENDS = ["numpy", "jax"]
KINDS = ["mono", "sharded"]


# ---------------------------------------------------------------------------
# fixture data: small handcrafted graph (oracle-friendly, weird literals)
# ---------------------------------------------------------------------------


def build_graph():
    d = Dictionary()
    people = ["alice", "bob", "carol", "dave", "eve", "frank"]
    products = ["p1", "p2", "p3", "p4", "p5"]
    cities = ["paris", "tokyo", "oslo"]
    ratings = ["5", "3", "8", "10"]
    tags = ["new", "sale item v1.0", "odd;tag", "q?mark {brace}"]
    for t in people + products + cities + ratings + tags:
        d.add_entity(t)
    for p in ["knows", "likes", "city", "rating", "tag"]:
        d.add_predicate(p)

    triples = [
        ("alice", "knows", "bob"), ("bob", "knows", "carol"),
        ("alice", "knows", "carol"), ("carol", "knows", "dave"),
        ("dave", "knows", "eve"), ("eve", "knows", "frank"),
        ("frank", "knows", "alice"), ("bob", "knows", "dave"),
        ("alice", "likes", "p1"), ("bob", "likes", "p1"),
        ("carol", "likes", "p2"), ("dave", "likes", "p3"),
        ("eve", "likes", "p2"), ("frank", "likes", "p4"),
        ("alice", "likes", "p2"), ("frank", "likes", "p5"),
        ("alice", "city", "paris"), ("bob", "city", "paris"),
        ("carol", "city", "tokyo"), ("dave", "city", "oslo"),
        ("eve", "city", "tokyo"),          # frank: no city
        ("p1", "rating", "5"), ("p2", "rating", "3"),
        ("p3", "rating", "8"), ("p5", "rating", "10"),   # p4: no rating
        ("p1", "tag", "new"), ("p2", "tag", "sale item v1.0"),
        ("p3", "tag", "odd;tag"), ("p4", "tag", "q?mark {brace}"),
    ]
    s = np.array([d.entity_id(a) for a, _, _ in triples])
    p = np.array([d.predicate_id(b) for _, b, _ in triples])
    o = np.array([d.entity_id(c) for _, _, c in triples])
    store = TripleStore(s, p, o, d.num_entities, d.num_predicates)
    return store, d


@pytest.fixture(scope="module")
def graph():
    return build_graph()


def store_of(kind: str, store):
    if kind == "mono":
        return store
    return ShardedTripleStore.from_store(store, 4)


# ---------------------------------------------------------------------------
# brute-force reference evaluator (row-wise, independent of the vectorized
# numpy implementation; leaves go through the exponential match_oracle)
# ---------------------------------------------------------------------------


def _compat(a: dict, b: dict) -> bool:
    return all(a[k] == b[k] for k in a.keys() & b.keys())


def _merge(a: dict, b: dict) -> dict:
    out = dict(b)
    out.update(a)
    return out


def ref_eval(root, store):
    d = root.dictionary
    pv = root.pred_vars

    def decode(var, vid):
        if vid is None:
            return None
        return d.predicate(vid) if var in pv else d.entity(vid)

    def ref_expr(expr, env) -> bool:
        if isinstance(expr, Comparison):
            def val(op):
                if op.kind == "var":
                    if op.value not in env:
                        return None
                    return decode(op.value, env[op.value])
                return op.value
            a, b = val(expr.lhs), val(expr.rhs)
            if a is None or b is None:
                return False
            return compare_terms(expr.op, a, b)
        if isinstance(expr, BoundExpr):
            return expr.var in env
        if isinstance(expr, RegexExpr):
            if expr.var not in env:
                return False
            flags = re.IGNORECASE if "i" in expr.flags else 0
            return re.search(expr.pattern,
                             decode(expr.var, env[expr.var]),
                             flags) is not None
        name = type(expr).__name__
        if name == "NotExpr":
            return not ref_expr(expr.arg, env)
        if name == "AndExpr":
            return all(ref_expr(a, env) for a in expr.args)
        if name == "OrExpr":
            return any(ref_expr(a, env) for a in expr.args)
        raise TypeError(expr)

    def walk(node) -> list[dict]:
        if isinstance(node, BGPNode):
            if not node.patterns:
                return [dict()]
            sols, vs = match_oracle(store, node.query)
            return [dict(zip(vs, map(int, row))) for row in sols]
        if isinstance(node, JoinNode):
            L, R = walk(node.left), walk(node.right)
            return [_merge(a, b) for a in L for b in R if _compat(a, b)]
        if isinstance(node, OptionalNode):
            L, R = walk(node.left), walk(node.right)
            out = []
            for a in L:
                ext = [_merge(a, b) for b in R if _compat(a, b)]
                out += ext if ext else [a]
            return out
        if isinstance(node, UnionNode):
            out = []
            for b in node.branches:
                out += walk(b)
            return out
        if isinstance(node, ValuesNode):
            return [{v: int(c) for v, c in zip(node.var_names, row)
                     if c != UNBOUND} for row in node.rows]
        if isinstance(node, FilterNode):
            return [e for e in walk(node.child) if ref_expr(node.expr, e)]
        if isinstance(node, ProjectNode):
            envs = walk(node.child)
            if not node.projection:
                return envs
            return [{v: e[v] for v in node.projection if v in e}
                    for e in envs]
        if isinstance(node, DistinctNode):
            envs = walk(node.child)
            cols = node.on or sorted({v for e in envs for v in e})
            seen, out = set(), []
            for e in envs:
                key = tuple(e.get(v) for v in cols)
                if key not in seen:
                    seen.add(key)
                    out.append(e)
            return out
        if isinstance(node, OrderSliceNode):
            envs = walk(node.child)
            for var, asc in reversed(node.order):
                envs.sort(key=lambda e: ((0,) if e.get(var) is None
                                         else (1, _term_key(
                                             decode(var, e[var])))),
                          reverse=not asc)
            lo = max(0, node.offset)
            hi = None if node.limit is None else lo + max(0, node.limit)
            return envs[lo:hi]
        if isinstance(node, AskNode):
            return [dict()] if walk(node.child) else []
        raise TypeError(node)

    envs = walk(root)
    return envs, decode


def ref_multiset(root, store) -> Counter:
    envs, decode = ref_eval(root, store)
    return Counter(tuple(sorted((v, decode(v, e[v])) for v in e))
                   for e in envs)


def table_multiset(tbl) -> Counter:
    out = []
    for row in tbl.rows(decoded=True):
        pairs = [(v, t) for v, t in zip(tbl.var_names, row) if t is not None]
        out.append(tuple(sorted(pairs)))
    return Counter(out)


# ---------------------------------------------------------------------------
# operator matrix vs the reference, both backends x both store kinds
# ---------------------------------------------------------------------------

MATRIX_QUERIES = [
    # FILTER comparisons / connectives
    'SELECT ?a ?b WHERE { ?a <knows> ?b . FILTER (?b != <carol>) }',
    'SELECT ?a WHERE { ?a <city> ?c . FILTER (?c = <paris>) }',
    'SELECT ?p ?r WHERE { ?x <likes> ?p . ?p <rating> ?r . '
    'FILTER (?r > "4") }',
    'SELECT ?p ?r WHERE { ?p <rating> ?r . FILTER (?r >= "10") }',
    'SELECT ?a ?b WHERE { ?a <knows> ?b . FILTER (?a < ?b) }',
    'SELECT ?a ?b WHERE { ?a <knows> ?b . ?a <city> ?c . ?b <city> ?c }',
    'SELECT ?a WHERE { ?a <city> ?c . '
    'FILTER ((?c = <paris> || ?c = <tokyo>) && !(?a = <bob>)) }',
    # BOUND / REGEX over OPTIONAL
    'SELECT ?a ?c WHERE { ?a <knows> ?b . OPTIONAL { ?b <city> ?c } }',
    'SELECT ?a ?r WHERE { ?a <likes> ?p . OPTIONAL { ?p <rating> ?r } . '
    'FILTER (!BOUND(?r)) }',
    'SELECT ?a ?c WHERE { ?a <city> ?c . '
    'OPTIONAL { ?a <likes> ?p . ?p <rating> ?r } . '
    'FILTER (BOUND(?r) || ?c = <tokyo>) }',
    'SELECT ?p WHERE { ?p <tag> ?t . FILTER (REGEX(?t, "sale")) }',
    'SELECT ?p WHERE { ?p <tag> ?t . FILTER (REGEX(?t, "SALE ITEM", "i")) }',
    # unbound shared-variable (compatibility) joins
    'SELECT ?a ?p ?t WHERE { ?a <city> ?c . OPTIONAL { ?a <likes> ?p } . '
    '?p <tag> ?t }',
    # UNION
    'SELECT ?x WHERE { { ?x <knows> ?b } UNION { ?x <likes> ?p } }',
    'SELECT ?x ?c ?p WHERE { { ?x <city> ?c } UNION { ?x <likes> ?p } '
    'UNION { ?x <knows> ?y } }',
    'SELECT ?x ?t WHERE { { ?x <likes> ?p } UNION { ?x <knows> ?p } . '
    '?p <tag> ?t }',
    # DISTINCT / nested group / predicate-variable filter
    'SELECT DISTINCT ?c WHERE { ?a <city> ?c }',
    'SELECT ?a WHERE { { ?a <knows> ?b . ?b <city> <tokyo> } }',
    'SELECT ?a ?pp ?b WHERE { ?a ?pp ?b . FILTER (?pp = <knows>) }',
    # quoted literals with separators (triple position)
    'SELECT ?p WHERE { ?p <tag> "sale item v1.0" }',
    'SELECT ?p WHERE { ?p <tag> "odd;tag" }',
    'SELECT ?p WHERE { ?p <tag> "q?mark {brace}" }',
    # VALUES: single-var, grouped rows, UNDEF compatibility, interaction
    # with OPTIONAL/UNION, and an unmatchable binding
    'SELECT ?a ?b WHERE { VALUES ?a { <alice> <bob> } ?a <knows> ?b }',
    'SELECT ?a ?p WHERE { ?a <likes> ?p . '
    'VALUES (?a ?p) { (<alice> <p1>) (<frank> UNDEF) } }',
    'SELECT ?a ?c WHERE { VALUES ?a { <frank> <eve> } '
    'OPTIONAL { ?a <city> ?c } }',
    'SELECT ?x WHERE { VALUES ?x { <p1> <paris> } '
    '{ { ?y <likes> ?x } UNION { ?y <city> ?x } } }',
    'SELECT ?a WHERE { VALUES ?a { <tokyo> } ?a <knows> ?b }',
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
def test_operator_matrix_vs_reference(graph, backend, kind):
    store, d = graph
    st = store_of(kind, store)
    eng = QueryEngine(backend=backend)
    plans = [compile_query(parse_query(t, d), d) for t in MATRIX_QUERIES]
    tables = evaluate_many(plans, st, eng)
    for text, plan, tbl in zip(MATRIX_QUERIES, plans, tables):
        assert table_multiset(tbl) == ref_multiset(plan, store), text
    # scan-counter invariants hold across the whole algebra batch
    assert eng.stats.scans_deduped >= 0
    assert eng.stats.scans_executed == eng.stats.scan_cache_misses


@pytest.mark.parametrize("kind", KINDS)
def test_order_by_limit_offset(graph, kind):
    store, d = graph
    st = store_of(kind, store)
    eng = QueryEngine()
    # unique keys: exact sequence is deterministic (numeric order!)
    t = ('SELECT DISTINCT ?r WHERE { ?p <rating> ?r } '
         'ORDER BY ?r LIMIT 2 OFFSET 1')
    tbl = evaluate_plan(compile_query(parse_query(t, d), d), st, eng)
    assert [r[0] for r in tbl.rows()] == ["5", "8"]   # 3 < 5 < 8 < 10
    # multi-key ORDER BY: key-column sequences match the reference exactly
    t2 = 'SELECT ?a ?b WHERE { ?a <knows> ?b } ORDER BY ?a DESC(?b)'
    plan2 = compile_query(parse_query(t2, d), d)
    tbl2 = evaluate_plan(plan2, st, eng)
    envs, decode = ref_eval(plan2, store)
    got = [(r[0], r[1]) for r in tbl2.rows()]
    want = [(decode("?a", e["?a"]), decode("?b", e["?b"])) for e in envs]
    assert got == want
    # descending numeric order puts 10 before 8 before 5 before 3
    t3 = 'SELECT DISTINCT ?r WHERE { ?p <rating> ?r } ORDER BY DESC(?r)'
    tbl3 = evaluate_plan(compile_query(parse_query(t3, d), d), st, eng)
    assert [r[0] for r in tbl3.rows()] == ["10", "8", "5", "3"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_ask_queries(graph, backend):
    store, d = graph
    eng = QueryEngine(backend=backend)

    def ask(text: str) -> bool:
        plan = compile_query(parse_query(text, d), d)
        return evaluate_plan(plan, store, eng).num_matches > 0

    assert ask('ASK { ?x <knows> <carol> }')
    assert not ask('ASK { <carol> <knows> <alice> }')
    assert not ask('ASK { ?p <rating> ?r . FILTER (?r > "100") }')
    assert ask('ASK { ?a <city> ?c . OPTIONAL { ?a <likes> ?p } }')


# ---------------------------------------------------------------------------
# parser regressions
# ---------------------------------------------------------------------------


def test_literals_with_separators_parse(graph):
    store, d = graph
    # the historical dot-split parser broke on '.', ';', '?', '{', and
    # whitespace inside quoted literals — tokenizing strings first fixes it
    q = parse_sparql('SELECT ?p WHERE { ?p <tag> "sale item v1.0" . '
                     '?p <rating> ?r }', d)
    assert len(q.patterns) == 2
    q2 = parse_sparql('SELECT ?p WHERE { ?p <tag> "odd;tag" }', d)
    assert len(q2.patterns) == 1
    q3 = parse_sparql('SELECT ?p WHERE { ?p <tag> "q?mark {brace}" }', d)
    assert len(q3.patterns) == 1
    # and they actually match
    from repro.sparql.matcher import match_bgp
    assert match_bgp(store, q).num_matches == 1      # p2 has a rating
    assert match_bgp(store, q2).num_matches == 1
    assert match_bgp(store, q3).num_matches == 1


def test_parse_sparql_shim_rejects_algebra(graph):
    _, d = graph
    for text in [
        'ASK { ?x <knows> ?y }',
        'SELECT ?x WHERE { ?x <knows> ?y . FILTER (?x != <bob>) }',
        'SELECT ?x WHERE { ?x <knows> ?y } LIMIT 3',
        'SELECT DISTINCT ?x WHERE { ?x <knows> ?y }',
        'SELECT ?x WHERE { { ?x <knows> ?y } UNION { ?x <likes> ?y } }',
    ]:
        with pytest.raises(ParseError):
            parse_sparql(text, d)
    # plain BGPs still parse (and PREFIXes still expand)
    q = parse_sparql('PREFIX ex: <kno> SELECT * WHERE { ?x ex:ws ?y }', d)
    assert len(q.patterns) == 1 and q.projection == []


def test_parse_errors(graph):
    _, d = graph
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <nosuchpred> ?y }', d)
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> <nobody> }', d)
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y', d)   # unterminated
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y } junk', d)
    with pytest.raises(ParseError):
        parse_query('SELECT WHERE { ?x <knows> ?y }', d)
    with pytest.raises(ParseError):
        parse_query('ASK { ?x <knows> ?y } LIMIT 2', d)
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y . FILTER (?x) }', d)


def test_filter_masks_on_empty_tables(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    # a selective filter empties the table; the following order-comparison
    # and negated-REGEX masks must stay boolean (regression: float64 masks
    # from np.array([]) rejected & | ~)
    t = ('SELECT ?a ?c WHERE { ?a <city> ?c . FILTER (?c = <frank>) . '
         'FILTER (?a < <zzz>) . FILTER (!REGEX(?c, "x")) }')
    assert ep.query(t).num_matches == 0
    t2 = ('SELECT ?a WHERE { ?a <city> ?c . FILTER (?c = <paris>) . '
          'FILTER (?a < ?c) }')
    assert table_multiset(ep.query(t2)) == ref_multiset(ep.parse(t2), store)


def test_negative_limit_offset_rejected(graph):
    _, d = graph
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y } LIMIT -3', d)
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y } OFFSET -1', d)
    with pytest.raises(ParseError):
        parse_query('SELECT ?x WHERE { ?x <knows> ?y } LIMIT 3.5', d)


def test_result_memo_smaller_than_batch(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d, result_cache_size=2)
    texts = [f'SELECT ?a WHERE {{ ?a <city> ?c . FILTER (?c != <{c}>) }}'
             for c in ("paris", "tokyo", "oslo")] + [
        'SELECT ?a ?b WHERE { ?a <knows> ?b }',
        'SELECT ?a ?p WHERE { ?a <likes> ?p }',
    ]
    # batch wider than the LRU: must still answer every text (regression:
    # the trim used to evict the current batch's entries before lookup)
    tables = ep.query_many(texts)
    assert [t.num_matches for t in tables] == [3, 3, 4, 8, 8]
    assert len(ep._results) == 2
    ep0 = SparqlEndpoint(store, d, result_cache_size=0)   # memo disabled
    assert [t.num_matches for t in ep0.query_many(texts)] == [3, 3, 4, 8, 8]


def test_mixed_space_variable_rejected(graph):
    _, d = graph
    # ?v binds predicate ids in one leaf and entity ids in another —
    # disjoint dictionary spaces cannot share a column; must fail at
    # compile time, not crash (or silently mis-decode) at decode time
    with pytest.raises(ParseError):
        compile_query(parse_query(
            'SELECT ?v WHERE { { ?x <likes> ?v } UNION { ?a ?v ?b } }',
            d), d)
    # predicate-only variables remain fine
    compile_query(parse_query('SELECT ?a ?v ?b WHERE { ?a ?v ?b }', d), d)


def test_values_forms_and_errors(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    # single-var and grouped forms constrain identically
    single = ep.query('SELECT ?a ?b WHERE { VALUES ?a { <alice> } '
                      '?a <knows> ?b }')
    grouped = ep.query('SELECT ?a ?b WHERE { VALUES (?a) { (<alice>) } '
                       '?a <knows> ?b }')
    assert table_multiset(single) == table_multiset(grouped)
    assert {r[0] for r in single.rows()} == {"alice"}
    # an UNDEF cell is compatible with every binding of that variable
    undef = ep.query('SELECT ?a ?b WHERE { '
                     'VALUES (?a ?b) { (<alice> UNDEF) } ?a <knows> ?b }')
    assert table_multiset(undef) == table_multiset(single)
    # empty VALUES block: joins everything away
    assert ep.query('SELECT ?a WHERE { VALUES ?a { } ?a <knows> ?b }'
                    ).num_matches == 0
    # VALUES rows multiply like any multiset operand (duplicate row)
    dup = ep.query('SELECT ?a ?b WHERE { VALUES ?a { <alice> <alice> } '
                   '?a <knows> ?b }')
    assert dup.num_matches == 2 * single.num_matches
    assert ep.stats.values_joins > 0
    for bad in [
            # unknown entity: same contract as triple constants
            'SELECT ?a WHERE { VALUES ?a { <nobody> } ?a <knows> ?b }',
            # arity mismatch between the var list and a row
            'SELECT ?a WHERE { VALUES (?a ?b) { (<alice>) } }',
            # duplicate variable in the var list
            'SELECT ?a WHERE { VALUES (?a ?a) { (<alice> <bob>) } }',
            # no variables at all
            'SELECT ?a WHERE { VALUES { <alice> } ?a <knows> ?b }',
            # unterminated block
            'SELECT ?a WHERE { VALUES ?a { <alice> ',
            # VALUES var used in predicate position: space mismatch
            'SELECT ?a WHERE { VALUES ?v { <alice> } ?a ?v ?b }']:
        with pytest.raises(ParseError):
            ep.parse(bad)


def test_result_memo_byte_bound(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d, result_cache_bytes=200)
    t1 = 'SELECT ?a ?b WHERE { ?a <knows> ?b }'       # 8*2*8 = 128 B
    t2 = 'SELECT ?a ?p WHERE { ?a <likes> ?p }'       # 128 B -> evicts t1
    ep.query(t1)
    assert len(ep._results) == 1
    ep.query(t2)
    assert len(ep._results) == 1 and ep._result_bytes <= 200
    big = 'SELECT ?x ?y ?z WHERE { ?x <knows> ?y . ?y <knows> ?z }'
    ep.query(big)                  # > budget: never admitted
    assert all(k[0] != big for k in ep._results)


def test_parsed_modifier_shapes(graph):
    _, d = graph
    p = parse_query('SELECT DISTINCT ?a ?b WHERE { ?a <knows> ?b } '
                    'ORDER BY DESC(?a) ?b LIMIT 4 OFFSET 2', d)
    assert p.form == "select" and p.distinct
    assert p.order_by == [("?a", False), ("?b", True)]
    assert p.limit == 4 and p.offset == 2
    root = compile_query(p, d)
    assert isinstance(root, ProjectNode)
    assert root.projection == ["?a", "?b"]


# ---------------------------------------------------------------------------
# engine counters + scan invariants
# ---------------------------------------------------------------------------


def assert_scan_invariants(eng: QueryEngine) -> None:
    assert eng.stats.scans_deduped >= 0
    assert eng.stats.scans_executed == eng.stats.scan_cache_misses


def test_per_operator_counters(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    ep.query_many([
        'SELECT ?a ?c WHERE { ?a <knows> ?b . OPTIONAL { ?b <city> ?c } }',
        'SELECT ?x WHERE { { ?x <knows> ?b } UNION { ?x <likes> ?p } }',
        'SELECT ?a WHERE { ?a <city> ?c . FILTER (?c = <paris>) }',
    ])
    s = ep.stats
    assert s.bgp_leaves == 5          # 2 + 2 + 1
    assert s.optional_joins == 1
    assert s.union_branches == 2
    assert s.filters_applied == 1
    assert s.queries == 5             # leaves executed through the engine
    assert_scan_invariants(ep.engine)


@pytest.mark.parametrize("backend", BACKENDS)
def test_scan_invariants_wildcard_empty_shards(graph, backend):
    store, d = graph
    # 8 shards over 5 predicates: some shards are guaranteed empty
    st = ShardedTripleStore.from_store(store, 8)
    assert any(sh.num_triples == 0 for sh in st.shards)
    eng = QueryEngine(backend=backend)
    qs = [
        QueryGraph([TriplePattern("?x", "?p", "?y")], []),
        QueryGraph([TriplePattern("?s", "?q", "?o")], []),   # alpha-equiv
        QueryGraph([TriplePattern("?x", "?p", "?y"),
                    TriplePattern("?y", d.predicate_id("city"), "?c")], []),
    ]
    out = eng.execute_batch(st, qs)
    assert out[0].num_matches == store.num_triples
    assert out[1].num_matches == store.num_triples
    assert_scan_invariants(eng)
    assert eng.stats.cache_hits >= 1          # alpha-equivalent BGP shared
    # repeat: now everything is cache-hot; invariants must keep holding
    eng.execute_batch(st, qs)
    assert_scan_invariants(eng)


@pytest.mark.parametrize("backend", BACKENDS)
def test_scan_invariants_empty_sharded_store(backend):
    z = np.zeros(0, dtype=np.int64)
    st = ShardedTripleStore(z, z, z, num_entities=4, num_predicates=3,
                            num_shards=4)
    eng = QueryEngine(backend=backend)
    qs = [QueryGraph([TriplePattern("?x", "?p", "?y")], []),
          QueryGraph([TriplePattern("?x", 1, "?y")], [])]
    out = eng.execute_batch(st, qs)
    assert out[0].num_matches == 0 and out[1].num_matches == 0
    assert_scan_invariants(eng)


def test_algebra_shares_sub_bgp_cache_entries(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    # alpha-equivalent sub-BGPs across DIFFERENT algebra queries (and one
    # plain BGP query) must share result-cache entries
    ep.query('SELECT ?a ?r WHERE { ?a <likes> ?q . '
             'OPTIONAL { ?q <rating> ?r } }')
    before = ep.stats.cache_hits
    ep.query('SELECT ?z WHERE { ?z <likes> ?w . FILTER (?w != <p1>) }')
    assert ep.stats.cache_hits == before + 1   # ?z <likes> ?w == ?a <likes> ?q
    res = ep.engine.execute(store, parse_sparql(
        'SELECT ?u WHERE { ?u <likes> ?v }', d))
    assert ep.stats.cache_hits == before + 2
    assert res.num_matches == 8
    assert_scan_invariants(ep.engine)


# ---------------------------------------------------------------------------
# endpoint facade
# ---------------------------------------------------------------------------


def test_endpoint_query_ask_explain(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d, backend="numpy")
    tbl = ep.query('SELECT ?a ?c WHERE { ?a <city> ?c . '
                   'FILTER (?c != <paris>) } ORDER BY ?a')
    assert tbl.var_names == ["?a", "?c"]
    assert tbl.rows()[0] == ("carol", "tokyo")
    assert ep.ask('ASK { ?x <knows> <carol> }') is True
    assert ep.ask('ASK { <carol> <knows> <alice> }') is False
    with pytest.raises(ParseError):
        ep.query('ASK { ?x <knows> ?y }')
    # plan cache: same text -> same compiled object
    t = 'SELECT ?x WHERE { ?x <likes> ?p }'
    assert ep.parse(t) is ep.parse(t)
    # explain shows the tree and cache provenance after a warm run
    ep.query(t)
    out = ep.explain(t)
    assert "Project" in out and "BGP" in out
    assert "result-cache=hit" in out and "scans-cached=1/1" in out
    exp2 = ep.explain('SELECT ?a WHERE { ?a <city> ?c . '
                      'OPTIONAL { ?a <likes> ?p } . '
                      'FILTER (BOUND(?p)) } LIMIT 2')
    for label in ("Filter", "Optional", "OrderSlice", "Project"):
        assert label in exp2


def test_endpoint_query_many_batches(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    texts = ['SELECT ?a WHERE { ?a <city> <paris> }',
             'ASK { ?x <knows> ?y }',
             'SELECT ?x WHERE { { ?x <knows> ?b } UNION '
             '{ ?x <likes> ?p } }']
    tables = ep.query_many(texts)
    assert tables[0].num_matches == 2
    assert tables[1].num_matches == 1          # ASK -> 1-row truthy table
    plan = ep.parse(texts[2])
    assert table_multiset(tables[2]) == ref_multiset(plan, store)
    assert ep.stats.batches == 1               # ONE engine batch for all


def test_solution_table_surface(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    tbl = ep.query('SELECT ?a ?r WHERE { ?a <likes> ?p . '
                   'OPTIONAL { ?p <rating> ?r } }')
    assert len(tbl) == tbl.num_matches == tbl.bindings.shape[0]
    assert tbl.result_bytes() == tbl.num_matches * 2 * 8
    assert set(tbl.var_names) == {"?a", "?r"}
    rows = tbl.rows()
    assert any(r[1] is None for r in rows)     # frank->p4 has no rating
    raw = tbl.rows(decoded=False)
    assert any(x == -1 for r in raw for x in r)


# ---------------------------------------------------------------------------
# feasibility + cost plumbing
# ---------------------------------------------------------------------------


def test_feasibility_excludes_optional_right_sides(graph):
    _, d = graph
    plan = compile_query(parse_query(
        'SELECT ?a ?c WHERE { ?a <knows> ?b . '
        'OPTIONAL { ?b <city> ?c } }', d), d)
    req = feasibility_patterns(plan)
    obs = observed_patterns(plan)
    assert req is not None and len(req) == 1    # knows leaf only
    assert len(obs) == 2                        # placement learns both
    # a pure-OPTIONAL query has no required anchor -> not certifiable
    plan2 = compile_query(parse_query(
        'SELECT ?p WHERE { OPTIONAL { ?x <likes> ?p } }', d), d)
    assert feasibility_patterns(plan2) is None
    # plain QueryGraph keeps the one-pattern behavior
    qg = parse_sparql('SELECT ?a WHERE { ?a <knows> ?b }', d)
    assert len(feasibility_patterns(qg)) == 1


def test_estimate_cost_on_plans(graph):
    store, d = graph
    plan = compile_query(parse_query(
        'SELECT ?a WHERE { ?a <knows> ?b . OPTIONAL { ?b <city> ?c } . '
        'FILTER (?a != <bob>) }', d), d)
    c, w = estimate_query_cost(store, plan)
    assert c > 0 and w > 0
    qg = parse_sparql('SELECT ?a ?b WHERE { ?a <knows> ?b }', d)
    c1, _ = estimate_query_cost(store, qg)
    assert c >= c1                              # plan adds the optional leaf


# ---------------------------------------------------------------------------
# end-to-end: EdgeCloudSystem rounds + serving pool + delta-rebalance parity
# ---------------------------------------------------------------------------

ROUND_QUERIES = [
    'SELECT ?a ?c WHERE { ?a <knows> ?b . OPTIONAL { ?b <city> ?c } }',
    'SELECT ?x WHERE { { ?x <knows> ?b } UNION { ?x <likes> ?p } }',
    'SELECT DISTINCT ?c WHERE { ?a <city> ?c . FILTER (?c != <paris>) } '
    'ORDER BY ?c',
    'ASK { ?x <knows> <carol> }',
    'SELECT ?p ?r WHERE { ?x <likes> ?p . ?p <rating> ?r . '
    'FILTER (?r > "4") } LIMIT 10',
]

HISTORY = [
    'SELECT ?a ?b WHERE { ?a <knows> ?b }',
    'SELECT ?a ?p WHERE { ?a <likes> ?p }',
    'SELECT ?a ?c WHERE { ?a <city> ?c }',
    'SELECT ?p ?r WHERE { ?p <rating> ?r }',
    'SELECT ?x ?p ?r WHERE { ?x <likes> ?p . ?p <rating> ?r }',
]


def tiny_params():
    # slow cloud link + fast edge CPUs: at this toy scale the cost model
    # must actually prefer edges for feasible queries
    return SystemParams.synthetic(n_users=6, n_edges=2, seed=3,
                                  cloud_mbps=0.05, f_ghz=2.0)


def make_system(store, d, backend="numpy", budget=10 ** 9):
    sys_ = EdgeCloudSystem(store, d, tiny_params(), storage_budgets=budget,
                           backend=backend)
    sys_.prepare([HISTORY for _ in range(sys_.params.N)])
    return sys_


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
def test_round_batched_edge_matches_cloud_oracle(graph, backend, kind):
    store, d = graph
    st = store_of(kind, store)
    sys_ = make_system(st, d, backend=backend)
    ep = SparqlEndpoint.from_system(sys_)
    pairs = [(i % sys_.params.N, t)
             for i, t in enumerate(ROUND_QUERIES * 2)]
    rep = ep.run_round(pairs, policy="bnb")
    assert len(rep.outcomes) == len(pairs)
    edge_assigned = [o for o in rep.outcomes if o.assigned_to >= 0]
    assert edge_assigned, "algebra queries should reach the edges"
    for (user, text), o in zip(pairs, rep.outcomes):
        plan = ep.parse(text)
        want = ref_multiset(plan, store)
        assert o.n_matches == sum(want.values())
        if o.assigned_to >= 0:
            es = sys_.edges[o.assigned_to]
            got = evaluate_plan(plan, es.store, sys_.engine)
            assert table_multiset(got) == want     # edge == cloud oracle


def test_parity_after_delta_rebalance(graph):
    store, d = graph
    st = store_of("sharded", store)
    # prepare WITHOUT the optional/rating shapes resident, then let the
    # round observe them and delta-rebalance the placement in
    sys_ = EdgeCloudSystem(st, d, tiny_params(), storage_budgets=10 ** 9)
    sys_.prepare([HISTORY[:2] for _ in range(sys_.params.N)])
    ep = SparqlEndpoint.from_system(sys_)
    pairs = [(i % sys_.params.N, t)
             for i, t in enumerate(ROUND_QUERIES * 2)]
    for _ in range(3):                      # observe the drifted workload
        ep.run_round(pairs, policy="greedy")
    epoch0 = sys_.placement_epoch
    changes = sys_.rebalance_all(use_deltas=True)
    assert sys_.placement_epoch == epoch0 + 1
    assert any(a > 0 for a, _ in changes.values())
    assert any(e.mode == "delta" for e in sys_.last_rebalance.per_edge)
    rep = ep.run_round(pairs, policy="bnb")
    by_edge = {k: v for k, v in rep.assignment_counts.items() if k >= 0}
    assert sum(by_edge.values()) > 0
    for (user, text), o in zip(pairs, rep.outcomes):
        plan = ep.parse(text)
        want = ref_multiset(plan, store)
        assert o.n_matches == sum(want.values())
        if o.assigned_to >= 0:
            got = evaluate_plan(plan, sys_.edges[o.assigned_to].store,
                                sys_.engine)
            assert table_multiset(got) == want


@pytest.mark.parametrize("backend", BACKENDS)
def test_serving_pool_algebra_payloads(graph, backend):
    store, d = graph
    st = store_of("sharded", store)
    eng = QueryEngine(backend=backend)
    runner = make_sparql_runner(st, eng)
    pool = OffloadServingPool(
        replicas=[Replica(0, {0}, 2e9, 50e6, runner),
                  Replica(1, {0, 1}, 2e9, 80e6, runner)],
        cloud_runner=runner)
    ep = SparqlEndpoint(st, d, engine=eng, pool=pool)
    texts = ROUND_QUERIES * 2
    batch = ep.admit_many(texts, class_of=lambda plan: 0, policy="greedy")
    assert len(batch.responses) == len(texts)
    for text, res in zip(texts, batch.responses):
        want = ref_multiset(ep.parse(text), store)
        assert table_multiset(res) == want
    assert_scan_invariants(eng)


@pytest.mark.parametrize("overlap", [True, "process"])
def test_round_batched_overlap_with_plans(graph, overlap):
    store, d = graph
    sys_ = make_system(store_of("sharded", store), d)
    ep = SparqlEndpoint.from_system(sys_)
    pairs = [(i % sys_.params.N, t)
             for i, t in enumerate(ROUND_QUERIES * 2)]
    queries = [(u, ep.parse(t)) for u, t in pairs]
    seq = sys_.run_round_batched(queries, policy="greedy", observe=False)
    ov = sys_.run_round_batched(queries, policy="greedy", observe=False,
                                overlap=overlap)
    sys_.close_overlap_pool()
    assert [o.n_matches for o in seq.outcomes] == \
        [o.n_matches for o in ov.outcomes]
    assert [o.assigned_to for o in seq.outcomes] == \
        [o.assigned_to for o in ov.outcomes]


def test_run_round_unbatched_handles_plans(graph):
    store, d = graph
    sys_ = make_system(store, d)
    ep = SparqlEndpoint.from_system(sys_)
    queries = [(i % sys_.params.N, ep.parse(t))
               for i, t in enumerate(ROUND_QUERIES)]
    rep = sys_.run_round(queries, policy="greedy")
    for (u, plan), o in zip(queries, rep.outcomes):
        assert o.n_matches == sum(ref_multiset(plan, store).values())
