"""Sharded RDF storage: ShardedTripleStore == TripleStore as solution
multisets over the adversarial BGP matrix on both backends, shard-count edge
cases (S=1, S > num_predicates, empty shards after subgraph), and the
end-to-end batched system path over a sharded cloud store."""

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import RDFStore, TripleStore, triples_size_bytes
from repro.rdf.sharding import ShardedTripleStore, shard_of_pred
from repro.sparql.engine import QueryEngine
from repro.sparql.matcher import match_bgp, match_oracle
from repro.sparql.query import QueryGraph, TriplePattern, parse_sparql

from test_engine import ADVERSARIAL, BACKENDS, sol_rows

SHARD_COUNTS = [1, 2, 5, 64]      # 64 > num_predicates of the small stores


def paired_stores(rng, num_shards, n_ent=12, n_pred=3, n_trip=40):
    s = rng.integers(0, n_ent, n_trip)
    p = rng.integers(0, n_pred, n_trip)
    o = rng.integers(0, n_ent, n_trip)
    return (TripleStore(s, p, o, n_ent, n_pred),
            ShardedTripleStore(s, p, o, n_ent, n_pred,
                               num_shards=num_shards))


def test_stores_satisfy_protocol():
    rng = np.random.default_rng(0)
    mono, sharded = paired_stores(rng, 3)
    assert isinstance(mono, RDFStore)
    assert isinstance(sharded, RDFStore)


@pytest.mark.parametrize("num_shards", SHARD_COUNTS)
def test_sharded_store_invariants(num_shards):
    rng = np.random.default_rng(1)
    mono, sh = paired_stores(rng, num_shards, n_trip=80)
    assert sh.num_triples == mono.num_triples
    assert sh.num_shards == num_shards
    assert sorted(map(tuple, sh.triples().tolist())) == \
        sorted(map(tuple, mono.triples().tolist()))
    assert np.array_equal(sh.pred_count, mono.pred_count)
    assert np.array_equal(sh.pred_distinct_s, mono.pred_distinct_s)
    assert np.array_equal(sh.pred_distinct_o, mono.pred_distinct_o)
    assert sh.size_bytes() == mono.size_bytes() == \
        triples_size_bytes(mono.num_triples)
    # composite version: distinct from any shard's and any other store's
    assert sh.version != mono.version
    assert len(set(sh.version)) == len(sh.version)
    for pid in range(mono.num_predicates):
        # global ids resolve to exactly this predicate's triples
        tids = sh.pred_tids(pid)
        k = sh.shard_of_pred(pid)
        assert k == int(shard_of_pred(pid, num_shards))
        assert np.all(sh.p[tids] == pid)
        assert len(tids) == mono.pred_count[pid]
        idx = sh.pred_index(pid)
        assert np.array_equal(sh.s[idx.s_order], idx.s_sorted)
        assert np.array_equal(sh.o[idx.o_order], idx.o_sorted)
        assert np.all(np.diff(idx.s_sorted) >= 0)


def test_sharded_store_dedupes_like_monolithic():
    s = np.array([0, 0, 1, 1, 0])
    p = np.array([0, 0, 1, 1, 0])
    o = np.array([2, 2, 3, 3, 2])    # triple (0,0,2) three times, (1,1,3) x2
    mono = TripleStore(s, p, o, 4, 2)
    sh = ShardedTripleStore(s, p, o, 4, 2, num_shards=3)
    assert sh.num_triples == mono.num_triples == 2


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("num_shards", SHARD_COUNTS)
def test_sharded_equals_monolithic_adversarial(backend, num_shards):
    """Equivalence matrix through execute_batch on both store kinds."""
    rng = np.random.default_rng(2)
    eng = QueryEngine(backend=backend)
    for trial in range(4):
        mono, sh = paired_stores(rng, num_shards,
                                 n_trip=int(rng.integers(5, 50)))
        queries = [QueryGraph(pats, []) for pats in ADVERSARIAL]
        got = eng.execute_batch(sh, queries)
        want = eng.execute_batch(mono, queries)
        for q, res, ref in zip(queries, got, want):
            assert sol_rows(res) == sol_rows(ref)
            sols, vs = match_oracle(mono, q)
            if vs:
                assert {tuple(r) for r in res.project(vs).tolist()} == sols


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_subgraph_empty_shards(backend):
    """subgraph keeps the store sharded; shards left empty still answer."""
    rng = np.random.default_rng(3)
    mono, sh = paired_stores(rng, 4, n_pred=5, n_trip=120)
    # keep only one predicate's triples -> every other shard is empty
    keep_pid = 2
    sub = sh.subgraph(sh.pred_tids(keep_pid))
    assert isinstance(sub, ShardedTripleStore)
    assert sub.num_shards == 4
    empties = [s for s in sub.shards if s.num_triples == 0]
    assert len(empties) >= 1
    sub_mono = mono.subgraph(mono.pred_tids(keep_pid))
    eng = QueryEngine(backend=backend)
    queries = [QueryGraph(pats, []) for pats in ADVERSARIAL]
    for res, ref in zip(eng.execute_batch(sub, queries),
                        eng.execute_batch(sub_mono, queries)):
        assert sol_rows(res) == sol_rows(ref)
    # fully empty subgraph
    empty = sh.subgraph(np.zeros(0, dtype=np.int64))
    assert empty.num_triples == 0
    for res in eng.execute_batch(empty, queries):
        assert res.num_matches == 0


def test_jax_staging_lru_scales_to_shard_count():
    """A store with more shards than the staging LRU's default slots must
    not evict its own shards mid-scan (re-uploading every round)."""
    from repro.sparql.engine import JaxBackend
    rng = np.random.default_rng(6)
    mono, sh = paired_stores(rng, 6, n_pred=6, n_trip=120)
    jb = JaxBackend(bt=64, max_staged=2)     # fewer slots than shards
    queries = [QueryGraph(pats, []) for pats in ADVERSARIAL]
    eng = QueryEngine(backend=jb)
    refs = eng.execute_batch(mono, queries)
    for res, ref in zip(eng.execute_batch(sh, queries), refs):
        assert sol_rows(res) == sol_rows(ref)
    non_empty = sum(1 for s in sh.shards if s.num_triples)
    staged_shard_versions = {s.version for s in sh.shards} & \
        set(jb._staged)
    assert len(staged_shard_versions) == non_empty


def test_match_bgp_works_directly_on_sharded_store():
    """The plain matcher path (no engine) also accepts a sharded store."""
    rng = np.random.default_rng(4)
    mono, sh = paired_stores(rng, 3, n_trip=60)
    for pats in ADVERSARIAL:
        q = QueryGraph(pats, [])
        assert sol_rows(match_bgp(sh, q)) == sol_rows(match_bgp(mono, q))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_system_round_matches_monolithic(backend):
    """run_round_batched over a sharded cloud store == monolithic system."""
    g = generate_watdiv_like(scale=0.5, seed=31)
    params = SystemParams.synthetic(n_users=8, n_edges=2, seed=5)
    history = [workload_sparql(g, 3, seed=200 + n) for n in range(8)]

    def build(store):
        sys_ = EdgeCloudSystem(store, g.dictionary, params,
                               storage_budgets=150_000, backend=backend)
        sys_.prepare(history)
        return sys_

    sys_mono = build(g.store)
    sys_sh = build(ShardedTripleStore.from_store(g.store, 4))
    assert isinstance(sys_sh.cloud.store, ShardedTripleStore)
    # pattern-induced edge stores inherit the cloud store's kind
    for es in sys_sh.edges:
        assert isinstance(es.store, ShardedTripleStore)
        assert es.used_bytes() <= es.budget
    queries = [(i % 8, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, 10, seed=17))]
    rep_mono = sys_mono.run_round_batched(queries, policy="greedy",
                                          observe=False)
    rep_sh = sys_sh.run_round_batched(queries, policy="greedy",
                                      observe=False)
    assert rep_sh.assignment_counts == rep_mono.assignment_counts
    for a, b in zip(rep_mono.outcomes, rep_sh.outcomes):
        assert a.n_matches == b.n_matches
    # per-query solution multisets agree through execute_batch as well
    qs = [q for (_, q) in queries]
    for res, ref in zip(sys_sh.engine.execute_batch(sys_sh.cloud.store, qs),
                        sys_mono.engine.execute_batch(g.store, qs)):
        assert sol_rows(res) == sol_rows(ref)


def test_sharded_rebalance_keeps_completeness():
    """Dynamic placement over a sharded cloud store: G[P] matches == G
    matches after rebalancing (the paper's completeness guarantee)."""
    from repro.core.pattern import pattern_of
    g = generate_watdiv_like(scale=0.5, seed=37)
    params = SystemParams.synthetic(n_users=6, n_edges=2, seed=9)
    sys_ = EdgeCloudSystem(ShardedTripleStore.from_store(g.store, 3),
                           g.dictionary, params, storage_budgets=150_000)
    sys_.prepare([workload_sparql(g, 3, seed=300 + n) for n in range(6)])
    queries = [(i % 6, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, 8, seed=19))]
    for _ in range(2):
        sys_.run_round_batched(queries, policy="greedy", execute=True)
    sys_.rebalance_all()
    checked = 0
    for (_, q) in queries:
        p = pattern_of(q)
        want = sol_rows(sys_.engine.execute(sys_.cloud.store, q))
        for es in sys_.edges:
            if es.can_execute(p):
                assert sol_rows(sys_.engine.execute(es.store, q)) == want
                checked += 1
    assert checked >= 1
