"""End-to-end edge-cloud system: correctness of the full paper pipeline."""

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.core.pattern import pattern_of
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.sparql.matcher import match_bgp
from repro.sparql.query import parse_sparql


@pytest.fixture(scope="module")
def system():
    g = generate_watdiv_like(scale=1.0, seed=42)
    params = SystemParams.synthetic(n_users=12, n_edges=3, seed=7)
    sys_ = EdgeCloudSystem(g.store, g.dictionary, params,
                           storage_budgets=200_000)
    history = [workload_sparql(g, 4, seed=100 + n) for n in range(12)]
    sys_.prepare(history)
    return g, sys_, history


def make_queries(g, sys_, n=12, seed=5):
    texts = workload_sparql(g, n, seed=seed)
    return [(i % sys_.params.N, parse_sparql(t, g.dictionary))
            for i, t in enumerate(texts)]


def test_prepare_deploys_subgraphs(system):
    g, sys_, history = system
    deployed = [es for es in sys_.edges if es.store is not None
                and es.store.num_triples > 0]
    assert len(deployed) >= 2
    for es in deployed:
        assert es.used_bytes() <= es.budget * 1.3  # size model consistent
        assert len(es.index) > 0


def test_edge_results_match_cloud(system):
    """The paper's core correctness claim: a query isomorphic to a resident
    pattern gets IDENTICAL results from G[P] and from G."""
    g, sys_, history = system
    checked = 0
    for (user, q) in make_queries(g, sys_, n=20, seed=9):
        p = pattern_of(q)
        for es in sys_.edges:
            if es.can_execute(p):
                res_edge = match_bgp(es.store, q)
                res_cloud = match_bgp(sys_.cloud.store, q)
                def rows(res):
                    order = sorted(res.var_names)
                    idx = [res.var_names.index(v) for v in order]
                    return {tuple(r[idx]) for r in res.bindings}
                assert rows(res_edge) == rows(res_cloud)
                checked += 1
    assert checked >= 3


def test_executability_requires_isomorphism(system):
    g, sys_, history = system
    # a query whose pattern was never deployed anywhere: 4-cycle over follows
    d = g.dictionary
    q = parse_sparql(
        "SELECT ?a WHERE { ?a <follows> ?b . ?b <follows> ?c . "
        "?c <follows> ?d2 . ?d2 <follows> ?a }", d)
    tasks = sys_.build_tasks([(0, q)])
    assert tasks.e.sum() == 0  # not resident -> cloud only


def test_run_round_all_policies(system):
    g, sys_, history = system
    queries = make_queries(g, sys_, n=12, seed=11)
    results = {}
    for policy in ["bnb", "cloud_only", "random", "edge_first", "greedy"]:
        rep = sys_.run_round(queries, policy=policy, execute=True)
        assert len(rep.outcomes) == len(queries)
        assert sum(rep.assignment_counts.values()) == len(queries)
        # every assignment was actually executable
        for o in rep.outcomes:
            if o.assigned_to >= 0:
                assert o.assigned_to in o.executable_edges
        results[policy] = rep.objective
    # paper's headline ordering: B&B never loses to any baseline
    for policy, obj in results.items():
        assert results["bnb"] <= obj + 1e-9, policy


def test_dynamic_rebalance_adds_hot_pattern(system):
    g, sys_, history = system
    queries = make_queries(g, sys_, n=16, seed=13)
    # run several rounds so frequencies accumulate, then rebalance
    for _ in range(3):
        sys_.run_round(queries, policy="greedy", execute=False)
    changes = sys_.rebalance_all()
    assert set(changes) == {0, 1, 2}
    for es in sys_.edges:
        assert es.placement.used_bytes() <= es.budget


def test_modeled_latency_positive(system):
    g, sys_, history = system
    queries = make_queries(g, sys_, n=8, seed=17)
    rep = sys_.run_round(queries, policy="bnb", execute=False)
    assert all(o.modeled_latency > 0 for o in rep.outcomes)
    assert np.isfinite(rep.objective)
