"""Batched multi-backend query engine: oracle equivalence, cache
correctness, and the paper's completeness guarantee through the batched
path (G[P] matches == G matches, also after rebalancing)."""

import numpy as np
import pytest

from repro.core.cost import (SystemParams, measured_query_cost,
                             measured_query_cost_batch)
from repro.core.pattern import pattern_of
from repro.edge.system import EdgeCloudSystem
from repro.kernels.triple_scan import triple_scan, triple_scan_many
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.sparql.engine import (JaxBackend, MatcherBackend, QueryEngine,
                                 available_backends, get_backend, query_key,
                                 scan_key)
from repro.sparql.matcher import match_bgp, match_oracle
from repro.sparql.query import QueryGraph, TriplePattern, parse_sparql

BACKENDS = ["numpy", "jax"]


def sol_rows(res, var_order=None):
    """Solution multiset with columns ordered by variable name."""
    order = var_order or sorted(res.var_names)
    idx = [res.var_names.index(v) for v in order]
    return sorted(map(tuple, res.bindings[:, idx].tolist()))


def random_store(rng, n_ent=12, n_pred=3, n_trip=40):
    return TripleStore(rng.integers(0, n_ent, n_trip),
                       rng.integers(0, n_pred, n_trip),
                       rng.integers(0, n_ent, n_trip), n_ent, n_pred)


# adversarial BGPs: repeated variables (incl. within one pattern), variable
# predicates, cartesian components, and a constant pair guaranteed empty
ADVERSARIAL = [
    [TriplePattern("?x", 0, "?x")],                         # self loop
    [TriplePattern("?x", "?p", "?y")],                      # var predicate
    [TriplePattern("?x", 0, "?y"), TriplePattern("?a", 1, "?b")],  # cartesian
    [TriplePattern("?x", 0, "?y"), TriplePattern("?y", 0, "?x")],  # 2-cycle
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?y", "?p", "?z")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?x", 1, "?y")],  # parallel
    [TriplePattern(0, 0, 1), TriplePattern("?x", 0, 1)],    # ground pattern
    [TriplePattern("?x", "?x", "?x")],                      # s == p == o
]


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_equals_matcher_equals_oracle(backend):
    """Equivalence matrix: match_bgp == match_oracle == batched engine."""
    rng = np.random.default_rng(0)
    eng = QueryEngine(backend=backend)
    for trial in range(6):
        store = random_store(rng, n_trip=int(rng.integers(5, 50)))
        queries = [QueryGraph(pats, []) for pats in ADVERSARIAL]
        batch = eng.execute_batch(store, queries)
        for q, res in zip(queries, batch):
            ref = match_bgp(store, q)
            assert sol_rows(res) == sol_rows(ref)
            sols, vs = match_oracle(store, q)
            if vs:
                got = {tuple(r) for r in res.project(vs).tolist()}
                assert got == sols
            else:
                assert (res.num_matches > 0) == (len(sols) > 0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_on_workload_queries(backend):
    g = generate_watdiv_like(scale=0.5, seed=3)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 12, seed=1)]
    eng = QueryEngine(backend=backend)
    for q, res in zip(qs, eng.execute_batch(g.store, qs)):
        assert sol_rows(res) == sol_rows(match_bgp(g.store, q))


def test_backends_registry():
    assert {"numpy", "jax"} <= set(available_backends())
    assert isinstance(get_backend("jax"), MatcherBackend)
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_scan_and_query_keys():
    # scan identity ignores variable names but not repetition structure
    assert scan_key(TriplePattern("?x", 0, "?x")) == \
        scan_key(TriplePattern("?y", 0, "?y"))
    assert scan_key(TriplePattern("?x", 0, "?y")) != \
        scan_key(TriplePattern("?x", 0, "?x"))
    # alpha-equivalent queries share a cache key; constants differ it
    qa = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    qb = QueryGraph([TriplePattern("?u", 0, "?v")], [])
    qc = QueryGraph([TriplePattern("?x", 1, "?y")], [])
    assert query_key(qa)[0] == query_key(qb)[0]
    assert query_key(qa)[0] != query_key(qc)[0]


def test_alpha_equivalent_queries_share_cache_with_correct_names():
    rng = np.random.default_rng(5)
    store = random_store(rng)
    eng = QueryEngine()
    qa = QueryGraph([TriplePattern("?x", 0, "?y"),
                     TriplePattern("?y", 1, "?z")], [])
    qb = QueryGraph([TriplePattern("?u", 0, "?v"),
                     TriplePattern("?v", 1, "?w")], [])
    ra = eng.execute(store, qa)
    rb = eng.execute(store, qb)
    assert eng.stats.cache_hits == 1         # qb resolved from qa's entry
    assert set(rb.var_names) == {"?u", "?v", "?w"}
    assert sol_rows(ra, ["?x", "?y", "?z"]) == sol_rows(rb, ["?u", "?v", "?w"])


def test_cache_hit_after_repeat_and_invalidation_on_store_change():
    g = generate_watdiv_like(scale=0.5, seed=7)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 8, seed=2)]
    eng = QueryEngine()
    eng.execute_batch(g.store, qs)
    h0, m0 = eng.stats.cache_hits, eng.stats.cache_misses
    again = eng.execute_batch(g.store, qs)
    assert eng.stats.cache_hits - h0 == len(qs)      # all hits on repeat
    assert eng.stats.cache_misses == m0
    # a DIFFERENT store (e.g. post-rebalance deployment) must not serve
    # the old entries: the version token differs
    sub = g.store.subgraph(np.arange(g.store.num_triples // 2))
    assert sub.version != g.store.version
    for q in qs:
        res_sub = eng.execute(sub, q)
        assert sol_rows(res_sub) == sol_rows(match_bgp(sub, q))
    # original store still hits its own (untouched) entries
    h1 = eng.stats.cache_hits
    eng.execute_batch(g.store, qs)
    assert eng.stats.cache_hits - h1 == len(qs)
    for q, res in zip(qs, again):
        assert sol_rows(res) == sol_rows(match_bgp(g.store, q))


def test_cache_lru_eviction_bounds_entries():
    rng = np.random.default_rng(9)
    store = random_store(rng)
    eng = QueryEngine(cache_size=4)
    qs = [QueryGraph([TriplePattern("?x", 0, i)], []) for i in range(10)]
    eng.execute_batch(store, qs)
    assert len(eng._cache) == 4
    assert eng.stats.cache_evictions == 6


def test_scan_dedup_across_batch():
    rng = np.random.default_rng(11)
    store = random_store(rng)
    eng = QueryEngine(cache_size=0)          # isolate scan dedup from cache
    q = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    eng.execute_batch(store, [q] * 16)
    assert eng.stats.scans_requested == 16
    assert eng.stats.scans_executed == 1


def test_cache_put_overwrite_does_not_leak_bytes():
    """Regression: overwriting a result-cache key must release the
    displaced entry's bytes, not inflate _cached_bytes forever."""
    rng = np.random.default_rng(21)
    store = random_store(rng)
    eng = QueryEngine()
    res = eng.execute(store, QueryGraph([TriplePattern("?x", 0, "?y")], []))
    want = eng._cached_bytes
    assert want == eng._result_bytes(res) > 0
    for _ in range(5):                       # repeated overwrites of one key
        eng._cache_put(("k",), res)
    assert eng._cached_bytes == want + eng._result_bytes(res)
    assert eng.stats.cache_evictions == 0    # no spurious evictions


def test_scan_cache_survives_between_batches():
    """Scan LRU: with the result cache disabled, a repeated batch re-joins
    but serves its candidate scans from the cross-round cache."""
    rng = np.random.default_rng(25)
    store = random_store(rng)
    eng = QueryEngine(cache_size=0)          # force re-execution every batch
    qs = [QueryGraph([TriplePattern("?x", p, "?y")], []) for p in range(3)]
    eng.execute_batch(store, qs)
    assert eng.stats.scans_executed == 3
    assert eng.stats.scan_cache_hits == 0
    eng.execute_batch(store, qs)             # scans resolve from the LRU
    assert eng.stats.scans_executed == 3
    assert eng.stats.scan_cache_hits == 3
    # a different store version must not reuse the entries
    sub = store.subgraph(np.arange(store.num_triples // 2))
    eng.execute_batch(sub, qs)
    assert eng.stats.scans_executed == 6
    for q in qs:
        assert sol_rows(eng.execute(sub, q)) == sol_rows(match_bgp(sub, q))


def test_scan_cache_count_bound_with_empty_results():
    """Zero-byte (empty-candidate) entries must still be bounded: the byte
    cap alone would never evict them."""
    rng = np.random.default_rng(29)
    store = random_store(rng, n_ent=12)
    eng = QueryEngine(cache_size=0, scan_cache_size=4)
    # objects >= n_ent never match -> every scan result is empty (0 bytes)
    qs = [QueryGraph([TriplePattern("?x", 0, 1000 + i)], [])
          for i in range(12)]
    eng.execute_batch(store, qs)
    assert all(r.num_matches == 0 for r in eng.execute_batch(store, qs))
    assert len(eng._scan_cache) <= 4
    assert eng.stats.scan_cache_evictions >= 8


def test_scan_cache_byte_bound_eviction():
    rng = np.random.default_rng(27)
    store = random_store(rng, n_trip=200)
    one_scan = store.pred_tids(0).nbytes
    eng = QueryEngine(cache_size=0, scan_cache_bytes=one_scan * 2)
    qs = [QueryGraph([TriplePattern("?x", "?p", i)], []) for i in range(8)]
    eng.execute_batch(store, qs)
    assert eng.stats.scan_cache_evictions > 0
    assert eng._scan_cached_bytes <= eng.scan_cache_bytes
    # entries are (CandidateParts, put-time global-id offset)
    assert sum(parts.nbytes for parts, _ in eng._scan_cache.values()) == \
        eng._scan_cached_bytes
    eng.clear_cache()
    assert eng._scan_cached_bytes == 0 and not eng._scan_cache


def test_triple_scan_many_matches_single():
    rng = np.random.default_rng(13)
    tr = rng.integers(0, 30, (1000, 3)).astype(np.int32)
    import jax.numpy as jnp
    pats = np.array([[-1, 1, -1], [4, -1, -1], [-1, -1, -1], [2, 1, 9]],
                    np.int32)
    many = np.asarray(triple_scan_many(jnp.asarray(tr), jnp.asarray(pats),
                                       bt=256, interpret=True))
    for i in range(len(pats)):
        one = np.asarray(triple_scan(jnp.asarray(tr), jnp.asarray(pats[i]),
                                     bt=256, interpret=True))
        assert np.array_equal(many[i], one)


def test_jax_backend_prescan_equals_numpy_candidates():
    rng = np.random.default_rng(17)
    store = random_store(rng, n_trip=200)
    jb = JaxBackend(bt=64)
    nb = get_backend("numpy")
    tps = [tp for pats in ADVERSARIAL for tp in pats]
    pre = jb.prescan(store, tps)
    for tp in tps:
        want = np.sort(nb.candidates(store, tp))
        assert np.array_equal(np.sort(pre[scan_key(tp)]), want)
        assert np.array_equal(np.sort(jb.candidates(store, tp)), want)


def test_measured_cost_hooks_match_direct_path():
    g = generate_watdiv_like(scale=0.5, seed=19)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 6, seed=4)]
    eng = QueryEngine()
    c_b, w_b, n_b = measured_query_cost_batch(g.store, qs, eng)
    for i, q in enumerate(qs):
        c, w, n = measured_query_cost(g.store, q)
        assert (c, w, n) == (c_b[i], w_b[i], n_b[i])
        assert measured_query_cost(g.store, q, engine=eng) == (c, w, n)


# ---------------------------------------------------------------------------
# end-to-end: completeness guarantee through the batched system path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=BACKENDS)
def batched_system(request):
    g = generate_watdiv_like(scale=1.0, seed=42)
    params = SystemParams.synthetic(n_users=12, n_edges=3, seed=7)
    sys_ = EdgeCloudSystem(g.store, g.dictionary, params,
                           storage_budgets=200_000, backend=request.param)
    history = [workload_sparql(g, 4, seed=100 + n) for n in range(12)]
    sys_.prepare(history)
    return g, sys_


def make_queries(g, sys_, n, seed):
    texts = workload_sparql(g, n, seed=seed)
    return [(i % sys_.params.N, parse_sparql(t, g.dictionary))
            for i, t in enumerate(texts)]


def _check_completeness(g, sys_, queries):
    """Matches over G[P] == matches over G for pattern-isomorphic queries,
    exercised through the engine (the paper's Def. 5 guarantee)."""
    checked = 0
    for (_, q) in queries:
        p = pattern_of(q)
        want = sol_rows(sys_.engine.execute(sys_.cloud.store, q))
        for es in sys_.edges:
            if es.can_execute(p):
                assert sol_rows(sys_.engine.execute(es.store, q)) == want
                checked += 1
    return checked


def test_batched_round_matches_per_query_round(batched_system):
    g, sys_ = batched_system
    queries = make_queries(g, sys_, n=12, seed=11)
    rep_loop = sys_.run_round(queries, policy="greedy", observe=False)
    rep_batch = sys_.run_round_batched(queries, policy="greedy",
                                       observe=False)
    assert rep_batch.assignment_counts == rep_loop.assignment_counts
    for a, b in zip(rep_loop.outcomes, rep_batch.outcomes):
        assert a.assigned_to == b.assigned_to
        assert a.n_matches == b.n_matches


def test_completeness_through_batched_path_and_rebalance(batched_system):
    g, sys_ = batched_system
    queries = make_queries(g, sys_, n=16, seed=13)
    assert _check_completeness(g, sys_, queries) >= 3
    # drive frequencies through the batched round, then rebalance (new edge
    # stores -> new version tokens -> cache cannot serve stale results)
    for _ in range(3):
        sys_.run_round_batched(queries, policy="greedy", execute=True)
    sys_.rebalance_all()
    assert _check_completeness(g, sys_, queries) >= 3
    rep = sys_.run_round_batched(queries, policy="greedy", execute=True)
    for o in rep.outcomes:
        if o.assigned_to >= 0:
            assert o.assigned_to in o.executable_edges


def test_sparql_serving_runner():
    """runtime.serving executes SPARQL payload batches via the engine."""
    from repro.runtime.serving import (OffloadServingPool, Replica,
                                       make_sparql_runner)
    g = generate_watdiv_like(scale=0.5, seed=23)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 8, seed=6)]
    eng = QueryEngine()
    runner = make_sparql_runner(g.store, eng)
    pool = OffloadServingPool(
        replicas=[Replica(0, classes={0}, cycles_per_s=2e8, link_bps=75e6,
                          runner=runner)],
        cloud_runner=runner)
    requests = [{"class_id": 0, "cycles": 1e6, "result_bits": 1e4,
                 "payload": q} for q in qs]
    served = pool.admit(requests, policy="greedy")
    assert len(served.responses) == len(qs)
    for q, res in zip(qs, served.responses):
        assert sol_rows(res) == sol_rows(match_bgp(g.store, q))
