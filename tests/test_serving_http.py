"""Serving front end: micro-batch admission + SPARQL-protocol HTTP layer.

Covers the ISSUE-6 surface:

- admission coalescing (concurrent submissions -> ONE engine batch, parity
  with ``query_many``), the sequential degenerate mode, queue-full
  backpressure, deadline expiry, eager parse rejection, close semantics;
- HTTP JSON parity with ``SparqlEndpoint.query`` across both backends x
  both store kinds, GET + both POST encodings, ASK, the W3C results shape
  (unbound cells omitted, predicate-space vars typed ``uri``);
- HTTP status mapping: 400 / 404 / 415 / 503 + Retry-After / 504;
- admission racing ``republish`` / ``rebalance_async`` (round and pool
  modes stay correct across placement epochs);
- the three ISSUE-6 regression fixes, each failing on pre-PR code:
  runnerless-replica reassignment (``OffloadServingPool.admit``), plan
  memo keyed on dictionary version (``SparqlEndpoint.parse``), and
  mid-batch store-version moves never caching under a stale version
  (``SparqlEndpoint._run``).
"""

from __future__ import annotations

import json
import threading
import urllib.error
import urllib.request
from collections import Counter
from urllib.parse import quote, urlencode

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem
from repro.rdf.deltas import TripleDelta
from repro.rdf.dictionary import Dictionary
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.runtime.admission import (AdmissionClosed, AdmissionFullError,
                                     AdmissionQueue, DeadlineExceeded)
from repro.runtime.http import SparqlHttpServer, table_to_json
from repro.runtime.serving import (OffloadServingPool, Replica,
                                   make_sparql_runner)
from repro.sparql.endpoint import SparqlEndpoint
from repro.sparql.engine import QueryEngine
from repro.sparql.query import ParseError

BACKENDS = ["numpy", "jax"]
KINDS = ["mono", "sharded"]


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def build_graph():
    d = Dictionary()
    people = ["alice", "bob", "carol", "dave"]
    products = ["p1", "p2", "p3"]
    cities = ["paris", "tokyo"]
    for t in people + products + cities:
        d.add_entity(t)
    for p in ["knows", "likes", "city"]:
        d.add_predicate(p)
    triples = [
        ("alice", "knows", "bob"), ("bob", "knows", "carol"),
        ("alice", "knows", "carol"), ("carol", "knows", "dave"),
        ("alice", "likes", "p1"), ("bob", "likes", "p1"),
        ("carol", "likes", "p2"), ("dave", "likes", "p3"),
        ("alice", "city", "paris"), ("bob", "city", "paris"),
        ("carol", "city", "tokyo"),          # dave: no city
    ]
    s = np.array([d.entity_id(a) for a, _, _ in triples])
    p = np.array([d.predicate_id(b) for _, b, _ in triples])
    o = np.array([d.entity_id(c) for _, _, c in triples])
    return TripleStore(s, p, o, d.num_entities, d.num_predicates), d


QUERIES = [
    'SELECT ?a ?b WHERE { ?a <knows> ?b }',
    'SELECT ?a ?c WHERE { ?a <knows> ?b . OPTIONAL { ?b <city> ?c } }',
    'SELECT ?x WHERE { { ?x <likes> <p1> } UNION { ?x <city> <tokyo> } }',
    'SELECT DISTINCT ?c WHERE { ?a <city> ?c } ORDER BY ?c',
    'SELECT ?p WHERE { <alice> ?p ?x }',
]


def store_of(kind, store):
    return (ShardedTripleStore.from_store(store, 3) if kind == "sharded"
            else store)


def table_multiset(table):
    return Counter(table.rows(decoded=True))


def http_get(url, query, **params):
    qs = urlencode({"query": query, **params})
    with urllib.request.urlopen(f"{url}/sparql?{qs}") as r:
        return r.status, json.loads(r.read())


@pytest.fixture()
def graph():
    return build_graph()


# ---------------------------------------------------------------------------
# admission queue semantics
# ---------------------------------------------------------------------------


def test_admission_coalesces_concurrent_submissions(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    texts = QUERIES + QUERIES[:3]           # duplicates coalesce too
    with AdmissionQueue(ep, window_s=0.25, max_batch=32) as q:
        tickets = [q.submit(t) for t in texts]
        tables = [t.result(timeout=10) for t in tickets]
    ref = SparqlEndpoint(store, d).query_many(texts)
    for got, want in zip(tables, ref):
        assert table_multiset(got) == table_multiset(want)
    # every submission landed in ONE micro-batch
    assert q.stats.batches == 1
    assert q.stats.max_coalesced == len(texts)
    assert len({t.batch_seq for t in tickets}) == 1
    bs = q.stats.recent[-1]
    assert bs.size == len(texts)
    assert bs.unique_texts == len(QUERIES)  # in-batch text dedup visible
    assert bs.window_fill == pytest.approx(len(texts) / 32)


def test_admission_sequential_degenerate_mode(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with AdmissionQueue(ep, window_s=0.0, max_batch=1) as q:
        for t in QUERIES:
            got = q.query(t)
            assert table_multiset(got) == table_multiset(ep.query(t))
    assert q.stats.batches == len(QUERIES)
    assert q.stats.max_coalesced == 1


def test_queue_full_backpressure_and_drain(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    q = AdmissionQueue(ep, window_s=5.0, max_batch=64, max_queue=2,
                       retry_after_s=0.125)
    t1, t2 = q.submit(QUERIES[0]), q.submit(QUERIES[1])
    with pytest.raises(AdmissionFullError) as exc:
        q.submit(QUERIES[2])
    assert exc.value.retry_after_s == 0.125
    assert q.stats.rejected == 1
    # close(drain=True) dispatches the waiting tickets without the window
    q.close(drain=True)
    assert t1.result(timeout=10).num_matches == \
        ep.query(QUERIES[0]).num_matches
    assert t2.result(timeout=10).num_matches == \
        ep.query(QUERIES[1]).num_matches
    with pytest.raises(AdmissionClosed):
        q.submit(QUERIES[0])


def test_deadline_expired_tickets_dropped_before_dispatch(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with AdmissionQueue(ep, window_s=0.3, max_batch=64) as q:
        doomed = q.submit(QUERIES[0], timeout_s=0.01)
        alive = q.submit(QUERIES[1], timeout_s=30.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=10)
        assert alive.result(timeout=10).num_matches == \
            ep.query(QUERIES[1]).num_matches
    assert q.stats.expired == 1
    assert q.stats.completed == 1
    assert q.stats.recent[-1].expired == 1


def test_submit_parses_eagerly_without_occupying_queue(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with AdmissionQueue(ep, window_s=1.0) as q:
        with pytest.raises(ParseError):
            q.submit("SELECT garbage")
        assert q.depth == 0
        assert q.stats.submitted == 0


def test_close_without_drain_rejects_pending(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    q = AdmissionQueue(ep, window_s=5.0, max_batch=64)
    t = q.submit(QUERIES[0])
    q.close(drain=False)
    with pytest.raises(AdmissionClosed):
        t.result(timeout=10)


# ---------------------------------------------------------------------------
# HTTP layer: JSON parity, W3C shape, status codes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", KINDS)
def test_http_json_parity_with_endpoint(graph, backend, kind):
    store, d = graph
    st = store_of(kind, store)
    ep = SparqlEndpoint(st, d, backend=backend)
    with SparqlHttpServer(ep, window_s=0.002) as srv:
        for text in QUERIES:
            status, payload = http_get(srv.url, text)
            assert status == 200
            want = ep.query(text)
            assert payload == table_to_json(want)
            assert payload["head"]["vars"] == \
                [v.lstrip("?") for v in want.var_names]
            assert len(payload["results"]["bindings"]) == want.num_matches


def test_http_post_both_encodings_and_ask(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with SparqlHttpServer(ep, window_s=0.002) as srv:
        want = json.loads(json.dumps(table_to_json(ep.query(QUERIES[0]))))
        raw = urllib.request.Request(
            srv.url + "/sparql", data=QUERIES[0].encode(),
            headers={"Content-Type": "application/sparql-query"})
        with urllib.request.urlopen(raw) as r:
            assert r.status == 200 and json.loads(r.read()) == want
        form = urllib.request.Request(
            srv.url + "/sparql",
            data=urlencode({"query": QUERIES[0]}).encode(),
            headers={"Content-Type": "application/x-www-form-urlencoded"})
        with urllib.request.urlopen(form) as r:
            assert r.status == 200 and json.loads(r.read()) == want
        _, yes = http_get(srv.url, 'ASK { ?x <knows> <carol> }')
        assert yes == {"head": {}, "boolean": True}
        _, no = http_get(srv.url, 'ASK { <dave> <city> ?c }')
        assert no == {"head": {}, "boolean": False}


def test_http_w3c_shape_unbound_omitted_and_pred_typing(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with SparqlHttpServer(ep, window_s=0.002) as srv:
        # OPTIONAL: ?c unbound where carol's successor has no city
        _, payload = http_get(srv.url, QUERIES[1])
        bindings = payload["results"]["bindings"]
        missing = [b for b in bindings if "c" not in b]
        assert missing, "unbound OPTIONAL cells must be OMITTED, not empty"
        for b in bindings:
            for var, term in b.items():
                assert set(term) == {"type", "value"}
        # predicate-space variables serialize as IRIs
        _, preds = http_get(srv.url, QUERIES[4])
        kinds = {b["p"]["type"] for b in preds["results"]["bindings"]}
        assert kinds == {"uri"}
        vals = {b["p"]["value"] for b in preds["results"]["bindings"]}
        assert vals == {"knows", "likes", "city"}


def test_http_error_codes(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with SparqlHttpServer(ep, window_s=0.002) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(srv.url + "/sparql")
        assert e.value.code == 400                       # missing query
        with pytest.raises(urllib.error.HTTPError) as e:
            http_get(srv.url, "SELECT garbage")
        assert e.value.code == 400                       # parse error
        assert "error" in json.loads(e.value.read())
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(srv.url + "/nope")
        assert e.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(urllib.request.Request(
                srv.url + "/sparql", data=b"x",
                headers={"Content-Type": "text/plain"}))
        assert e.value.code == 415
        with pytest.raises(urllib.error.HTTPError) as e:
            http_get(srv.url, QUERIES[0], timeout="banana")
        assert e.value.code == 400                       # bad param
        with urllib.request.urlopen(srv.url + "/healthz") as r:
            assert r.status == 200
        stats = json.loads(
            urllib.request.urlopen(srv.url + "/stats").read())
        assert stats["admission"]["rejected"] == 0


def test_http_503_queue_full_with_retry_after(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with SparqlHttpServer(ep, window_s=1.0, max_batch=64,
                          max_queue=1, retry_after_s=0.25) as srv:
        codes = {}

        def first():
            codes["first"] = http_get(srv.url, QUERIES[0])[0]

        t = threading.Thread(target=first)
        t.start()
        # wait until the first request occupies the only queue slot
        deadline = threading.Event()
        for _ in range(100):
            if srv.queue.depth == 1:
                break
            deadline.wait(0.01)
        assert srv.queue.depth == 1
        with pytest.raises(urllib.error.HTTPError) as e:
            http_get(srv.url, QUERIES[1])
        assert e.value.code == 503
        assert e.value.headers["Retry-After"] == "0.250"
        t.join(15)
        assert codes["first"] == 200


def test_http_504_deadline(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with SparqlHttpServer(ep, window_s=0.3, max_batch=64) as srv:
        with pytest.raises(urllib.error.HTTPError) as e:
            http_get(srv.url, QUERIES[0], timeout="0.01")
        assert e.value.code == 504


def test_http_concurrent_clients_one_batch(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    texts = QUERIES * 4
    with SparqlHttpServer(ep, window_s=0.25, max_batch=64) as srv:
        out = [None] * len(texts)

        def client(i, t):
            out[i] = http_get(srv.url, t)

        ths = [threading.Thread(target=client, args=(i, t))
               for i, t in enumerate(texts)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(30)
        for (status, payload), text in zip(out, texts):
            assert status == 200
            assert payload == table_to_json(ep.query(text))
        stats = json.loads(
            urllib.request.urlopen(srv.url + "/stats").read())
    # the window coalesced the burst into very few engine batches
    assert stats["admission"]["batches"] <= 3
    assert stats["admission"]["max_coalesced"] >= len(QUERIES)
    assert stats["endpoint_memo"]["hits"] >= 1   # duplicate texts memo-hit


# ---------------------------------------------------------------------------
# admission x placement churn (round + pool modes)
# ---------------------------------------------------------------------------


def make_system(g, n_edges=2):
    params = SystemParams.synthetic(n_users=6, n_edges=n_edges, seed=3,
                                    cloud_mbps=0.05, f_ghz=2.0)
    sys_ = EdgeCloudSystem(g.store, g.dictionary, params,
                           storage_budgets=10 ** 9)
    sys_.prepare([workload_sparql(g, 3, seed=100 + n) for n in range(6)])
    return sys_


def test_round_mode_collects_results_and_matches_endpoint():
    g = generate_watdiv_like(scale=0.5, seed=11)
    sys_ = make_system(g)
    ep = SparqlEndpoint.from_system(sys_)
    texts = workload_sparql(g, 6, seed=5)
    with AdmissionQueue(ep, window_s=0.2, max_batch=32, mode="round") as q:
        tickets = [q.submit(t, user=i % sys_.params.N)
                   for i, t in enumerate(texts)]
        tables = [t.result(timeout=30) for t in tickets]
    ref = SparqlEndpoint(g.store, g.dictionary).query_many(texts)
    for got, want in zip(tables, ref):
        assert got is not None
        assert table_multiset(got) == table_multiset(want)


def test_pool_mode_matches_endpoint():
    g = generate_watdiv_like(scale=0.5, seed=11)
    eng = QueryEngine()
    runner = make_sparql_runner(g.store, eng)
    pool = OffloadServingPool(
        replicas=[Replica(0, {0}, 2e9, 50e6, runner)],
        cloud_runner=runner)
    ep = SparqlEndpoint(g.store, g.dictionary, engine=eng, pool=pool)
    texts = workload_sparql(g, 6, seed=5)
    # mode_kw forwards scheduling knobs to admit_many: greedy placement
    # keeps wide coalesced batches off the exponential B&B path
    with AdmissionQueue(ep, window_s=0.2, max_batch=32, mode="pool",
                        mode_kw={"policy": "greedy"}) as q:
        tables = [t.result(timeout=30) for t in
                  [q.submit(t) for t in texts]]
    ref = SparqlEndpoint(g.store, g.dictionary).query_many(texts)
    for got, want in zip(tables, ref):
        assert table_multiset(got) == table_multiset(want)


def test_admission_mode_validation(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    with pytest.raises(ValueError):
        AdmissionQueue(ep, mode="round")     # no system attached
    with pytest.raises(ValueError):
        AdmissionQueue(ep, mode="pool")      # no pool attached
    with pytest.raises(ValueError):
        AdmissionQueue(ep, mode="warp")


@pytest.mark.slow
def test_round_mode_admission_racing_rebalance_async():
    """Concurrent clients x rebalance_async: every admitted batch holds the
    placement-epoch barrier, so results stay byte-correct across commits."""
    g = generate_watdiv_like(scale=0.5, seed=11)
    sys_ = make_system(g, n_edges=3)
    ep = SparqlEndpoint.from_system(sys_)
    texts = workload_sparql(g, 8, seed=5)
    ref = {t: table_multiset(r) for t, r in zip(
        texts, SparqlEndpoint(g.store, g.dictionary).query_many(texts))}
    errors = []
    with AdmissionQueue(ep, window_s=0.01, max_batch=64,
                        mode="round") as q:
        stop = threading.Event()

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    t = texts[rng.integers(len(texts))]
                    got = q.query(t, user=int(rng.integers(6)))
                    assert table_multiset(got) == ref[t], t
            except Exception as exc:      # pragma: no cover - fail path
                errors.append(exc)

        clients = [threading.Thread(target=client, args=(s,))
                   for s in range(4)]
        for c in clients:
            c.start()
        for _ in range(4):                # placement churn mid-traffic
            sys_.rebalance_async().join(30)
        stop.set()
        for c in clients:
            c.join(30)
    assert not errors, errors[:1]
    assert q.stats.completed > 0 and q.stats.failed == 0


@pytest.mark.slow
def test_pool_mode_admission_racing_republish():
    g = generate_watdiv_like(scale=0.5, seed=11)
    eng = QueryEngine()
    runner = make_sparql_runner(g.store, eng)
    pool = OffloadServingPool(
        replicas=[Replica(0, {0}, 2e9, 50e6, runner),
                  Replica(1, {0}, 2e9, 80e6, runner)],
        cloud_runner=runner)
    ep = SparqlEndpoint(g.store, g.dictionary, engine=eng, pool=pool)
    texts = workload_sparql(g, 8, seed=5)
    ref = {t: table_multiset(r) for t, r in zip(
        texts, SparqlEndpoint(g.store, g.dictionary).query_many(texts))}
    errors = []
    with AdmissionQueue(ep, window_s=0.01, max_batch=64, mode="pool") as q:
        stop = threading.Event()

        def client(seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    t = texts[rng.integers(len(texts))]
                    assert table_multiset(q.query(t)) == ref[t], t
            except Exception as exc:      # pragma: no cover - fail path
                errors.append(exc)

        clients = [threading.Thread(target=client, args=(s,))
                   for s in range(4)]
        for c in clients:
            c.start()
        for i in range(30):               # class churn mid-traffic
            pool.republish(i % 2, {0} if i % 3 else set())
        stop.set()
        for c in clients:
            c.join(30)
    assert not errors, errors[:1]
    assert pool.epoch == 30 and q.stats.failed == 0


# ---------------------------------------------------------------------------
# ISSUE-6 regression fixes (each fails on pre-PR code)
# ---------------------------------------------------------------------------


def test_runnerless_replica_reassigned_to_cloud():
    """Regression (ISSUE 6 satellite 1): a replica whose ``runner`` is None
    must not report edge assignments while the cloud executed the work."""
    cloud_calls = []

    def cloud_runner(ps):
        cloud_calls.append(len(ps))
        return ["cloud"] * len(ps)

    pool = OffloadServingPool(
        replicas=[Replica(0, {0}, 2e9, 1e8, None)],     # scheduler bait
        cloud_runner=cloud_runner)
    reqs = [{"class_id": 0, "cycles": 1e6, "result_bits": 8e3,
             "payload": i} for i in range(4)]
    # the scheduler itself wants the (fast, feasible) edge
    sim = pool.admit(reqs, policy="edge_first", execute=False)
    assert list(sim.assignments) == [0, 0, 0, 0]
    # ...but at execute time the runnerless replica cannot serve: the
    # executed placement AND the reported assignments must both say cloud
    out = pool.admit(reqs, policy="edge_first", execute=True)
    assert list(out.assignments) == [-1, -1, -1, -1]
    assert out.responses == ["cloud"] * 4
    assert cloud_calls == [4]


@pytest.mark.parametrize("kind", KINDS)
def test_plan_memo_invalidated_by_dictionary_growth(graph, kind):
    """Regression (ISSUE 6 satellite 2): a FILTER constant unknown at first
    compile bakes ``ent_id=None`` into the memoized plan; after live ingest
    adds the term, the SAME text must see it."""
    store, d = graph
    st = store_of(kind, store)
    ep = SparqlEndpoint(st, d)
    text = ('SELECT ?x WHERE { ?x <likes> ?prod . '
            'FILTER (?prod = "pnew") }')
    assert ep.query(text).num_matches == 0   # "pnew" not in the dictionary
    # live ingest: new term + a triple using it (store version moves too,
    # so the RESULT memo self-invalidates — the PLAN memo is what's tested)
    pid = d.add_entity("pnew")
    row = np.array([[d.entity_id("alice"), d.predicate_id("likes"), pid]])
    st.apply_delta(TripleDelta(base_version=st.version, add=row))
    got = ep.query(text)
    assert got.num_matches == 1
    assert got.rows(decoded=True) == [("alice",)]


def test_plan_memo_still_memoizes_within_a_version(graph):
    store, d = graph
    ep = SparqlEndpoint(store, d)
    assert ep.parse(QUERIES[0]) is ep.parse(QUERIES[0])
    v = d.version
    d.add_entity("alice")                    # existing term: NOT a new id
    assert d.version == v                    # so no invalidation
    assert ep.parse(QUERIES[0]) is ep.parse(QUERIES[0])


def test_midbatch_version_move_skips_result_caching(graph, monkeypatch):
    """Regression (ISSUE 6 satellite 3): when the store version moves
    between dispatch and caching, results must NOT be cached under the
    dispatch-time version."""
    import repro.sparql.endpoint as ep_mod
    store, d = graph
    ep = SparqlEndpoint(store, d)
    text = QUERIES[0]
    real = ep_mod.evaluate_many

    def racing(plans, st, engine):
        # a content-no-op delta: same row evicted and re-added — data is
        # unchanged but the version token moves, exactly what a concurrent
        # delta-rebalance commit does mid-batch
        row = st.triples()[:1]
        st.apply_delta(TripleDelta(base_version=st.version,
                                   add=row, evict=row))
        return real(plans, st, engine)

    monkeypatch.setattr(ep_mod, "evaluate_many", racing)
    v_old = store.version
    got = ep.query(text)                     # still answers correctly
    assert got.num_matches == 4
    assert (text, v_old) not in ep._results, \
        "results computed after a version move were cached under the " \
        "dispatch-time version"
    assert not any(k[0] == text for k in ep._results)
    # with the race gone, the same text caches normally again
    monkeypatch.setattr(ep_mod, "evaluate_many", real)
    ep.query(text)
    assert (text, store.version) in ep._results
