"""Deterministic mini property-testing engine — fallback when ``hypothesis``
is not installed.

``hypothesis`` is the declared test dependency (requirements-test.txt) and
is preferred: it shrinks failures and explores adversarially. This module
implements just the slice of its API the suite uses (``given``, ``settings``,
``strategies.{integers, lists, booleans, sampled_from, randoms, composite}``)
so the property tests still *run* — with a fixed seed and no shrinking —
on environments where the dependency cannot be installed. Draw semantics
match hypothesis closely enough that the same test bodies work unchanged.

Usage (in test modules)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_DEFAULT_MAX_EXAMPLES = 50
_SEED = 0xC0FFEE


class Strategy:
    """A value generator: ``example(rng) -> value``."""

    def __init__(self, fn):
        self._fn = fn

    def example(self, rng: random.Random):
        return self._fn(rng)


def _integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def _booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq) -> Strategy:
    items = list(seq)
    return Strategy(lambda rng: items[rng.randrange(len(items))])


def _lists(elements: Strategy, min_size: int = 0,
           max_size: int | None = None) -> Strategy:
    hi = min_size + 10 if max_size is None else max_size
    return Strategy(lambda rng: [elements.example(rng)
                                 for _ in range(rng.randint(min_size, hi))])


def _randoms() -> Strategy:
    return Strategy(lambda rng: random.Random(rng.getrandbits(64)))


def _composite(fn):
    """``@st.composite``: ``fn(draw, *args)`` -> a Strategy factory."""
    @functools.wraps(fn)
    def make(*args, **kwargs):
        def gen(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return Strategy(gen)
    return make


strategies = SimpleNamespace(
    integers=_integers, booleans=_booleans, sampled_from=_sampled_from,
    lists=_lists, randoms=_randoms, composite=_composite,
)
# tests also spell `@st.composite` at module level via `strategies as st`
st = strategies


def settings(**kwargs):
    """Records ``max_examples``; other knobs (deadline, ...) are ignored."""
    def deco(fn):
        fn._fallback_settings = dict(kwargs)
        return fn
    return deco


def given(*strats: Strategy):
    """Run the test body over ``max_examples`` seeded draws.

    The wrapper takes no parameters (drawn values are appended
    positionally), so pytest does not mistake strategy arguments for
    fixtures — mirroring hypothesis's own signature rewriting.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            cfg = (getattr(wrapper, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", {}))
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                fn(*(s.example(rng) for s in strats))
        # functools.wraps sets __wrapped__, which inspect.signature follows —
        # pytest would then see the original parameters and demand fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
