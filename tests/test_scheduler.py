"""MINLP scheduler: CRA closed form, R-QAD relaxation, B&B vs brute force."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared test dep; deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.baselines import (BASELINES, cloud_only, edge_first,
                                  greedy_assign, random_assign)
from repro.core.bnb import branch_and_bound, brute_force
from repro.core.cost import QueryTasks, SystemParams, assignment_cost, total_cost
from repro.core.cra import allocate_closed_form, o_total_calc
from repro.core.qad import (build_qad_arrays, round_relaxed, solve_rqad)
from repro.core.scheduler import schedule


def make_instance(N, K, seed=0, exec_prob=0.7):
    rng = np.random.default_rng(seed)
    params = SystemParams.synthetic(N, K, seed=seed)
    c = rng.uniform(1e7, 5e8, N)              # cycles
    w = rng.uniform(1e5, 5e7, N)              # bits
    e = (rng.random((N, K)) < exec_prob).astype(float) * params.assoc
    return QueryTasks(c=c, w=w, e=e), params


# -- CRA ----------------------------------------------------------------------

def test_cra_matches_scipy():
    from scipy.optimize import minimize
    rng = np.random.default_rng(1)
    N, K = 6, 2
    c = rng.uniform(1e6, 1e8, N)
    F = np.array([2e8, 3e8])
    De = np.zeros((N, K))
    De[:3, 0] = 1
    De[3:, 1] = 1
    f_closed = allocate_closed_form(De, c, F)
    o_closed = o_total_calc(De, c, F)

    # numeric optimum per edge server (normalized: f = x * F_k, obj scaled)
    for k in range(K):
        members = np.flatnonzero(De[:, k] > 0)
        cm = c[members] / c[members].max()

        def obj(x):
            return np.sum(cm / x)
        res = minimize(obj, np.full(len(members), 1.0 / len(members)),
                       constraints=[{"type": "ineq",
                                     "fun": lambda x: 1.0 - x.sum()}],
                       bounds=[(1e-6, 1.0)] * len(members), method="SLSQP")
        assert res.success
        assert np.allclose(res.x * F[k], f_closed[members, k], rtol=1e-3)
    # objective identity (Eq. 13)
    direct = sum(c[n] / f_closed[n, k] for k in range(K)
                 for n in np.flatnonzero(De[:, k] > 0))
    assert np.isclose(direct, o_closed, rtol=1e-12)


def test_cra_respects_capacity():
    tasks, params = make_instance(10, 3, seed=2)
    D = edge_first(tasks, params)
    f = allocate_closed_form(D * tasks.e * params.assoc, tasks.c, params.F)
    assert (f.sum(axis=0) <= params.F * (1 + 1e-9)).all()
    assert (f >= 0).all()


# -- R-QAD --------------------------------------------------------------------

def test_rqad_against_scipy():
    from scipy.optimize import minimize
    tasks, params = make_instance(5, 2, seed=3)
    e = tasks.e * params.assoc
    A, b, const = build_qad_arrays(tasks.c, tasks.w, e, params.r_edge,
                                   params.r_cloud)
    N, K = A.shape
    fixed_mask = np.zeros(N)
    fixed_D = np.zeros((N, K))
    D_rel, f_val, lb = solve_rqad(A, b, params.F, e, fixed_mask, fixed_D, 600)
    D_rel, f_val, lb = map(np.asarray, (D_rel, f_val, lb))

    def obj(x):
        D = x.reshape(N, K)
        S = (D * A).sum(axis=0)
        return (S ** 2 / params.F).sum() + (D * b).sum()

    cons = [{"type": "ineq",
             "fun": (lambda x, n=n: 1.0 - (x.reshape(N, K)[n] * e[n]).sum())}
            for n in range(N)]
    res = minimize(obj, np.zeros(N * K), bounds=[(0, 1)] * (N * K),
                   constraints=cons, method="SLSQP")
    assert f_val <= res.fun + 1e-6 * abs(res.fun) + 1e-9 or \
        np.isclose(f_val, res.fun, rtol=1e-4)
    # certified lower bound really is below both
    assert lb <= f_val + 1e-9
    assert lb <= res.fun + 1e-6 * abs(res.fun)


def test_rqad_feasibility_and_fixed_rows():
    tasks, params = make_instance(8, 3, seed=4)
    e = tasks.e * params.assoc
    A, b, const = build_qad_arrays(tasks.c, tasks.w, e, params.r_edge,
                                   params.r_cloud)
    fixed_mask = np.zeros(8)
    fixed_mask[:3] = 1
    fixed_D = np.zeros((8, 3))
    feas0 = np.flatnonzero(e[0] > 0)
    if len(feas0):
        fixed_D[0, feas0[0]] = 1.0
    D_rel, f_val, lb = solve_rqad(A, b, params.F, e, fixed_mask, fixed_D, 300)
    D_rel = np.asarray(D_rel)
    # constraints
    assert (D_rel >= -1e-9).all() and (D_rel <= 1 + 1e-9).all()
    assert ((D_rel * e).sum(axis=1) <= 1 + 1e-6).all()
    # fixed rows pinned
    assert np.allclose(D_rel[:3], fixed_D[:3])
    # e-infeasible coords zero
    assert np.allclose(D_rel[e == 0], 0.0)


def test_round_relaxed_feasible():
    D = np.array([[0.6, 0.3], [0.5, 0.5], [0.2, 0.1], [0.0, 0.9]])
    e = np.ones_like(D)
    R = round_relaxed(D, e)
    assert set(np.unique(R)) <= {0.0, 1.0}
    assert (R.sum(axis=1) <= 1).all()
    assert R[0, 0] == 1 and R[3, 1] == 1 and R[2].sum() == 0


# -- B&B ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_bnb_matches_brute_force(seed):
    tasks, params = make_instance(6, 2, seed=seed)
    bf = brute_force(tasks, params)
    bb = branch_and_bound(tasks, params, solver_iters=300)
    assert bb.optimal
    assert np.isclose(bb.objective, bf.objective, rtol=1e-9), \
        f"bnb {bb.objective} vs brute {bf.objective}"


def test_bnb_best_first_matches_too():
    tasks, params = make_instance(5, 3, seed=7)
    bf = brute_force(tasks, params)
    bb = branch_and_bound(tasks, params, strategy="best_first")
    assert np.isclose(bb.objective, bf.objective, rtol=1e-9)


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_bnb_rqad_bound_matches_brute_force(seed):
    """Paper-faithful bounding mode (convex relaxation) is exact too."""
    tasks, params = make_instance(5, 2, seed=seed)
    bf = brute_force(tasks, params)
    bb = branch_and_bound(tasks, params, bound="rqad", solver_iters=400,
                          warm_start="cloud", order="given")
    assert np.isclose(bb.objective, bf.objective, rtol=1e-9)


def test_bnb_fast_at_paper_scale():
    """N=20, K=4 (the paper's default) must schedule in well under a second."""
    tasks, params = make_instance(20, 4, seed=12)
    bb = branch_and_bound(tasks, params)
    assert bb.optimal
    assert bb.solve_seconds < 1.0, f"too slow: {bb.solve_seconds:.3f}s"


def test_bnb_beats_or_ties_baselines():
    tasks, params = make_instance(12, 3, seed=5)
    bb = branch_and_bound(tasks, params)
    for name, fn in BASELINES.items():
        D = fn(tasks, params)
        assert bb.objective <= assignment_cost(D, tasks, params) + 1e-9, name


def test_bnb_prunes():
    tasks, params = make_instance(10, 3, seed=6)
    bb = branch_and_bound(tasks, params)
    total_leaves = np.prod([1 + tasks.e[n].sum() for n in range(10)])
    assert bb.nodes_explored < total_leaves


def test_constraints_satisfied_all_policies():
    tasks, params = make_instance(15, 4, seed=8)
    for policy in ["bnb", "cloud_only", "random", "edge_first", "greedy"]:
        r = schedule(tasks, params, policy=policy)
        D = r.D
        assert set(np.unique(D)) <= {0.0, 1.0}                       # C1
        assert ((D * tasks.e * params.assoc).sum(axis=1) <= 1).all()  # C2
        assert (r.f >= 0).all()                                       # C3
        assert (r.f.sum(axis=0) <= params.F * (1 + 1e-9)).all()       # C4
        # objective consistency
        assert np.isclose(r.objective, assignment_cost(D, tasks, params),
                          rtol=1e-9)


def test_total_cost_consistency():
    tasks, params = make_instance(8, 2, seed=9)
    D = greedy_assign(tasks, params)
    f = allocate_closed_form(D * tasks.e * params.assoc, tasks.c, params.F)
    v1 = total_cost(D, f, tasks, params)
    v2 = assignment_cost(D, tasks, params)
    assert np.isclose(v1, v2, rtol=1e-9)


# -- property: B&B optimality on random tiny instances -------------------------

@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_bnb_optimal_property(seed, N, K):
    tasks, params = make_instance(N, K, seed=seed)
    bf = brute_force(tasks, params)
    bb = branch_and_bound(tasks, params)
    assert bb.objective <= bf.objective * (1 + 1e-9) + 1e-12
    assert bb.objective >= bf.objective * (1 - 1e-9) - 1e-12
