"""Shard-parallel join pipeline (PR 3): predicate-variable join bugfixes,
post-mask capacity semantics, plan/scan-counter invariants, shard-local vs
global join equivalence, and overlapped vs sequential round parity —
both backends x monolithic/sharded store x overlapped/sequential rounds."""

import numpy as np
import pytest

from repro.core.cost import SystemParams, result_bits
from repro.edge.server import ExecutionRecord
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.engine import QueryEngine
from repro.sparql.matcher import (CandidateParts, JoinStats,
                                  MatchCapacityError, match_bgp,
                                  match_oracle, plan_bgp)
from repro.sparql.query import QueryGraph, TriplePattern, parse_sparql

from test_engine import BACKENDS, sol_rows

# BGPs whose only (or dominant) shared variables are PREDICATE variables —
# the shapes that used to fall through to the cartesian branch
PRED_VAR_ADVERSARIAL = [
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?a", "?p", "?b")],
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?a", "?p", "?b"),
     TriplePattern("?c", "?p", "?d")],
    [TriplePattern("?x", "?p", "?x"), TriplePattern("?a", "?p", "?b")],
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?y", "?q", "?z"),
     TriplePattern("?a", "?p", "?b")],
    [TriplePattern(0, "?p", 1), TriplePattern("?a", "?p", "?b")],
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?a", "?q", "?b")],
    [TriplePattern("?x", "?p", "?y"), TriplePattern("?a", "?p", "?y")],
]


def paired_stores(rng, num_shards=3, n_ent=10, n_pred=4, n_trip=30):
    s = rng.integers(0, n_ent, n_trip)
    p = rng.integers(0, n_pred, n_trip)
    o = rng.integers(0, n_ent, n_trip)
    return (TripleStore(s, p, o, n_ent, n_pred),
            ShardedTripleStore(s, p, o, n_ent, n_pred,
                               num_shards=num_shards))


# ---------------------------------------------------------------------------
# headline bugfix: predicate-variable joins
# ---------------------------------------------------------------------------

def test_pred_var_join_regression_no_capacity_error():
    """A BGP whose only shared variable is a predicate variable must join on
    it, not expand the R*C cartesian product. On the old code this raises
    MatchCapacityError (400*400 pre-mask rows > max_rows) even though the
    true result has only 400 rows."""
    T = 400
    store = TripleStore(np.arange(T), np.arange(T), np.arange(T) + 1,
                        T + 1, T)                  # one triple per predicate
    q = QueryGraph([TriplePattern("?x", "?p", "?y"),
                    TriplePattern("?a", "?p", "?b")], [])
    res = match_bgp(store, q, max_rows=5_000)
    assert res.num_matches == T
    # plan took the predicate-variable join, not the cartesian branch
    js = JoinStats()
    match_bgp(store, q, max_rows=5_000, stats=js)
    assert js.joins_pred_var == 1
    assert js.joins_cartesian == 1                 # only the seed expansion


def test_pred_var_join_equals_oracle():
    rng = np.random.default_rng(0)
    for trial in range(5):
        mono, sh = paired_stores(rng, n_trip=int(rng.integers(10, 40)))
        for pats in PRED_VAR_ADVERSARIAL:
            q = QueryGraph(pats, [])
            sols, vs = match_oracle(mono, q)
            for store in (mono, sh):
                res = match_bgp(store, q)
                got = {tuple(r) for r in res.project(vs).tolist()}
                assert got == sols, (trial, pats)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sharded", [False, True])
def test_pred_var_matrix_through_engine(backend, sharded):
    """Oracle-equivalence matrix for predicate-variable-heavy BGPs through
    execute_batch: both backends x both store kinds."""
    rng = np.random.default_rng(1)
    eng = QueryEngine(backend=backend)
    for trial in range(3):
        mono, sh = paired_stores(rng, n_trip=int(rng.integers(10, 40)))
        store = sh if sharded else mono
        queries = [QueryGraph(pats, []) for pats in PRED_VAR_ADVERSARIAL]
        for q, res in zip(queries, eng.execute_batch(store, queries)):
            sols, vs = match_oracle(mono, q)
            assert {tuple(r) for r in res.project(vs).tolist()} == sols


# ---------------------------------------------------------------------------
# capacity semantics: max_rows bounds SURVIVING rows
# ---------------------------------------------------------------------------

def test_capacity_applies_post_mask_on_vertex_join():
    """Two parallel stars: the ?x-join fans out n*n rows pre-mask but only n
    survive the ?y equality mask; the old pre-mask check raised."""
    n = 150
    s = np.zeros(2 * n, dtype=np.int64)
    p = np.concatenate([np.zeros(n, np.int64), np.ones(n, np.int64)])
    o = np.concatenate([np.arange(n), np.arange(n)])
    store = TripleStore(s, p, o, n + 1, 2)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?x", 1, "?y")], [])
    res = match_bgp(store, q, max_rows=2 * n)      # old: raises at n*n
    assert res.num_matches == n
    sols, vs = match_oracle(store, q)
    assert {tuple(r) for r in res.project(vs).tolist()} == sols


def test_capacity_boundary_exact():
    """max_rows == surviving rows passes; one less raises."""
    n = 64
    s = np.zeros(2 * n, dtype=np.int64)
    p = np.concatenate([np.zeros(n, np.int64), np.ones(n, np.int64)])
    o = np.concatenate([np.arange(n), np.arange(n)])
    store = TripleStore(s, p, o, n + 1, 2)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?x", 1, "?y")], [])
    assert match_bgp(store, q, max_rows=n).num_matches == n
    with pytest.raises(MatchCapacityError):
        match_bgp(store, q, max_rows=n - 1)


def test_capacity_still_raises_on_genuine_blowup():
    rng = np.random.default_rng(2)
    mono, _ = paired_stores(rng, n_trip=40)
    q = QueryGraph([TriplePattern("?x", "?p", "?y"),
                    TriplePattern("?a", "?q", "?b")], [])   # true cartesian
    with pytest.raises(MatchCapacityError):
        match_bgp(mono, q, max_rows=50)


def test_single_row_fanout_is_subchunked():
    """One binding row whose raw fan-out exceeds max_rows must be processed
    in sub-chunks (bounded peak memory) and survive when the equality mask
    keeps few rows."""
    n = 5_000
    # pred 0: one edge 0->5 (binds ?x=0, ?y=5 as the single row);
    # pred 1: star 0 -> {0..n-1}, so the ?x-join fans out n rows pre-mask
    s = np.concatenate([[0], np.zeros(n, np.int64)])
    p = np.concatenate([[0], np.ones(n, np.int64)])
    o = np.concatenate([[5], np.arange(n)])
    store = TripleStore(s, p, o, n + 1, 2)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?x", 1, "?y")], [])
    res = match_bgp(store, q, max_rows=600)        # 600 << n pre-mask rows
    assert res.num_matches == 1
    assert res.column("?y").tolist() == [5]
    # and with no mask to save it, the capacity error still fires
    q2 = QueryGraph([TriplePattern("?x", 0, "?y"),
                     TriplePattern("?x", 1, "?z")], [])
    with pytest.raises(MatchCapacityError):
        match_bgp(store, q2, max_rows=600)


@pytest.mark.parametrize("num_shards", [1, 4])
def test_chunked_join_equals_unchunked(num_shards):
    """Tiny max_rows forces the chunked expansion path; results must be
    identical to the roomy path (same multiset)."""
    rng = np.random.default_rng(3)
    mono, sh = paired_stores(rng, num_shards=num_shards, n_trip=60)
    store = sh if num_shards > 1 else mono
    for pats in PRED_VAR_ADVERSARIAL[:4]:
        q = QueryGraph(pats, [])
        want = sol_rows(match_bgp(store, q))
        if len(want) == 0:
            continue
        assert sol_rows(match_bgp(store, q, max_rows=len(want))) == want


# ---------------------------------------------------------------------------
# shard-local vs global join pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_shard_local_equals_global_join(backend):
    g = generate_watdiv_like(scale=0.5, seed=11)
    sh = ShardedTripleStore.from_store(g.store, 4)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 10, seed=7)]
    eng_shard = QueryEngine(backend=backend, shard_local_joins=True)
    eng_global = QueryEngine(backend=backend, shard_local_joins=False)
    for res, ref in zip(eng_shard.execute_batch(sh, qs),
                        eng_global.execute_batch(sh, qs)):
        assert sol_rows(res) == sol_rows(ref)
    # the shard-local pipeline actually took the presorted path
    assert eng_shard.stats.join.joins_pred_index > 0
    assert eng_global.stats.join.joins_pred_index == 0
    # presorted joins skip their candidate scans entirely
    assert eng_shard.stats.scans_requested < eng_global.stats.scans_requested


def test_plan_marks_pred_index_steps():
    rng = np.random.default_rng(4)
    mono, _ = paired_stores(rng)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?y", 1, "?z")], [])
    plan = plan_bgp(mono, q)
    assert [st.kind for st in plan] == ["seed", "vertex"]
    assert plan[0].needs_scan and not plan[1].needs_scan
    assert plan[1].use_pred_index
    # globally-disabled shard-local path plans every step as a scan
    assert all(st.needs_scan for st in plan_bgp(mono, q, shard_local=False))
    # constants / repeated vars / variable predicates disqualify the
    # presorted path FOR THAT PATTERN (other patterns may still take it)
    for pats in ([TriplePattern("?x", 0, "?y"), TriplePattern("?y", 1, 5)],
                 [TriplePattern("?x", 0, "?y"),
                  TriplePattern("?y", 1, "?y")],
                 [TriplePattern("?x", 0, "?y"),
                  TriplePattern("?y", "?p", "?z")]):
        step = next(st for st in plan_bgp(mono, QueryGraph(pats, []))
                    if st.pattern == 1)
        assert not step.use_pred_index and step.needs_scan


def test_merged_joins_on_sharded_store():
    """Variable-predicate candidates span shards: the engine feeds multi-part
    CandidateParts to the matcher and partial binding tables are merged."""
    rng = np.random.default_rng(5)
    mono, sh = paired_stores(rng, num_shards=4, n_pred=6, n_trip=80)
    eng = QueryEngine(backend="numpy")
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?y", "?p", "?z")], [])
    res = eng.execute(sh, q)
    assert eng.stats.join.merged_joins >= 1
    assert sol_rows(res) == sol_rows(match_bgp(mono, q))


def test_candidate_parts_normalization():
    a = np.array([3, 1], dtype=np.int64)
    parts = CandidateParts([a, np.zeros(0, dtype=np.int64),
                            np.array([7], dtype=np.int64)])
    assert parts.total == len(parts) == 3
    assert parts.nbytes == 3 * 8
    assert sorted(parts.concat().tolist()) == [1, 3, 7]
    assert CandidateParts.of(parts) is parts
    assert CandidateParts.of(a).parts[0] is a


# ---------------------------------------------------------------------------
# stats invariants
# ---------------------------------------------------------------------------

def test_scan_counter_invariants():
    """scans_deduped can never go negative; every executed scan is exactly
    one scan-LRU miss — across repeated batches, cache hits, store switches
    and mid-join lookups."""
    g = generate_watdiv_like(scale=0.5, seed=13)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 8, seed=3)]
    # selective pred-var shapes (self-loop / constant seeds keep the
    # ?p-join small on the ~5k-triple store)
    qs += [QueryGraph(PRED_VAR_ADVERSARIAL[2], []),
           QueryGraph(PRED_VAR_ADVERSARIAL[4], [])]
    stores = [g.store, ShardedTripleStore.from_store(g.store, 3),
              g.store.subgraph(np.arange(g.store.num_triples // 2))]

    def check(eng):
        s = eng.stats
        assert s.scans_deduped >= 0
        assert s.scans_requested >= s.scans_executed
        assert s.scans_executed == s.scan_cache_misses

    for kwargs in ({}, {"cache_size": 0}, {"scan_cache_bytes": 0},
                   {"shard_local_joins": False}):
        eng = QueryEngine(backend="numpy", **kwargs)
        for _ in range(3):
            for store in stores:
                eng.execute_batch(store, qs)
                check(eng)
        for q in qs:                       # single-query path
            eng.execute(stores[0], q)
            check(eng)


def test_per_phase_stats_populated():
    g = generate_watdiv_like(scale=0.5, seed=17)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 6, seed=5)]
    eng = QueryEngine(backend="numpy")
    eng.execute_batch(ShardedTripleStore.from_store(g.store, 4), qs)
    s = eng.stats
    assert s.prescan_seconds > 0 and s.join_seconds > 0
    assert s.exec_seconds >= s.join_seconds
    js = s.join
    assert js.partitions_probed >= (js.joins_pred_index + js.joins_vertex
                                    + js.joins_pred_var + js.joins_cartesian)


# ---------------------------------------------------------------------------
# result_bits single-sourcing
# ---------------------------------------------------------------------------

def test_execution_record_bits_single_sourced():
    rng = np.random.default_rng(19)
    mono, _ = paired_stores(rng, n_trip=50)
    q = QueryGraph([TriplePattern("?x", 0, "?y")], ["?x"])
    res = match_bgp(mono, q)
    rec = ExecutionRecord.of(res, q.projection, 0.01)
    assert rec.result_bits == result_bits(res, q.projection)
    assert rec.result_bits == res.result_bytes(q.projection) * 8
    assert rec.n_matches == res.num_matches


def test_cloud_and_batch_records_agree_on_units():
    from repro.edge.server import CloudServer
    g = generate_watdiv_like(scale=0.5, seed=23)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 4, seed=9)]
    cloud = CloudServer(g.store)
    batch_recs = [rec for _, rec in cloud.execute_batch(qs)]
    for q, brec in zip(qs, batch_recs):
        _, rec = cloud.execute(q)
        assert rec.result_bits == brec.result_bits
        assert rec.n_matches == brec.n_matches


# ---------------------------------------------------------------------------
# overlapped rounds
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["numpy", "jax"])
def overlap_system(request):
    g = generate_watdiv_like(scale=0.5, seed=29)
    params = SystemParams.synthetic(n_users=8, n_edges=3, seed=5)
    systems = {}
    for kind, store in (("mono", g.store),
                        ("sharded", ShardedTripleStore.from_store(g.store,
                                                                  4))):
        sys_ = EdgeCloudSystem(store, g.dictionary, params,
                               storage_budgets=150_000,
                               backend=request.param)
        sys_.prepare([workload_sparql(g, 3, seed=400 + n)
                      for n in range(8)])
        systems[kind] = sys_
    queries = [(i % 8, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, 12, seed=31))]
    return g, systems, queries


@pytest.mark.parametrize("kind", ["mono", "sharded"])
def test_overlapped_round_matches_sequential(overlap_system, kind):
    """overlap=True must produce the same RoundReport outcomes as the
    sequential batched round and the per-query round."""
    g, systems, queries = overlap_system
    sys_ = systems[kind]
    rep_seq = sys_.run_round_batched(queries, policy="greedy",
                                     observe=False)
    rep_ov = sys_.run_round_batched(queries, policy="greedy",
                                    observe=False, overlap=True)
    rep_loop = sys_.run_round(queries, policy="greedy", observe=False)
    assert not rep_seq.overlapped and rep_ov.overlapped
    assert rep_ov.assignment_counts == rep_seq.assignment_counts \
        == rep_loop.assignment_counts
    for a, b, c in zip(rep_seq.outcomes, rep_ov.outcomes,
                       rep_loop.outcomes):
        assert a.assigned_to == b.assigned_to == c.assigned_to
        assert a.n_matches == b.n_matches == c.n_matches
        assert a.executable_edges == b.executable_edges
    # per-server wall clock was measured inside each thread
    assert set(rep_ov.server_wall_seconds) == set(rep_ov.assignment_counts)
    assert all(dt >= 0 for dt in rep_ov.server_wall_seconds.values())
    assert rep_ov.execute_wall_seconds > 0


def test_overlapped_round_solutions_complete(overlap_system):
    """Solution multisets through the overlapped round's engine equal the
    direct matcher — the completeness guarantee is execution-strategy
    independent."""
    g, systems, queries = overlap_system
    sys_ = systems["sharded"]
    sys_.run_round_batched(queries, policy="greedy", observe=False,
                           overlap=True)
    for (_, q) in queries[:6]:
        res = sys_.engine.execute(sys_.cloud.store, q)
        assert sol_rows(res) == sol_rows(match_bgp(g.store, q))


_PROCESS_OVERLAP_SCRIPT = r"""
from repro.core.cost import SystemParams
from repro.edge.system import EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.query import parse_sparql

g = generate_watdiv_like(scale=0.5, seed=41)
params = SystemParams.synthetic(n_users=6, n_edges=2, seed=3)
sys_ = EdgeCloudSystem(ShardedTripleStore.from_store(g.store, 3),
                       g.dictionary, params, storage_budgets=150_000,
                       backend="numpy")
sys_.prepare([workload_sparql(g, 3, seed=500 + n) for n in range(6)])
queries = [(i % 6, parse_sparql(t, g.dictionary))
           for i, t in enumerate(workload_sparql(g, 10, seed=43))]
try:
    rep_seq = sys_.run_round_batched(queries, policy="greedy",
                                     observe=False)
    rep_pr = sys_.run_round_batched(queries, policy="greedy",
                                    observe=False, overlap="process")
    assert rep_pr.overlapped and rep_pr.overlap_mode == "process"
    assert rep_pr.assignment_counts == rep_seq.assignment_counts
    for a, b in zip(rep_seq.outcomes, rep_pr.outcomes):
        assert a.assigned_to == b.assigned_to
        assert a.n_matches == b.n_matches
    pool1 = sys_._proc_pool
    assert pool1 is not None
    sys_.run_round_batched(queries, policy="greedy", observe=True,
                           overlap="process")
    assert sys_._proc_pool is pool1          # reused while stores stable
    sys_.rebalance_all()                     # may deploy new stores
    rep_pr2 = sys_.run_round_batched(queries, policy="greedy",
                                     observe=False, overlap="process")
    rep_seq2 = sys_.run_round_batched(queries, policy="greedy",
                                      observe=False)
    assert rep_pr2.assignment_counts == rep_seq2.assignment_counts
    for a, b in zip(rep_seq2.outcomes, rep_pr2.outcomes):
        assert a.n_matches == b.n_matches
    # cold-start broadcast: clearing caches must not change results
    sys_.clear_engine_caches()
    rep_pr3 = sys_.run_round_batched(queries, policy="greedy",
                                     observe=False, overlap="process")
    for a, b in zip(rep_pr2.outcomes, rep_pr3.outcomes):
        assert a.n_matches == b.n_matches
finally:
    sys_.close_overlap_pool()
print("PROCESS-OVERLAP-OK")
"""


def test_process_overlap_matches_sequential():
    """overlap='process' (persistent fork pool): same outcomes, pool reused
    across rounds, rebuilt after rebalance. Runs in a fresh subprocess: in
    this pytest process XLA is (eventually) initialized, which correctly
    downgrades process mode to threads — a clean numpy-only process is the
    supported deployment for the fork pool."""
    import os
    import subprocess
    import sys
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _PROCESS_OVERLAP_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "PROCESS-OVERLAP-OK" in proc.stdout


def test_process_overlap_falls_back_to_threads_on_jax():
    g = generate_watdiv_like(scale=0.3, seed=47)
    params = SystemParams.synthetic(n_users=4, n_edges=2, seed=3)
    sys_ = EdgeCloudSystem(g.store, g.dictionary, params,
                           storage_budgets=100_000, backend="jax")
    sys_.prepare([workload_sparql(g, 2, seed=600 + n) for n in range(4)])
    queries = [(i % 4, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, 6, seed=49))]
    rep = sys_.run_round_batched(queries, policy="greedy", observe=False,
                                 overlap="process")
    assert rep.overlap_mode == "thread"          # forked XLA is unsafe
    assert sys_._proc_pool is None


def test_overlap_mode_resolution():
    """overlap=True auto-picks per backend (process for GIL-bound numpy,
    thread for jax whose fork is unsafe); explicit strings pass through."""
    from repro.edge.system import resolve_overlap_mode
    assert resolve_overlap_mode(False, "numpy") == ""
    assert resolve_overlap_mode(False, "jax") == ""
    assert resolve_overlap_mode(True, "numpy") == "process"
    assert resolve_overlap_mode(True, "jax") == "thread"
    for explicit in ("thread", "process"):
        assert resolve_overlap_mode(explicit, "numpy") == explicit
        assert resolve_overlap_mode(explicit, "jax") == explicit


def test_device_vs_host_joinstats_parity():
    """The device-resident pipeline reports the SAME join counters as the
    host path for the same plans — joins_device alone says WHERE a presorted
    join ran, never changing what was counted."""
    from dataclasses import asdict

    from repro.sparql.engine import JaxBackend

    g = generate_watdiv_like(scale=0.5, seed=11)
    sh = ShardedTripleStore.from_store(g.store, 4)
    qs = [QueryGraph([TriplePattern("?x", 0, "?y"),
                      TriplePattern("?y", 1, "?z")], []),
          QueryGraph([TriplePattern("?x", 2, "?y"),
                      TriplePattern("?x", 3, "?z")], []),
          QueryGraph([TriplePattern("?a", 1, "?b")], [])]
    eng_dev = QueryEngine(backend=JaxBackend(bt=512))
    eng_host = QueryEngine(backend=JaxBackend(bt=512,
                                              device_resident=False))
    for res, ref in zip(eng_dev.execute_batch(sh, qs),
                        eng_host.execute_batch(sh, qs)):
        assert sol_rows(res) == sol_rows(ref)
    dev, host = asdict(eng_dev.stats.join), asdict(eng_host.stats.join)
    assert dev.pop("joins_device") > 0
    assert host.pop("joins_device") == 0
    assert dev == host
    assert eng_dev.stats.device_queries == len(qs)


def test_serving_overlap_matches_sequential():
    from repro.runtime.serving import (OffloadServingPool, Replica,
                                       make_sparql_runner)
    g = generate_watdiv_like(scale=0.5, seed=37)
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 8, seed=11)]
    eng = QueryEngine()
    runner = make_sparql_runner(g.store, eng)
    pool = OffloadServingPool(
        replicas=[Replica(0, classes={0}, cycles_per_s=2e8, link_bps=75e6,
                          runner=runner),
                  Replica(1, classes={1}, cycles_per_s=2e8, link_bps=75e6,
                          runner=runner)],
        cloud_runner=runner)
    requests = [{"class_id": i % 3, "cycles": 1e6, "result_bits": 1e4,
                 "payload": q} for i, q in enumerate(qs)]
    seq = pool.admit(requests, policy="greedy")
    ov = pool.admit(requests, policy="greedy", overlap=True)
    assert np.array_equal(seq.assignments, ov.assignments)
    for a, b in zip(seq.responses, ov.responses):
        assert sol_rows(a) == sol_rows(b)
